"""Streaming Sphere tests: the multi-tenant admission queue (pure python,
deterministic virtual clocks), cross-batch carry + stream/batch equivalence
(8-device subprocesses), and the compile-cache counters."""

import collections
import os

import numpy as np
import pytest

from test_spmd import run_spmd

from repro.sphere.scheduler import DeadlineHeap, SegStatus
from repro.sphere.streaming import QueueFull, TenantQueue

BENCH = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                     "benchmarks"))


# -- DeadlineHeap --------------------------------------------------------------


def test_deadline_heap_pop_due_order_and_peek():
    h = DeadlineHeap()
    h.push(5.0, "c")
    h.push(1.0, "a")
    h.push(3.0, "b")
    assert len(h) == 3
    assert h.peek() == 1.0
    assert h.pop_due(0.5) == []
    assert [x for _, x in h.pop_due(3.0)] == ["a", "b"]
    assert len(h) == 1
    assert [x for _, x in h.pop_due(100.0)] == ["c"]
    assert h.peek() is None


# -- TenantQueue: fairness / priority / backpressure ---------------------------


def test_weighted_fair_share_drr():
    """With every tenant permanently backlogged, served cost per tenant
    converges to the weight ratio (deficit round-robin)."""
    weights = {"a": 1.0, "b": 3.0, "c": 4.0}
    q = TenantQueue(quantum=1.0, capacity=10_000)
    for t, w in weights.items():
        q.register(t, weight=w)
    for _ in range(600):                       # deep enough that no tenant
        for t in weights:                      # drains within 100 acquires
            q.admit(t, payload=t, cost=1, now=0.0)
    served = collections.Counter()
    for _ in range(100):                       # all tenants stay backlogged
        for tk in q.acquire(8, now=0.0):
            q.complete(tk, now=1.0)
            served[tk.tenant] += 1
    total = sum(served.values())
    wsum = sum(weights.values())
    assert total == 800
    for t, w in weights.items():
        rel = (served[t] / total) / (w / wsum)
        assert 0.9 <= rel <= 1.1, (t, rel, dict(served))


def test_drr_uneven_costs_converge_to_weights():
    """Fairness is in cost units, not request counts: a tenant sending big
    requests gets the same record share as one sending small requests."""
    q = TenantQueue(quantum=8.0, capacity=10_000)
    q.register("small", weight=1.0)
    q.register("big", weight=1.0)
    for _ in range(400):
        q.admit("small", "s", cost=2, now=0.0)
    for _ in range(100):
        q.admit("big", "b", cost=8, now=0.0)
    served = collections.Counter()
    for _ in range(40):
        for tk in q.acquire(32, now=0.0):
            q.complete(tk, now=1.0)
            served[tk.tenant] += tk.cost
    total = sum(served.values())
    assert total == 40 * 32
    rel = served["small"] / total
    assert 0.45 <= rel <= 0.55, dict(served)


def test_strict_priority_classes_no_bypass():
    q = TenantQueue(quantum=16.0)
    q.register("urgent", priority=0)
    q.register("bulk", priority=1)
    for _ in range(5):
        q.admit("bulk", "b", cost=1, now=0.0)
    for _ in range(3):
        q.admit("urgent", "u", cost=1, now=0.0)
    got = [tk.tenant for tk in q.acquire(4, now=0.0)]
    # urgent drains completely before bulk sees any budget
    assert got == ["urgent", "urgent", "urgent", "bulk"]
    # non-bypassing: an urgent head too big for the remaining budget blocks
    # lower classes from filling the gap (leftover budget is padding)
    q.admit("urgent", "u", cost=3, now=0.0)
    assert q.acquire(2, now=0.0) == []
    assert q.depth("bulk") == 4


def test_bounded_queue_backpressure():
    q = TenantQueue(capacity=2)
    q.register("t")
    q.admit("t", 1, now=0.0)
    q.admit("t", 2, now=0.0)
    with pytest.raises(QueueFull):
        q.admit("t", 3, now=0.0)
    assert q.stats()["t"]["rejected"] == 1
    assert q.depth("t") == 2
    # draining makes room again
    for tk in q.acquire(2, now=0.0):
        q.complete(tk, now=0.0)
    q.admit("t", 3, now=0.0)


# -- TenantQueue: deadlines / requeue / exactly-once ---------------------------


def test_timeout_requeues_at_head_with_fresh_deadline():
    q = TenantQueue(quantum=16.0)
    q.register("t")
    first = q.admit("t", "first", now=0.0)            # no deadline
    late = q.admit("t", "late", cost=1, timeout=5.0, now=0.0)
    assert q.expire(4.9) == []
    requeued = q.expire(5.1)
    assert requeued == [late]
    assert late.requeues == 1
    assert late.deadline == pytest.approx(10.1)       # fresh deadline
    assert q.stats()["t"]["timeouts"] == 1
    # head position: the blown deadline escalates past the earlier request
    got = q.acquire(1, now=5.1)
    assert got == [late]
    assert q.complete(late, now=5.2)
    assert first.status == SegStatus.PENDING


def test_exactly_once_delivery_with_requeued_twin():
    """A ticket completes at most once: late completions are suppressed and
    a still-queued requeued copy is withdrawn when its twin finishes."""
    q = TenantQueue(quantum=16.0)
    q.register("t")
    tk = q.admit("t", "p", now=0.0)
    (got,) = q.acquire(1, now=0.0)
    assert got is tk and tk.status == SegStatus.RUNNING
    # dispatcher thinks the batch is lost -> requeue; then the original
    # in-flight copy completes after all
    assert q.requeue(tk, now=1.0)
    assert tk.status == SegStatus.PENDING and q.depth("t") == 1
    assert q.complete(tk, now=2.0)                    # withdraws the copy
    assert q.depth("t") == 0
    assert q.acquire(1, now=2.0) == []
    assert not q.complete(tk, now=3.0)                # second completion: no
    assert q.stats()["t"]["delivered"] == 1
    # expired RUNNING tickets are left alone (the dispatcher owns them)
    tk2 = q.admit("t", "p2", timeout=1.0, now=10.0)
    q.acquire(1, now=10.0)
    assert q.expire(20.0) == []
    assert tk2.status == SegStatus.RUNNING


def test_max_requeues_abandons_ticket():
    q = TenantQueue(quantum=16.0, max_requeues=2)
    q.register("t")
    tk = q.admit("t", "p", timeout=1.0, now=0.0)
    assert q.expire(1.5) == [tk]        # requeue 1
    assert q.expire(3.0) == [tk]        # requeue 2
    assert q.expire(5.0) == []          # exhausted -> abandoned
    assert tk.status == SegStatus.DATA_ERROR
    assert q.depth("t") == 0
    st = q.stats()["t"]
    assert st["failed"] == 1 and st["timeouts"] == 3
    assert not q.complete(tk, now=6.0)  # a failed ticket cannot deliver


# -- StreamExecutor (1-device, in-process) -------------------------------------


def _wordcount_stream_df(num_buckets):
    import jax.numpy as jnp
    from repro.core.mapreduce import default_hash, reduce_by_key_sum
    from repro.sphere.dataflow import Dataflow

    def emit(rec):
        return {"key": rec["x"].astype(jnp.int32) % 7,
                "value": jnp.ones_like(rec["x"], jnp.int32)}

    def count(rec, valid):
        k, v, d = reduce_by_key_sum(rec["key"], rec["value"], valid)
        return {"key": k, "value": v}, k >= 0, d

    return (Dataflow.stream_source()
            .map(emit)
            .shuffle(by=lambda r: default_hash(r["key"], num_buckets),
                     num_buckets=num_buckets)
            .reduce(count))


def _make_stream_executor(micro_batch=16, **kw):
    import jax
    from repro.sphere.dataflow import SPMDExecutor
    from repro.sphere.streaming import StreamExecutor

    mesh = jax.make_mesh((1,), ("data",))
    inner = SPMDExecutor(mesh)
    return StreamExecutor(inner, _wordcount_stream_df(1),
                          micro_batch=micro_batch, **kw)


def test_stream_executor_carry_and_cache_counters():
    """Micro-batches of one fixed shape reuse ONE compiled program (misses
    stays 1, hits grows), and the carry snapshot tracks the running count."""
    ex = _make_stream_executor(carry_capacity=8, clock=lambda: 0.0)
    rng = np.random.default_rng(0)
    seen = []
    for step in range(5):
        x = rng.integers(0, 100, size=16 if step % 2 else 10)
        seen.append(x.astype(np.int32))
        ex.submit({"x": seen[-1]})      # short batches get padded
        batch = ex.step()
        assert len(batch.delivered) == 1 and batch.dropped == 0
        snap = ex.carry_state()
        got = {int(k): int(v) for k, v in zip(snap["key"], snap["value"])}
        want = collections.Counter(np.concatenate(seen).astype(int) % 7)
        assert got == dict(want), step
    info = ex.inner.cache_info()
    assert info.misses == 1 and info.hits == 4 and info.evictions == 0
    stats = ex.stats()
    assert stats["steps"] == 5
    assert stats["records_in"] == sum(len(x) for x in seen)
    assert stats["tenants"]["default"]["delivered"] == 5


def test_stream_executor_failed_batch_requeue_exactly_once():
    """A lost micro-batch (scheduled ``lose_batch`` fault) requeues its
    tickets; they are delivered on a later batch — exactly once — and the
    final aggregate is unaffected."""
    from repro.sphere.chaos import ChaosSchedule, FaultPlan

    ex = _make_stream_executor(
        carry_capacity=8, clock=lambda: 0.0,
        chaos=ChaosSchedule([FaultPlan(kind="lose_batch", at_batch=0)]))
    rng = np.random.default_rng(1)
    xs = [rng.integers(0, 50, size=16).astype(np.int32) for _ in range(3)]
    tickets = [ex.submit({"x": x}) for x in xs]
    lost = ex.step()
    assert ex.chaos.fired and len(ex.chaos.events) == 1
    assert lost.delivered == [] and len(lost.requeued) == 1
    assert lost.requeued[0].requeues == 1
    delivered = [tk for b in ex.drain() for tk in b.delivered]
    assert sorted(tk.req_id for tk in delivered) == \
        sorted(tk.req_id for tk in tickets)         # all once, none twice
    snap = ex.carry_state()
    got = {int(k): int(v) for k, v in zip(snap["key"], snap["value"])}
    want = collections.Counter(np.concatenate(xs).astype(int) % 7)
    assert got == dict(want)
    assert ex.stats()["batch_failures"] == 1


def test_stream_executor_rejects_bad_requests():
    ex = _make_stream_executor(carry_capacity=8)
    with pytest.raises(ValueError, match="micro-batch"):
        ex.submit({"x": np.zeros(17, np.int32)})     # larger than a batch
    ex.submit({"x": np.zeros(4, np.int32)})
    with pytest.raises(ValueError, match="schema"):
        ex.submit({"x": np.zeros(4, np.float32)})    # schema drift
    with pytest.raises(ValueError, match="stream_source"):
        from repro.sphere.dataflow import Dataflow
        _make_stream_executor().__class__(
            ex.inner, Dataflow.source().map(lambda r: r), micro_batch=16)


def test_stream_carry_requires_schema_preserving_reduce():
    import jax.numpy as jnp
    from repro.sphere.dataflow import Dataflow
    from repro.sphere.streaming import StreamExecutor

    def bad_reduce(rec, valid):       # changes the value dtype: not feedable
        return ({"key": rec["key"],
                 "value": rec["value"].astype(jnp.float32)},
                valid, jnp.zeros((), jnp.int32))

    df = (Dataflow.stream_source()
          .map(lambda r: {"key": r["x"].astype(jnp.int32),
                          "value": jnp.ones_like(r["x"], jnp.int32)})
          .shuffle(by=lambda r: r["key"] % 1, num_buckets=1)
          .reduce(bad_reduce))
    ex = _make_stream_executor(carry_capacity=4)
    ex2 = StreamExecutor(ex.inner, df, micro_batch=16, carry_capacity=4)
    ex2.submit({"x": np.zeros(8, np.int32)})
    with pytest.raises(ValueError, match="schema-preserving"):
        ex2.step()
    # a pipeline with no reduce cannot carry at all
    nodf = (Dataflow.stream_source()
            .map(lambda r: r)
            .shuffle(by=lambda r: r["x"] % 1, num_buckets=1))
    with pytest.raises(ValueError, match="reduce"):
        StreamExecutor(ex.inner, nodf, micro_batch=16, carry_capacity=4)


# -- stream/batch equivalence (8 devices, subprocess) --------------------------


def test_stream_vs_batch_equivalence_flat_and_hierarchical():
    """Acceptance: the SAME stream pipeline fed as K micro-batches (with
    carry) ends at a snapshot multiset-identical to the one-shot run of the
    concatenation — on a flat AND a hierarchical mesh, and equal to the
    HostExecutor (Sector/SPE) one-shot result too."""
    run_spmd("""
import collections, tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.core.mapreduce import default_hash, reduce_by_key_sum
from repro.core.records import RecordCodec
from repro.launch.train import make_sector
from repro.sphere.dataflow import Dataflow, HostExecutor, SPMDExecutor
from repro.sphere.spe import SPE
from repro.sphere.streaming import StreamExecutor

NB = 8
codec = RecordCodec.from_fields({"word": np.uint8})
def emit(rec):
    return {"key": rec["word"].astype(jnp.int32),
            "value": jnp.ones_like(rec["word"], jnp.int32)}
def count(rec, valid):
    k, v, dropped = reduce_by_key_sum(rec["key"], rec["value"], valid)
    return {"key": k, "value": v}, k >= 0, dropped
df = (Dataflow.stream_source(codec)
      .map(emit)
      .shuffle(by=lambda r: default_hash(r["key"], NB), num_buckets=NB)
      .reduce(count))

rng = np.random.default_rng(13)
K, MB = 7, 8 * 32
words = rng.integers(0, 26, size=K * MB, dtype=np.uint8)
want = dict(collections.Counter(words.astype(int).tolist()))

def snapshot_counts(snap):
    return {int(k): int(v) for k, v in zip(snap["key"], snap["value"])}

mesh1 = jax.make_mesh((8,), ("data",))
mesh2 = jax.make_mesh((2, 4), ("dc", "node"))
for mesh, axes in ((mesh1, ("data",)), (mesh2, ("dc", "node"))):
    ex = StreamExecutor(SPMDExecutor(mesh, axes=axes), df, micro_batch=MB,
                        carry_capacity=32)
    for i in range(K):
        ex.submit({"word": words[i * MB:(i + 1) * MB]})
        ex.step()
    assert snapshot_counts(ex.carry_state()) == want, axes
    assert ex.inner.cache_info().misses == 1, axes   # one compile for K

# one-shot SPMD over the concatenation: same multiset
with mesh1:
    res = SPMDExecutor(mesh1).run(df, {"word": jnp.asarray(words)})
rec = res.valid_records()
assert {int(k): int(v) for k, v in zip(rec["key"], rec["value"])} == want

# one-shot HostExecutor (Sector/SPE) over the same bytes: same multiset
root = tempfile.mkdtemp()
master, client, daemon = make_sector(root, num_slaves=4)
client.upload_dataset("/wc/in", [s.tobytes() for s in np.split(words, 4)])
daemon.run_until_stable()
spes = [SPE(i, master.slaves[i].address, master, client.session_id)
        for i in range(4)]
hres = HostExecutor(master, client, spes).run(
    df, [f"/wc/in.{i:05d}" for i in range(4)])
hrec = hres.valid_records()
assert {int(k): int(v) for k, v in zip(hrec["key"], hrec["value"])} == want
print("stream == batch across executors:", len(want), "keys")
""")


def test_stream_mid_batch_device_loss_elastic_recovery():
    """Acceptance: a stream surviving ``lose_device`` at batch 1 shrinks the
    mesh 8 -> 4, remeshes the carry from the boundary StreamCheckpoint with
    exactly ONE recompile, requeues the in-flight ticket through the
    TenantQueue (exactly once — requeued once, delivered once), and ends at
    a snapshot multiset-identical to the fault-free one-shot run."""
    run_spmd("""
import collections
import jax, jax.numpy as jnp, numpy as np
from repro.core.mapreduce import default_hash, reduce_by_key_sum
from repro.sphere.chaos import ChaosSchedule, FaultPlan
from repro.sphere.dataflow import Dataflow, SPMDExecutor
from repro.sphere.streaming import StreamExecutor, TenantQueue

NB = 8
def emit(rec):
    return {"key": rec["word"].astype(jnp.int32),
            "value": jnp.ones_like(rec["word"], jnp.int32)}
def count(rec, valid):
    k, v, dropped = reduce_by_key_sum(rec["key"], rec["value"], valid)
    return {"key": k, "value": v}, k >= 0, dropped
df = (Dataflow.stream_source()
      .map(emit)
      .shuffle(by=lambda r: default_hash(r["key"], NB), num_buckets=NB)
      .reduce(count))

mesh = jax.make_mesh((8,), ("data",))
MB = 8 * 32
sched = ChaosSchedule([FaultPlan(kind="lose_device", at_batch=1)], seed=5)
queue = TenantQueue(quantum=float(MB))
vclock = {"now": 0.0}
ex = StreamExecutor(SPMDExecutor(mesh), df, micro_batch=MB,
                    carry_capacity=32, queue=queue,
                    clock=lambda: vclock["now"], chaos=sched)
rng = np.random.default_rng(21)
words = rng.integers(0, 26, size=5 * MB, dtype=np.uint8)
tickets = [ex.submit({"word": words[i*MB:(i+1)*MB]}) for i in range(5)]

results = []
step = 0
while queue.pending():
    vclock["now"] = float(step)
    b = ex.step()
    if b is not None:
        results.append(b)
    step += 1

# batch 1 was abandoned: its ticket requeued once, then delivered once
lost = [b for b in results if not b.delivered]
assert len(lost) == 1 and len(lost[0].requeued) == 1
victim = lost[0].requeued[0]
assert victim.requeues == 1 and victim.attempts == 2
delivered = [tk for b in results for tk in b.delivered]
assert sorted(tk.req_id for tk in delivered) == \\
    sorted(t.req_id for t in tickets)               # all once, none twice

# mesh shrank 8 -> 4 with one recovery and exactly one extra recompile
st = ex.stats()
assert ex.inner.axis_size == 4
assert st["recoveries"] == 1
assert st["cache"]["misses"] == 2, st["cache"]
assert sched.fired and len(sched.events) == 2       # fault + resume audit

# exactly-once end to end: snapshot == one-shot over everything submitted
want = dict(collections.Counter(words.astype(int).tolist()))
snap = ex.carry_state()
assert {int(k): int(v) for k, v in zip(snap["key"], snap["value"])} == want

# the requeued ticket's latency spans the full wait + recovery (admitted
# at t=0, head-requeued at the loss, delivered on the post-recovery batch)
assert victim.completed_at == 2.0
assert queue.stats()["default"]["latency_p99"] >= 2.0
print("mid-stream device loss: recovered on", ex.inner.axis_size,
      "devices, snapshot equal to fault-free run")
""")


def test_streamed_sort_batches_are_sorted_and_lossless():
    """A carry-less stream pipeline (sort) treats every micro-batch as an
    independent slice of the output stream: each batch is globally sorted
    and the union of batches is the multiset of everything submitted."""
    run_spmd("""
import jax, jax.numpy as jnp, numpy as np
from repro.sphere.dataflow import Dataflow, SPMDExecutor
from repro.sphere.streaming import StreamExecutor

mesh = jax.make_mesh((8,), ("data",))
df = Dataflow.stream_source().sort(key=lambda r: r["key"], num_buckets=8,
                                   capacity_factor=3.0)
MB = 8 * 64
ex = StreamExecutor(SPMDExecutor(mesh), df, micro_batch=MB)
rng = np.random.default_rng(4)
seen = []
for i in range(5):
    keys = rng.integers(0, 2**31 - 2, size=MB).astype(np.int32)
    seen.append(keys)
    ex.submit({"key": keys, "payload": np.arange(MB, dtype=np.int32)})
    b = ex.step()
    assert b.dropped == 0
    out = b.valid_records()
    assert out["key"].shape == (MB,)
    assert (np.diff(out["key"]) >= 0).all(), i
    assert (np.sort(out["key"]) == np.sort(keys)).all(), i
assert ex.inner.cache_info().misses == 1
print("streamed sort ok")
""")


def test_streaming_soak_acceptance():
    """Run the real soak harness end-to-end and apply its acceptance gates:
    >=3 tenants over >=20 micro-batches on one compiled pipeline, fair share
    within 10% of weights, timed-out request requeued then delivered exactly
    once, stream == batch."""
    run_spmd(f"""
import sys
sys.path.insert(0, {BENCH!r})
import streaming_bench
res = streaming_bench.soak(steps=22)
failures = streaming_bench.check(res)
assert not failures, failures
print("soak acceptance ok:", res["steps"], "batches,",
      res["cache"]["misses"], "compile")
""")
