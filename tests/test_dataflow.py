"""Unified dataflow API tests: RecordCodec round-trips, cross-executor
equivalence (the same pipeline on SPMD and Sector/SPE), and the satellite
regressions (empty-bucket dtype, reduce truncation reporting, non-int32
map_reduce)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from test_spmd import run_spmd

from repro.core.records import RecordCodec


# -- RecordCodec ---------------------------------------------------------------


DTYPES = ["int32", "uint8", "int16", "float32", "bool", "int8", "uint32"]


def _example(rng, dtype, n, shape):
    dt = np.dtype(dtype)
    if dt == np.bool_:
        return rng.random((n,) + shape) > 0.5
    if dt.kind == "f":
        return rng.random((n,) + shape).astype(dt)
    info = np.iinfo(dt)
    return rng.integers(info.min, int(info.max) + 1,
                        size=(n,) + shape).astype(dt)


@pytest.mark.parametrize("dtype", DTYPES)
def test_codec_roundtrip_single_field(dtype):
    rng = np.random.default_rng(0)
    rec = {"x": _example(rng, dtype, 9, (2,))}
    codec = RecordCodec.from_example(rec)
    packed = codec.pack(rec)
    encoded = codec.encode(rec)
    # jax and numpy paths must be byte-identical (host<->SPMD interop)
    np.testing.assert_array_equal(np.asarray(packed), encoded)
    for out in (codec.unpack(packed), codec.decode(encoded)):
        got = out["x"]
        assert np.asarray(got).dtype == rec["x"].dtype
        np.testing.assert_array_equal(np.asarray(got), rec["x"])


def test_codec_mixed_pytree_and_layout():
    rng = np.random.default_rng(1)
    rec = {"word": np.arange(5, dtype=np.uint8),
           "vec": rng.random((5, 3)).astype(np.float32),
           "ok": np.array([1, 0, 1, 1, 0], bool)}
    # insertion order = byte layout, even though dicts flatten sorted
    codec = RecordCodec.from_fields(
        {"word": np.uint8, "vec": (np.float32, (3,)), "ok": np.bool_})
    assert codec.nbytes == 1 + 12 + 1
    enc = codec.encode(rec)
    assert enc.shape == (5, 14)
    assert (enc[:, 0] == rec["word"]).all()          # word is byte 0
    np.testing.assert_array_equal(np.asarray(codec.pack(rec)), enc)
    dec = codec.decode(enc.tobytes())
    for k in rec:
        np.testing.assert_array_equal(dec[k], rec[k])
    # multi-leading-dim unpack (shuffle receive layout)
    import jax.numpy as jnp
    u = codec.unpack(jnp.asarray(enc).reshape(1, 5, 14))
    assert np.asarray(u["vec"]).shape == (1, 5, 3)


def test_codec_float64_numpy_lossless():
    rng = np.random.default_rng(2)
    codec = RecordCodec.from_fields({"key": np.int64, "value": np.float64})
    rec = {"key": rng.integers(0, 1 << 40, 6),
           "value": rng.random(6)}
    out = codec.decode(codec.encode(rec))
    assert out["value"].dtype == np.float64
    np.testing.assert_array_equal(out["value"], rec["value"])  # bit-exact
    np.testing.assert_array_equal(out["key"], rec["key"])


def test_codec_zero_records():
    """Empty segments/buckets are legal: pack/encode of n=0 must produce
    (0, nbytes) rows, and the round-trip must hold."""
    import jax.numpy as jnp
    codec = RecordCodec.from_fields({"k": np.int32, "v": (np.float32, (2,))})
    rec = {"k": np.zeros(0, np.int32), "v": np.zeros((0, 2), np.float32)}
    enc = codec.encode(rec)
    assert enc.shape == (0, codec.nbytes)
    packed = codec.pack(rec)
    assert packed.shape == (0, codec.nbytes)
    out = codec.decode(enc)
    assert out["v"].shape == (0, 2)
    out = codec.unpack(jnp.asarray(enc))
    assert np.asarray(out["k"]).shape == (0,)


def test_codec_64bit_requires_x64_on_jax_path():
    """With jax_enable_x64 off (the default), the jax pack/unpack of a
    64-bit codec must fail loudly instead of silently truncating; the numpy
    path stays fully functional."""
    import jax
    codec = RecordCodec.from_fields({"v": np.float64})
    rec = {"v": np.random.default_rng(0).random(4)}
    if jax.config.jax_enable_x64:
        pytest.skip("x64 enabled in this environment")
    with pytest.raises(RuntimeError, match="x64"):
        codec.pack(rec)
    with pytest.raises(RuntimeError, match="x64"):
        codec.unpack(np.zeros((4, codec.nbytes), np.uint8))
    out = codec.decode(codec.encode(rec))           # numpy path unaffected
    np.testing.assert_array_equal(out["v"], rec["v"])


def test_codec_rejects_schema_mismatch():
    codec = RecordCodec.from_fields({"a": np.int32})
    with pytest.raises(ValueError):
        codec.pack({"a": np.zeros(3, np.float32)})
    with pytest.raises(ValueError):
        codec.unpack(np.zeros((3, codec.nbytes + 1), np.uint8))


@settings(max_examples=25, deadline=None)
@given(
    dtypes=st.lists(st.sampled_from(DTYPES), min_size=1, max_size=4),
    n=st.integers(min_value=0, max_value=17),
    trailing=st.integers(min_value=0, max_value=3),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_codec_roundtrip_property(dtypes, n, trailing, seed):
    """pack/unpack and encode/decode are exact inverses over mixed dtypes,
    and the two packings agree byte-for-byte."""
    rng = np.random.default_rng(seed)
    shape = (trailing,) if trailing else ()
    rec = {f"f{i}": _example(rng, dt, n, shape)
           for i, dt in enumerate(dtypes)}
    codec = RecordCodec.from_example(rec)
    packed, encoded = codec.pack(rec), codec.encode(rec)
    np.testing.assert_array_equal(np.asarray(packed), encoded)
    unpacked, decoded = codec.unpack(packed), codec.decode(encoded)
    for k in rec:
        np.testing.assert_array_equal(np.asarray(unpacked[k]), rec[k])
        np.testing.assert_array_equal(decoded[k], rec[k])
        assert decoded[k].dtype == rec[k].dtype


# -- reduce_by_key_sum truncation accounting -----------------------------------


def test_reduce_by_key_sum_reports_drops():
    from repro.core.mapreduce import reduce_by_key_sum
    keys = np.array([5, 1, 5, 2, 3, 4, 1, 9], np.int32)
    values = np.ones_like(keys)
    valid = np.ones(len(keys), bool)
    out_k, out_v, dropped = reduce_by_key_sum(keys, values, valid,
                                              max_unique=3)
    # 6 distinct keys, room for 3 -> 3 dropped, and it is REPORTED
    assert int(dropped) == 3
    kept = np.asarray(out_k)
    assert (kept >= 0).sum() == 3
    # no truncation -> zero drops, sums correct
    out_k, out_v, dropped = reduce_by_key_sum(keys, values, valid)
    assert int(dropped) == 0
    got = {int(k): int(v) for k, v in zip(out_k, out_v) if k >= 0}
    assert got == {1: 2, 2: 1, 3: 1, 4: 1, 5: 2, 9: 1}


# -- SphereProcess bucket regression -------------------------------------------


def test_engine_empty_bucket_keeps_dtype_and_shape(tmp_path):
    from repro.launch.train import make_sector
    from repro.sphere.engine import SphereProcess
    from repro.sphere.spe import SPE

    master, client, daemon = make_sector(str(tmp_path), num_slaves=3)
    rec = np.arange(24, dtype=np.uint8).reshape(12, 2)
    client.upload_dataset("/data/x", [rec.tobytes()])
    daemon.run_until_stable()
    spes = [SPE(i, master.slaves[i].address, master, client.session_id)
            for i in range(3)]
    proc = SphereProcess(master, client.session_id, spes)
    # bucket_fn routes EVERYTHING to bucket 0 and mentions no other bucket,
    # so buckets 1..3 stay empty
    res = proc.run(["/data/x.00000"], lambda r: r.reshape(-1, 2),
                   record_bytes=2, bucket_fn=lambda out: {0: out},
                   num_buckets=4)
    assert res.outputs[0].shape == (12, 2)
    for b in (1, 2, 3):
        empty = res.outputs[b]
        assert empty.shape == (0, 2), "empty bucket lost trailing dims"
        assert empty.dtype == np.uint8, "empty bucket lost dtype"


# -- cross-executor equivalence (SPMD vs Sector/SPE) ---------------------------


def test_cross_executor_inverted_index_equivalence():
    """The acceptance check: ONE Dataflow object, two executors, identical
    key -> count multiset (and both equal the ground-truth Counter)."""
    run_spmd("""
import collections, tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.core.mapreduce import default_hash, reduce_by_key_sum
from repro.core.records import RecordCodec
from repro.launch.train import make_sector
from repro.sphere.dataflow import Dataflow, HostExecutor, SPMDExecutor
from repro.sphere.spe import SPE

NB = 8
codec = RecordCodec.from_fields({"word": np.uint8, "page": np.uint8})
def emit(rec):
    return {"key": rec["word"].astype(jnp.int32),
            "value": jnp.ones_like(rec["word"], jnp.int32)}
def count(rec, valid):
    k, v, dropped = reduce_by_key_sum(rec["key"], rec["value"], valid)
    return {"key": k, "value": v}, k >= 0, dropped
df = (Dataflow.source(codec)
      .map(emit)
      .shuffle(by=lambda r: default_hash(r["key"], NB), num_buckets=NB)
      .reduce(count))

rng = np.random.default_rng(7)
pages = []
for i in range(4):
    p = rng.integers(0, 26, size=(40, 2), dtype=np.uint8)
    p[:, 1] = i
    pages.append(p)
allpages = np.concatenate(pages)
want = dict(collections.Counter(allpages[:, 0].tolist()))

def counts(res):
    rec = res.valid_records()
    return {int(k): int(v) for k, v in zip(rec["key"], rec["value"])}

# host executor: one SPE crashes mid-run; retry must absorb it
root = tempfile.mkdtemp()
master, client, daemon = make_sector(root, num_slaves=4)
client.upload_dataset("/web/page", [p.tobytes() for p in pages])
daemon.run_until_stable()
spes = [SPE(i, master.slaves[i].address, master, client.session_id,
            fail_after=0 if i == 0 else None) for i in range(4)]
host_res = HostExecutor(master, client, spes).run(
    df, [f"/web/page.{i:05d}" for i in range(4)])
assert not host_res.errors, host_res.errors
assert host_res.retries >= 1   # the crash was absorbed, not ignored

# SPMD executor: same pipeline object
mesh = jax.make_mesh((8,), ("data",))
spmd = SPMDExecutor(mesh)
with mesh:
    spmd_res = spmd.run(df, {"word": jnp.asarray(allpages[:, 0]),
                             "page": jnp.asarray(allpages[:, 1])})
assert int(spmd_res.dropped) == 0

hc, sc = counts(host_res), counts(spmd_res)
assert hc == want, (hc, want)
assert sc == want, (sc, want)
print("cross-executor multiset equal:", len(hc), "keys")
""")


def test_cross_executor_sort_equivalence():
    """Dataflow.sort: SPMD terasort and host bucket-file sort produce the
    same globally sorted key sequence."""
    run_spmd("""
import tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.core.records import RecordCodec
from repro.launch.train import make_sector
from repro.sphere.dataflow import Dataflow, HostExecutor, SPMDExecutor
from repro.sphere.spe import SPE

N = 8 * 256
rng = np.random.default_rng(3)
keys = rng.integers(0, 2**31 - 2, size=N).astype(np.int32)
payload = np.arange(N, dtype=np.int32)
codec = RecordCodec.from_fields({"key": np.int32, "payload": np.int32})
df = Dataflow.source(codec).sort(key=lambda r: r["key"], num_buckets=8)

mesh = jax.make_mesh((8,), ("data",))
with mesh:
    sres = SPMDExecutor(mesh, use_pallas=True).run(
        df, {"key": jnp.asarray(keys), "payload": jnp.asarray(payload)})
svr = sres.valid_records()
assert int(sres.dropped) == 0
assert (np.diff(svr["key"]) >= 0).all()
assert (keys[svr["payload"]] == svr["key"]).all()

root = tempfile.mkdtemp()
master, client, daemon = make_sector(root, num_slaves=4)
slices = np.split(codec.encode({"key": keys, "payload": payload}), 4)
client.upload_dataset("/ts/in", [s.tobytes() for s in slices])
daemon.run_until_stable()
spes = [SPE(i, master.slaves[i].address, master, client.session_id)
        for i in range(4)]
hres = HostExecutor(master, client, spes).run(
    df, [f"/ts/in.{i:05d}" for i in range(4)])
hvr = hres.valid_records()
assert (np.diff(hvr["key"]) >= 0).all()
np.testing.assert_array_equal(hvr["key"], svr["key"])
print("sort equivalence ok")
""")


def test_map_reduce_float64_values_lossless():
    """Acceptance: a non-int32 (float64-value) map_reduce round-trips
    losslessly through the codec-backed shuffle (the old entry point cast
    everything to int32)."""
    run_spmd("""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp, numpy as np
from repro.core.mapreduce import map_reduce, reduce_by_key_sum

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
N = 8 * 128
weights = rng.random(N)                      # float64 record stream
data = jnp.asarray(weights)
assert data.dtype == jnp.float64
# the map UDF derives an int32 key from each float64 value and emits the
# value untouched; the shuffle must carry it at full precision
with mesh:
    k, v, valid, dropped = map_reduce(
        lambda seg: ((seg * 40).astype(jnp.int32), seg),
        reduce_by_key_sum, data, mesh)
k, v, valid = np.asarray(k), np.asarray(v), np.asarray(valid)
assert v.dtype == np.float64, v.dtype
assert int(dropped) == 0
got = {int(a): b for a, b, ok in zip(k, v, valid) if ok and a >= 0}
want = {}
for x in weights:
    want.setdefault(int(x * 40), []).append(x)
assert set(got) == set(want)
for key in want:
    assert abs(got[key] - sum(sorted(want[key]))) < 1e-9, key
print("float64 map_reduce lossless:", len(got), "keys")
""")


def test_cross_executor_equivalence_chunked_and_hierarchical():
    """Satellite acceptance: the inverted-index and sort pipelines produce
    identical results on HostExecutor vs SPMDExecutor with chunks ∈ {1, 4}
    and flat vs hierarchical plans (all on the fused one-wire-tensor
    framing)."""
    run_spmd("""
import collections, tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.core.mapreduce import default_hash, reduce_by_key_sum
from repro.core.records import RecordCodec
from repro.launch.train import make_sector
from repro.sphere.dataflow import Dataflow, HostExecutor, SPMDExecutor
from repro.sphere.spe import SPE

NB = 8
N = 8 * 128
rng = np.random.default_rng(11)

# -- inverted index ----------------------------------------------------------
codec = RecordCodec.from_fields({"word": np.uint8, "page": np.uint8})
def emit(rec):
    return {"key": rec["word"].astype(jnp.int32),
            "value": jnp.ones_like(rec["word"], jnp.int32)}
def count(rec, valid):
    k, v, dropped = reduce_by_key_sum(rec["key"], rec["value"], valid)
    return {"key": k, "value": v}, k >= 0, dropped
ii = (Dataflow.source(codec)
      .map(emit)
      .shuffle(by=lambda r: default_hash(r["key"], NB), num_buckets=NB)
      .reduce(count))
pages = rng.integers(0, 26, size=(N, 2), dtype=np.uint8)
want = dict(collections.Counter(pages[:, 0].tolist()))

def counts(res):
    rec = res.valid_records()
    return {int(k): int(v) for k, v in zip(rec["key"], rec["value"])}

root = tempfile.mkdtemp()
master, client, daemon = make_sector(root, num_slaves=4)
client.upload_dataset("/ii/page", [p.tobytes() for p in np.split(pages, 4)])
daemon.run_until_stable()
spes = [SPE(i, master.slaves[i].address, master, client.session_id)
        for i in range(4)]
host = counts(HostExecutor(master, client, spes).run(
    ii, [f"/ii/page.{i:05d}" for i in range(4)]))
assert host == want

mesh1 = jax.make_mesh((8,), ("data",))
mesh2 = jax.make_mesh((2, 4), ("dc", "node"))
src = {"word": jnp.asarray(pages[:, 0]), "page": jnp.asarray(pages[:, 1])}
for mesh, axes in ((mesh1, ("data",)), (mesh2, ("dc", "node"))):
    for w in (1, 4):
        ex = SPMDExecutor(mesh, axes=axes, chunks=w)
        with mesh:
            res = ex.run(ii, src)
        assert int(res.dropped) == 0, (axes, w)
        assert counts(res) == want, (axes, w)

# -- sort --------------------------------------------------------------------
keys = rng.integers(0, 2**31 - 2, size=N).astype(np.int32)
payload = np.arange(N, dtype=np.int32)
scodec = RecordCodec.from_fields({"key": np.int32, "payload": np.int32})
# capacity_factor covers per-CHUNK skew at W=4 (capacity splits W ways, so
# each chunk's bins see W x the relative variance)
sdf = Dataflow.source(scodec).sort(key=lambda r: r["key"], num_buckets=8,
                                   capacity_factor=3.0)

slices = np.split(scodec.encode({"key": keys, "payload": payload}), 4)
client.upload_dataset("/ts/in", [s.tobytes() for s in slices])
daemon.run_until_stable()
hres = HostExecutor(master, client, spes).run(
    sdf, [f"/ts/in.{i:05d}" for i in range(4)])
hkeys = hres.valid_records()["key"]
assert (np.diff(hkeys) >= 0).all()

for mesh, axes in ((mesh1, ("data",)), (mesh2, ("dc", "node"))):
    for w in (1, 4):
        ex = SPMDExecutor(mesh, axes=axes, chunks=w)
        with mesh:
            sres = ex.run(sdf, {"key": jnp.asarray(keys),
                                "payload": jnp.asarray(payload)})
        svr = sres.valid_records()
        assert int(sres.dropped) == 0, (axes, w)
        np.testing.assert_array_equal(svr["key"], hkeys, err_msg=str((axes, w)))
        assert (keys[svr["payload"]] == svr["key"]).all(), (axes, w)
print("cross-executor chunked/hier equivalence ok")
""")


def test_spmd_executor_cache_eviction():
    """Satellite: the compile cache is a bounded LRU — it cannot grow past
    cache_size, evicts least-recently-used first, and an evicted pipeline
    retraces on its next run."""
    run_spmd("""
import jax, jax.numpy as jnp, numpy as np
from repro.sphere.dataflow import Dataflow, SPMDExecutor

mesh = jax.make_mesh((8,), ("data",))
data = {"key": jnp.arange(8 * 32, dtype=jnp.int32)}
trace_count = [0]

def make_df():
    def bump(rec):
        trace_count[0] += 1
        return rec
    return Dataflow.source().map(bump).shuffle(by=lambda r: r["key"] % 8,
                                               num_buckets=8)

ex = SPMDExecutor(mesh, cache_size=2)
df1, df2, df3 = make_df(), make_df(), make_df()
with mesh:
    ex.run(df1, data)
    ex.run(df2, data)
    assert len(ex._cache) == 2
    ex.run(df1, data)          # refresh df1 -> df2 becomes LRU
    n = trace_count[0]
    ex.run(df3, data)          # evicts df2
    assert len(ex._cache) == 2
    cached = [e[0] for e in ex._cache.values()]
    assert df1 in cached and df3 in cached and df2 not in cached
    ex.run(df1, data)          # still cached: no retrace
    assert trace_count[0] == n + 1   # only df3's trace happened
    ex.run(df2, data)          # evicted: must retrace
    assert trace_count[0] == n + 2
    assert len(ex._cache) == 2
# the cache_info counters must tell the same story as the trace counts:
# 4 lowers (df1, df2, df3, df2-again), 2 hits (both df1 reruns), and the
# two evictions (df2 then df3) are counted, not silent
info = ex.cache_info()
assert info.misses == 4, info
assert info.hits == 2, info
assert info.evictions == 2, info
assert info.currsize == 2 and info.maxsize == 2, info
print("lru eviction ok")
""")


def test_sort_key_max_sentinel_guard():
    """Satellite: a real key equal to the key dtype's maximum collides with
    the stage-2 padding sentinel. Under the unstable bitonic kernel the
    executor's debug guard raises; under any *stable* sort (the default
    autotuned path resolves to one here, and sort_algo='radix'/'oracle'
    pin one) padding stays behind real keys, so the record is delivered
    correctly and the guard never fires — the regression this test pins."""
    run_spmd("""
import jax, jax.numpy as jnp, numpy as np
from repro.sphere.dataflow import Dataflow, SPMDExecutor

mesh = jax.make_mesh((8,), ("data",))
N = 8 * 64
rng = np.random.default_rng(5)
keys = rng.integers(0, np.iinfo(np.int32).max, size=N).astype(np.int32)
keys[7] = np.iinfo(np.int32).max          # collides with the sort sentinel
payload = np.arange(N, dtype=np.int32)
df = Dataflow.source().sort(key=lambda r: r["key"], num_buckets=8)
src = {"key": jnp.asarray(keys), "payload": jnp.asarray(payload)}

# unstable bitonic: the guard must still catch the collision
strict = SPMDExecutor(mesh, sort_algo="bitonic")
try:
    with mesh:
        strict.run(df, src)
    raise AssertionError("sentinel collision was not detected")
except ValueError as e:
    assert "bitonic" in str(e) and "sentinel" in str(e), e
print("guard raised ok")

# clean keys pass the guard (no false positive)
keys2 = keys.copy(); keys2[7] = 0
with mesh:
    strict.run(df, {"key": jnp.asarray(keys2),
                    "payload": jnp.asarray(payload)})

# stable sorts deliver the max-value key instead of raising: the record
# is present in the output with its payload, in its sorted position
for algo in (None, "radix", "oracle"):     # None -> autotuned (stable here)
    ex = SPMDExecutor(mesh, sort_algo=algo)
    with mesh:
        res = ex.run(df, src)
    out_k = np.asarray(res.records["key"])[np.asarray(res.valid)]
    out_p = np.asarray(res.records["payload"])[np.asarray(res.valid)]
    assert out_k.size == N and int(res.dropped) == 0, algo
    assert out_k[-1] == np.iinfo(np.int32).max, (algo, out_k[-8:])
    assert out_p[out_k == np.iinfo(np.int32).max][0] == 7, algo
print("stable delivery ok")

# opting out restores the old silent behaviour for bitonic too
loose = SPMDExecutor(mesh, sort_algo="bitonic", debug_checks=False)
with mesh:
    loose.run(df, src)    # no raise
print("sentinel guard ok")
""")


def test_spmd_executor_compile_cache():
    """Re-running the same pipeline object on same-shaped data must hit the
    executor's compile cache (one entry, one trace)."""
    run_spmd("""
import jax, jax.numpy as jnp, numpy as np
from repro.sphere.dataflow import Dataflow, SPMDExecutor

trace_count = [0]
def emit(rec):
    trace_count[0] += 1
    return {"key": rec["key"] % 16, "value": rec["value"]}
df = (Dataflow.source().map(emit)
      .shuffle(by=lambda r: r["key"] % 8, num_buckets=8))
mesh = jax.make_mesh((8,), ("data",))
ex = SPMDExecutor(mesh)
data = {"key": jnp.arange(8 * 32, dtype=jnp.int32),
        "value": jnp.ones(8 * 32, jnp.float32)}
with mesh:
    r1 = ex.run(df, data)
    n_after_first = trace_count[0]
    r2 = ex.run(df, data)
assert len(ex._cache) == 1
assert trace_count[0] == n_after_first, "second run retraced"
vr1, vr2 = r1.valid_records(), r2.valid_records()
np.testing.assert_array_equal(vr1["value"], vr2["value"])
# float32 values survived the byte shuffle
assert vr1["value"].dtype == np.float32
print("cache ok")
""")
