"""Pallas kernel sweeps vs the pure-jnp oracles (interpret=True on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("n", [1, 7, 128, 1000, 4096, 10_000])
@pytest.mark.parametrize("buckets", [1, 4, 17, 128, 513])
def test_bucket_histogram_shapes(n, buckets):
    ids = RNG.integers(0, buckets, size=n).astype(np.int32)
    got = ops.bucket_histogram(jnp.asarray(ids), buckets)
    want = ref.bucket_histogram_ref(jnp.asarray(ids), buckets)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(got.sum()) == n


def test_bucket_histogram_ignores_out_of_range():
    ids = np.array([-1, 0, 1, 5, 99], np.int32)
    got = ops.bucket_histogram(jnp.asarray(ids), 4)
    np.testing.assert_array_equal(np.asarray(got), [1, 1, 0, 0])


def test_bucket_histogram_exact_past_2_24():
    """Regression: the kernel used to accumulate counts in float32, which
    cannot represent 2^24 + 4 — every +1 past 16.7M records was silently
    rounded away. The int32 accumulator must be exact."""
    from repro.kernels.bucket_hist import bucket_histogram_pallas
    n = (1 << 24) + 9
    ids = np.zeros(n, np.int32)
    ids[:5] = 1
    got = bucket_histogram_pallas(jnp.asarray(ids), 4, tile=1 << 18,
                                  interpret=True)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), [n - 5, 5, 0, 0])


@pytest.mark.parametrize("rows,cols", [(1, 2), (3, 9), (2, 128), (1, 1000),
                                       (4, 257), (17, 33), (9, 8)])
@pytest.mark.parametrize("dtype", [np.int32, np.float32])
def test_sort_segments_sweep(rows, cols, dtype):
    if dtype == np.int32:
        keys = RNG.integers(0, 1 << 30, size=(rows, cols)).astype(dtype)
    else:
        keys = RNG.standard_normal((rows, cols)).astype(dtype)
    got = ops.sort_segments(jnp.asarray(keys))
    want = ref.sort_segments_ref(jnp.asarray(keys))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("rows,cols", [(1, 16), (3, 100), (2, 512)])
def test_sort_kv_segments_sweep(rows, cols):
    keys = RNG.integers(0, 1 << 20, size=(rows, cols)).astype(np.int32)
    vals = np.arange(rows * cols, dtype=np.int32).reshape(rows, cols)
    gk, gv = ops.sort_kv_segments(jnp.asarray(keys), jnp.asarray(vals))
    rk, rv = ref.sort_kv_segments_ref(jnp.asarray(keys), jnp.asarray(vals))
    np.testing.assert_array_equal(np.asarray(gk), np.asarray(rk))
    # (key, value) multiset preserved per row (bitonic is not stable)
    for r in range(rows):
        got_pairs = sorted(zip(np.asarray(gk)[r], np.asarray(gv)[r]))
        want_pairs = sorted(zip(keys[r], vals[r]))
        assert got_pairs == want_pairs


def test_sort_duplicate_keys():
    keys = np.array([[5, 5, 5, 1, 1, 9, 0, 5]], np.int32)
    got = ops.sort_segments(jnp.asarray(keys))
    np.testing.assert_array_equal(np.asarray(got)[0],
                                  np.sort(keys[0]))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 2**30 - 1), min_size=1, max_size=300))
def test_property_bitonic_sorts_and_preserves(xs):
    keys = np.asarray(xs, np.int32)[None, :]
    got = np.asarray(ops.sort_segments(jnp.asarray(keys)))[0]
    assert list(got) == sorted(xs)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 63), min_size=1, max_size=500),
       st.integers(1, 64))
def test_property_histogram_counts(ids, buckets):
    arr = np.asarray(ids, np.int32)
    got = np.asarray(ops.bucket_histogram(jnp.asarray(arr), buckets))
    import collections
    want = collections.Counter(i for i in ids if i < buckets)
    for b in range(buckets):
        assert got[b] == want.get(b, 0)
