"""Property suite for the stable radix-sort kernel and the kernel autotuner.

The radix kernel's contract is *exact* equality with the stable oracle
(``ref.sort_kv_segments_ref`` — stable argsort + gather): same keys AND same
payload permutation, including within runs of duplicate keys. The bitonic
kernel is only held to key equality (it is not stable). The autotuner's
contract is measure-once-replay-forever plus the ``REPRO_KERNEL_FORCE``
override winning over everything.
"""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import autotune, ops, ref
from repro.kernels.radix_sort import (default_bits, key_to_sortable_bits,
                                      radix_supported, sort_kv_segments_radix,
                                      sort_segments_radix,
                                      sortable_bits_to_key)

from test_spmd import run_spmd


@pytest.fixture(autouse=True)
def _fresh_autotuner():
    """Each test sees an empty autotune cache and no force env."""
    autotune.reset()
    saved = os.environ.pop(autotune.FORCE_ENV, None)
    yield
    autotune.reset()
    if saved is not None:
        os.environ[autotune.FORCE_ENV] = saved


def _keys(rng, shape, dtype):
    if dtype == np.float32:
        k = rng.standard_normal(shape).astype(np.float32)
        k[k == 0.0] = 1.0     # avoid -0.0/+0.0 ties (bit order refines them)
        return k
    if dtype == np.uint32:
        return rng.integers(0, 1 << 32, size=shape,
                            dtype=np.uint64).astype(np.uint32)
    return rng.integers(-2**31, 2**31 - 1, size=shape,
                        dtype=np.int64).astype(np.int32)


def _assert_matches_oracle(k, v):
    want_k, want_v = ref.sort_kv_segments_ref(k, v)
    got_k, got_v = sort_kv_segments_radix(k, v)
    np.testing.assert_array_equal(np.asarray(got_k), np.asarray(want_k))
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))


# -- kernel vs oracle --------------------------------------------------------


@pytest.mark.parametrize("dtype", [np.int32, np.uint32, np.float32])
def test_radix_matches_stable_oracle(dtype):
    rng = np.random.default_rng(0)
    k = jnp.asarray(_keys(rng, (7, 320), dtype))
    v = jnp.arange(7 * 320, dtype=jnp.int32).reshape(7, 320)
    _assert_matches_oracle(k, v)
    np.testing.assert_array_equal(np.asarray(sort_segments_radix(k)),
                                  np.asarray(ref.sort_segments_ref(k)))


def test_radix_duplicate_keys_are_stable():
    """Payloads of equal keys keep arrival order — exactly the stable
    argsort permutation, for every digit width."""
    rng = np.random.default_rng(1)
    k = jnp.asarray(rng.integers(0, 6, size=(4, 256)).astype(np.int32))
    v = jnp.arange(4 * 256, dtype=jnp.int32).reshape(4, 256)
    want_k, want_v = ref.sort_kv_segments_ref(k, v)
    for bits in (1, 2, 4, 8):
        got_k, got_v = sort_kv_segments_radix(k, v, bits=bits)
        np.testing.assert_array_equal(np.asarray(got_k), np.asarray(want_k))
        np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))


def test_radix_max_key_survives_padding():
    """Keys equal to the dtype max (= the padding value) stay real: padding
    is appended *after* them and the sort is stable, so they come back in
    their slots — the collision the unstable bitonic kernel can't rule out."""
    big = np.iinfo(np.int32).max
    k = jnp.asarray([[big, 3, big, 7, big]], dtype=jnp.int32)
    v = jnp.asarray([[0, 1, 2, 3, 4]], dtype=jnp.int32)
    got_k, got_v = sort_kv_segments_radix(k, v)
    assert got_k.tolist() == [[3, 7, big, big, big]]
    assert got_v.tolist() == [[1, 3, 0, 2, 4]]     # stable among the maxes


@pytest.mark.parametrize("rows", [1, 3, 17])
@pytest.mark.parametrize("seglen", [1, 2, 127, 128, 129, 255])
def test_radix_tile_boundary_shapes(rows, seglen):
    """Lane padding (to 128) and row blocking must be invisible."""
    rng = np.random.default_rng(rows * 1000 + seglen)
    k = jnp.asarray(_keys(rng, (rows, seglen), np.int32))
    v = jnp.arange(rows * seglen, dtype=jnp.int32).reshape(rows, seglen)
    _assert_matches_oracle(k, v)


@pytest.mark.parametrize("bpd", [1, 4, 16, 64])
def test_radix_bpd_sweep(bpd):
    """The stage-2 geometry: bpd segment rows per device."""
    rng = np.random.default_rng(bpd)
    k = jnp.asarray(_keys(rng, (bpd, 256), np.int32))
    v = jnp.arange(bpd * 256, dtype=jnp.int32).reshape(bpd, 256)
    _assert_matches_oracle(k, v)


def test_radix_empty_and_full_segments():
    """All-padding rows (empty segments) and rows that are entirely one
    value must round-trip."""
    sent = int(ops.pad_sentinel(jnp.int32))
    k = jnp.asarray(np.stack([
        np.full(200, sent, np.int32),                  # empty segment
        np.full(200, 42, np.int32),                    # constant segment
        np.arange(200, dtype=np.int32)[::-1].copy(),   # reversed
    ]))
    v = jnp.arange(3 * 200, dtype=jnp.int32).reshape(3, 200)
    _assert_matches_oracle(k, v)


def test_radix_matches_bitonic_on_keys():
    """Keys (not payloads — bitonic is unstable) agree across kernels."""
    rng = np.random.default_rng(3)
    k = jnp.asarray(_keys(rng, (8, 256), np.int32))
    a = ops.sort_segments(k, algo="radix")
    b = ops.sort_segments(k, algo="bitonic")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sortable_bits_bijection():
    """key -> sortable bits is monotone and exactly invertible."""
    rng = np.random.default_rng(4)
    for dtype in (np.int32, np.uint32, np.float32):
        k = jnp.asarray(np.sort(_keys(rng, (4096,), dtype)))
        bits = key_to_sortable_bits(k)
        assert bool(jnp.all(bits[1:] >= bits[:-1])), dtype     # monotone
        back = sortable_bits_to_key(bits, k.dtype)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(k))


def test_radix_envelope_reported():
    """Out-of-envelope shapes fail loudly with the recorded reason."""
    assert radix_supported(256) is None
    too_big = (autotune._RADIX_MEASURE_MAX_SEGLEN + 1) * 1024
    reason = radix_supported(too_big, bits=8)
    assert reason is not None and "VMEM" in reason
    assert default_bits(too_big) == 4      # auto-narrows the digit first


# -- autotuner ---------------------------------------------------------------


def test_autotune_measures_once_and_replays():
    rng = np.random.default_rng(5)
    k = jnp.asarray(_keys(rng, (64, 256), np.int32))   # above MIN_MEASURE
    v = jnp.arange(64 * 256, dtype=jnp.int32).reshape(64, 256)
    assert 64 * 256 >= autotune.MIN_MEASURE_ELEMS
    ops.sort_kv_segments(k, v)
    key = autotune.cell_key(64, 256, jnp.int32, kv=True)
    assert autotune.MEASUREMENTS[key] == 1
    first = autotune._cache[key]
    assert first.source == "measured"
    assert set(first.melem) == {"bitonic", "radix", "oracle"}  # all ran
    for _ in range(3):                      # replay: no second measurement
        ops.sort_kv_segments(k, v)
    assert autotune.MEASUREMENTS[key] == 1
    assert autotune.choose(64, 256, jnp.int32).source == "cached"


def test_autotune_small_shapes_skip_measurement():
    rng = np.random.default_rng(6)
    k = jnp.asarray(_keys(rng, (2, 64), np.int32))
    ops.sort_segments(k)
    assert not autotune.MEASUREMENTS
    c = autotune.choose(2, 64, jnp.int32, kv=False)
    assert c.algo == "oracle" and c.source in ("static", "cached")


def test_autotune_force_env_wins():
    """REPRO_KERNEL_FORCE beats the cache, the table, and a pinned algo."""
    os.environ[autotune.FORCE_ENV] = "radix"
    try:
        assert autotune.choose(16, 4096, jnp.int32).algo == "radix"
        assert autotune.choose(16, 4096, jnp.int32).source == "forced"
        # ... even over an explicitly pinned algo at the ops layer
        assert ops.resolve_sort_algo(16, 4096, jnp.int32,
                                     algo="oracle") == "radix"
        # and the forced kernel actually runs (and is right)
        rng = np.random.default_rng(7)
        k = jnp.asarray(_keys(rng, (4, 300), np.int32))
        v = jnp.arange(4 * 300, dtype=jnp.int32).reshape(4, 300)
        got_k, got_v = ops.sort_kv_segments(k, v)
        want_k, want_v = ref.sort_kv_segments_ref(k, v)
        np.testing.assert_array_equal(np.asarray(got_k), np.asarray(want_k))
        np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
        os.environ[autotune.FORCE_ENV] = "quicksort"
        with pytest.raises(ValueError, match="REPRO_KERNEL_FORCE"):
            autotune.choose(16, 4096, jnp.int32)
    finally:
        del os.environ[autotune.FORCE_ENV]


def test_autotune_table_replay_without_measurement():
    """A persisted table (what BENCH_kernels.json carries) short-circuits
    measurement entirely."""
    key = autotune.cell_key(16, 4096, jnp.int32, kv=True)
    autotune.load_table({key: {"algo": "bitonic"}})
    c = autotune.choose(16, 4096, jnp.int32)
    assert c.algo == "bitonic" and c.source == "table"
    assert not autotune.MEASUREMENTS


def test_autotune_export_round_trips():
    rng = np.random.default_rng(8)
    k = jnp.asarray(_keys(rng, (64, 256), np.int32))
    v = jnp.arange(64 * 256, dtype=jnp.int32).reshape(64, 256)
    ops.sort_kv_segments(k, v)
    table = autotune.export_table()
    autotune.reset()
    autotune.load_table(table)
    key = autotune.cell_key(64, 256, jnp.int32, kv=True)
    assert autotune.choose(64, 256, jnp.int32).algo == table[key]["algo"]
    assert not autotune.MEASUREMENTS


def test_deprecated_use_pallas_still_works():
    rng = np.random.default_rng(9)
    k = jnp.asarray(_keys(rng, (2, 128), np.int32))
    v = jnp.arange(256, dtype=jnp.int32).reshape(2, 128)
    with pytest.warns(DeprecationWarning, match="autotuned"):
        a = ops.sort_segments(k, True)                  # -> bitonic
    with pytest.warns(DeprecationWarning, match="autotuned"):
        b, _ = ops.sort_kv_segments(k, v, use_pallas=False)   # -> oracle
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- SPMD integration: over-capacity segments + streaming-path parity --------


def test_radix_spmd_over_capacity_segments():
    """Skewed keys overflow a segment's capacity under the radix stage-2
    path: overflow is dropped AND counted, survivors stay globally sorted
    (same §3.5.1 contract as the bitonic/oracle paths)."""
    run_spmd("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.sort import terasort, is_globally_sorted

mesh = jax.make_mesh((8,), ("data",))
N = 8 * 512
rng = np.random.default_rng(11)
# heavy skew: half the keys land in one of 32 buckets (bpd=4)
keys = rng.integers(0, np.iinfo(np.int32).max, size=N).astype(np.int32)
keys[: N // 2] = keys[: N // 2] % 1000
pay = np.arange(N, dtype=np.int32)
with mesh:
    res = terasort(jnp.asarray(keys), jnp.asarray(pay), mesh,
                   buckets_per_device=4, capacity_factor=1.1,
                   sort_algo="radix")
assert int(res.dropped) > 0, "skew was supposed to overflow a segment"
assert is_globally_sorted(res, 8)
n_out = int(np.asarray(res.valid).sum())
assert n_out + int(res.dropped) == N
print("over-capacity ok", int(res.dropped))
""")


def test_radix_terasort_matches_oracle_terasort():
    """End-to-end SPMD parity: radix stage-2 delivers exactly the oracle
    stage-2's keys (same buckets, same capacities, stable both)."""
    run_spmd("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.sort import terasort

mesh = jax.make_mesh((8,), ("data",))
N = 8 * 512
rng = np.random.default_rng(12)
keys = jnp.asarray(rng.integers(0, np.iinfo(np.int32).max, size=N)
                   .astype(np.int32))
pay = jnp.arange(N, dtype=jnp.int32)
with mesh:
    a = terasort(keys, pay, mesh, buckets_per_device=4, sort_algo="radix")
    b = terasort(keys, pay, mesh, buckets_per_device=4, sort_algo="oracle")
va, vb = np.asarray(a.valid), np.asarray(b.valid)
assert (va == vb).all()
assert (np.asarray(a.keys)[va] == np.asarray(b.keys)[vb]).all()
assert (np.asarray(a.payload)[va] == np.asarray(b.payload)[vb]).all()
print("radix == oracle end-to-end")
""")
