import os
import sys
import types

# Make `repro` importable regardless of how pytest is invoked. Note: we do
# NOT set --xla_force_host_platform_device_count here — smoke tests must see
# one device; SPMD tests spawn subprocesses with their own XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _install_hypothesis_stub() -> None:
    """If hypothesis is not installed (it is dev-only, see
    requirements-dev.txt), register a stub so test modules still import and
    their @given tests are skipped instead of killing collection."""
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    import pytest

    hyp = types.ModuleType("hypothesis")
    strategies = types.ModuleType("hypothesis.strategies")

    def given(*_args, **_kwargs):
        def deco(fn):
            def stub():
                pytest.skip("hypothesis not installed (pip install -r "
                            "requirements-dev.txt)")
            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    def _strategy(*_args, **_kwargs):
        return None

    hyp.given = given
    hyp.settings = settings
    hyp.strategies = strategies
    strategies.__getattr__ = lambda name: _strategy
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strategies


_install_hypothesis_stub()
