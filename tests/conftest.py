import os
import sys

# Make `repro` importable regardless of how pytest is invoked. Note: we do
# NOT set --xla_force_host_platform_device_count here — smoke tests must see
# one device; SPMD tests spawn subprocesses with their own XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
