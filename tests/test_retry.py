"""Shared RetryPolicy: capped exponential backoff with deterministic seeded
jitter, and its three consumers — engine segment retries (host.backoff_ms
histogram + retry event attrs), TenantQueue requeue backoff (not_before
honored on a virtual clock), and SectorClient.recover retry loops."""

import numpy as np
import pytest

from repro.core.retry import RetryPolicy


# -- the policy itself ---------------------------------------------------------


def test_delay_is_capped_exponential():
    p = RetryPolicy(base=0.1, factor=2.0, cap=1.0)
    assert p.delay(0) == pytest.approx(0.1)
    assert p.delay(1) == pytest.approx(0.2)
    assert p.delay(2) == pytest.approx(0.4)
    assert p.delay(5) == 1.0                       # capped, not 3.2
    assert p.delay(50) == 1.0                      # no overflow blowup
    assert p.schedule(4) == tuple(p.delay(a) for a in range(4))
    with pytest.raises(ValueError):
        p.delay(-1)


def test_default_policy_is_zero_delay():
    """The zero-base default is behavior-preserving: consumers wired with
    RetryPolicy() retry immediately (and record 0ms observations)."""
    p = RetryPolicy()
    assert all(d == 0.0 for d in p.schedule(10))


def test_jitter_is_bounded_and_deterministic():
    p = RetryPolicy(base=0.5, factor=2.0, cap=60.0, jitter=0.2, seed=7)
    for attempt in range(6):
        nominal = min(60.0, 0.5 * 2.0 ** attempt)
        d = p.delay(attempt, key=3)
        assert 0.8 * nominal <= d <= 1.2 * nominal
        assert d == p.delay(attempt, key=3)        # same draw every time
    # distinct keys de-synchronize concurrent retriers
    draws = {p.delay(2, key=k) for k in range(16)}
    assert len(draws) > 8
    # distinct seeds give distinct ladders; equal seeds agree
    q = RetryPolicy(base=0.5, factor=2.0, cap=60.0, jitter=0.2, seed=8)
    assert p.schedule(6, key=1) != q.schedule(6, key=1)
    assert p.schedule(6, key=1) == RetryPolicy(
        base=0.5, factor=2.0, cap=60.0, jitter=0.2, seed=7).schedule(6, key=1)


def test_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(base=-1.0)
    with pytest.raises(ValueError):
        RetryPolicy(factor=0.5)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=1.0)
    with pytest.raises(ValueError):
        RetryPolicy(cap=-0.1)


# -- TenantQueue backoff -------------------------------------------------------


def test_tenant_queue_requeue_backoff_honored():
    """A requeued ticket keeps its head seniority but is not dispatched
    before ``not_before``; its deadline is pushed past the backoff so the
    delay never eats the timeout; peer tenants are served meanwhile."""
    from repro.sphere.streaming import TenantQueue

    q = TenantQueue(quantum=16.0, timeout=10.0,
                    retry_policy=RetryPolicy(base=2.0, factor=2.0, cap=8.0))
    q.register("t")
    q.register("u")
    tk = q.admit("t", "p", now=0.0)
    (got,) = q.acquire(1, now=0.0)
    assert got is tk
    assert q.requeue(tk, now=1.0)                  # backoff = base = 2.0
    assert tk.not_before == pytest.approx(3.0)
    assert tk.deadline == pytest.approx(13.0)      # now + delay + timeout
    # tenant t's head is backing off -> the slot passes to tenant u
    other = q.admit("u", "o", now=1.0)
    assert q.acquire(1, now=2.0) == [other]
    q.complete(other, now=2.0)
    assert q.acquire(1, now=2.9) == []             # still inside the window
    assert q.acquire(1, now=3.0) == [tk]           # ready exactly on time
    assert q.complete(tk, now=3.5)
    assert q.stats()["t"]["delivered"] == 1
    assert q.stats()["u"]["delivered"] == 1


def test_tenant_queue_backoff_escalates_per_requeue():
    from repro.sphere.streaming import TenantQueue

    q = TenantQueue(quantum=16.0, max_requeues=5,
                    retry_policy=RetryPolicy(base=1.0, factor=2.0, cap=16.0))
    q.register("t")
    tk = q.admit("t", "p", now=0.0)
    waits = []
    now = 0.0
    for _ in range(3):
        (got,) = q.acquire(1, now=tk.not_before or now)
        assert got is tk
        now = (tk.not_before or now)
        q.requeue(tk, now=now)
        waits.append(tk.not_before - now)
    assert waits == [pytest.approx(1.0), pytest.approx(2.0),
                     pytest.approx(4.0)]           # the exponential ladder


# -- SectorClient.recover retry ------------------------------------------------


def test_client_recover_retries_until_survivor_appears(tmp_path):
    """A transiently-unrecoverable file (every copy gone NOW, a survivor
    appears during the backoff window) succeeds within ``recover_attempts``;
    the injected sleep sees the policy's deterministic delays."""
    from test_sector import make_deployment
    from repro.sector import SectorClient

    _, m = make_deployment(tmp_path, replication=2)
    data = b"flaky" * 40
    slept = []

    def sleep(d):
        slept.append(d)
        if len(slept) == 2:        # the survivor comes back mid-backoff
            stash.write_file("/d/flaky.dat", data)

    c = SectorClient(m, "u", "pw",
                     retry_policy=RetryPolicy(base=0.0),  # no real waiting
                     recover_attempts=4, sleep=sleep)
    c.upload("/d/flaky.dat", data)
    stash = next(s for s in m.live_slaves()
                 if s.slave_id not in m.lookup("/d/flaky.dat").locations)
    for s in m.slaves.values():
        s.drop_file("/d/flaky.dat")
    meta = c.recover("/d/flaky.dat")
    assert len(slept) == 2                         # failed twice, then won
    assert stash.slave_id in meta.locations
    assert c.download("/d/flaky.dat") == data
    # exhausted attempts still fail loudly
    for s in m.slaves.values():
        s.drop_file("/d/flaky.dat")
    slept.clear()
    with pytest.raises(IOError):
        SectorClient(m, "u", "pw", retry_policy=RetryPolicy(),
                     recover_attempts=3, sleep=slept.append
                     ).recover("/d/flaky.dat")
    assert len(slept) == 2                         # attempts-1 backoffs


# -- engine + metrics wiring ---------------------------------------------------


def test_engine_retry_events_carry_attempt_and_delay(tmp_path):
    """Satellite (c): engine ``retry`` trace events expose ``attempt=`` and
    ``delay_ms=`` and every backoff lands in the ``host.backoff_ms``
    histogram."""
    from test_sector import make_deployment
    from repro.obs.metrics import MS_BUCKETS, REGISTRY
    from repro.obs.trace import Tracer
    from repro.sector import SectorClient
    from repro.sphere.engine import SphereProcess
    from repro.sphere.spe import SPE

    _, m = make_deployment(tmp_path, replication=2)
    c = SectorClient(m, "u", "pw")
    rng = np.random.default_rng(0)
    slices = [rng.integers(0, 256, size=(32, 4), dtype=np.uint8)
              for _ in range(2)]
    c.upload_dataset("/r/rec", [s.tobytes() for s in slices])
    spes = [SPE(i, m.slaves[i].address, m, c.session_id) for i in range(2)]

    hist = REGISTRY.histogram("host.backoff_ms", bounds=MS_BUCKETS)
    before = hist.snapshot()["count"]
    calls = {"n": 0}

    def flaky_udf(records):
        calls["n"] += 1
        if calls["n"] <= 2:                        # first try per segment dies
            raise ValueError("transient")
        return records

    sleeps = []
    proc = SphereProcess(m, c.session_id, spes, max_retries=3,
                         retry_policy=RetryPolicy(base=0.01, jitter=0.5,
                                                  seed=2),
                         sleep=sleeps.append)
    tr = Tracer()
    res = proc.run([f"/r/rec.{i:05d}" for i in range(2)], flaky_udf,
                   record_bytes=4, trace=tr)
    assert not res.errors and res.retries >= 2
    retry_events = [e for e in tr.buffer.events() if e.name == "retry"]
    assert len(retry_events) >= 2
    for e in retry_events:
        assert e.attrs["attempt"] >= 1
        assert e.attrs["delay_ms"] > 0.0
        assert e.attrs["reason"] == "udf_error"
    # the jittered delays actually elapsed and landed in the histogram
    assert len(sleeps) == len(retry_events)
    assert [round(s * 1e3, 3) for s in sleeps] == [
        e.attrs["delay_ms"] for e in retry_events]
    assert hist.snapshot()["count"] - before == len(retry_events)
