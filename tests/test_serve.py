"""Serving engine: slot management, continuous batching, greedy correctness."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build
from repro.models.transformer import lm_forward
from repro.serve import Request, ServeEngine


def make_engine(slots=2, max_len=64):
    cfg = get_smoke_config("tinyllama_1_1b")
    model = build(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, ServeEngine(model, params, batch_slots=slots,
                                           max_len=max_len)


def greedy_reference(model, params, cfg, prompt, n_new):
    toks = list(map(int, prompt))
    for _ in range(n_new):
        logits, _, _ = lm_forward(params, cfg,
                                  jnp.asarray([toks], jnp.int32))
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_single_request_matches_full_forward_greedy():
    cfg, model, params, eng = make_engine(slots=1)
    prompt = np.array([5, 17, 3, 99], np.int32)
    eng.submit(Request(0, prompt, max_new_tokens=6))
    done = eng.run_to_completion()
    assert len(done) == 1
    want = greedy_reference(model, params, cfg, prompt, 6)
    assert done[0].out_tokens == want


def test_many_requests_continuous_batching():
    cfg, model, params, eng = make_engine(slots=2)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, size=5).astype(np.int32),
                    max_new_tokens=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_to_completion()
    assert sorted(r.req_id for r in done) == [0, 1, 2, 3, 4]
    assert all(len(r.out_tokens) == 4 for r in done)
    # batching must not corrupt per-request results
    for r in done[:2]:
        want = greedy_reference(model, params, cfg, r.prompt, 4)
        assert r.out_tokens == want, r.req_id


def test_slot_reuse_isolation():
    """A slot reused by a second request must not see the first one's KV."""
    cfg, model, params, eng = make_engine(slots=1)
    p1 = np.array([1, 2, 3], np.int32)
    p2 = np.array([9, 8, 7, 6], np.int32)
    eng.submit(Request(0, p1, max_new_tokens=3))
    eng.submit(Request(1, p2, max_new_tokens=3))
    done = eng.run_to_completion()
    by_id = {r.req_id: r for r in done}
    assert by_id[1].out_tokens == greedy_reference(model, params, cfg, p2, 3)


def test_run_to_completion_reports_unfinished_work():
    """Satellite regression: exhausting max_steps used to silently drop the
    in-flight and queued requests — the report must surface them."""
    cfg, model, params, eng = make_engine(slots=1)
    reqs = [Request(i, np.array([3, 1 + i], np.int32), max_new_tokens=50)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    report = eng.run_to_completion(max_steps=2)
    assert not report.completed
    assert len(report.unfinished) > 0
    # every submitted request is accounted for, exactly once
    seen = sorted(r.req_id for r in list(report) + report.unfinished)
    assert seen == [0, 1, 2]
    assert all(not r.done for r in report.unfinished)
    # back-compat: the report iterates/lens as the done list
    assert isinstance(report, list)
    # and with budget the same engine drains completely
    report2 = eng.run_to_completion()
    assert report2.completed
    done = {r.req_id for r in list(report) + list(report2)}
    assert done == {0, 1, 2}


def test_tenant_mode_priority_and_fair_refills():
    """With a TenantQueue attached, slot refills follow strict priority
    (urgent drains before bulk gets a slot) and completions flow back into
    the per-tenant stats; deadlines are measured in engine steps."""
    from repro.sphere.streaming import QueueFull, TenantQueue

    cfg, model, params, _ = make_engine()
    tq = TenantQueue(quantum=4.0, capacity=8)
    tq.register("urgent", priority=0)
    tq.register("bulk", priority=1)
    eng = ServeEngine(model, params, batch_slots=1, max_len=64, tenants=tq)
    rng = np.random.default_rng(0)
    for i in range(2):          # bulk submitted FIRST, must still wait
        eng.submit(Request(i, rng.integers(0, cfg.vocab, size=4)
                           .astype(np.int32), max_new_tokens=3,
                           tenant="bulk"))
    for i in range(2, 4):
        eng.submit(Request(i, rng.integers(0, cfg.vocab, size=4)
                           .astype(np.int32), max_new_tokens=3,
                           tenant="urgent"))
    report = eng.run_to_completion()
    assert report.completed and len(report) == 4
    assert [r.req_id for r in report[:2]] == [2, 3]    # urgent first
    stats = tq.stats()
    assert stats["urgent"]["delivered"] == 2
    assert stats["bulk"]["delivered"] == 2
    assert stats["bulk"]["latency_p50"] >= stats["urgent"]["latency_p50"]
    # bounded admission: the 9th queued request bounces
    for i in range(8):
        eng.submit(Request(10 + i, np.array([1, 2], np.int32),
                           max_new_tokens=2, tenant="bulk"))
    try:
        eng.submit(Request(99, np.array([1, 2], np.int32),
                           max_new_tokens=2, tenant="bulk"))
        raise AssertionError("QueueFull not raised")
    except QueueFull:
        pass
    assert eng.run_to_completion().completed


def test_encdec_whisper_serving():
    """Enc-dec serving: per-slot encoder memory; batched decode matches the
    single-request teacher-forced reference."""
    from repro.configs import get_smoke_config
    from repro.models import build, encdec
    cfg = get_smoke_config("whisper_small")
    model = build(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    eng = ServeEngine(model, params, batch_slots=2, max_len=32)
    frames = [rng.standard_normal((cfg.enc_seq, cfg.d_model)).astype(np.float32)
              for _ in range(3)]
    prompts = [rng.integers(0, cfg.vocab, size=4).astype(np.int32)
               for _ in range(3)]
    for i in range(3):
        eng.submit(Request(i, prompts[i], max_new_tokens=4,
                           frames=frames[i]))
    done = {r.req_id: r for r in eng.run_to_completion()}
    assert sorted(done) == [0, 1, 2]
    # reference for request 0: greedy over the teacher-forced stack
    enc_out = encdec.encode(params, cfg,
                            jnp.asarray(frames[0], jnp.bfloat16)[None])
    toks = list(map(int, prompts[0]))
    for _ in range(4):
        lg, _ = encdec.decode_stack(params, cfg,
                                    jnp.asarray([toks], jnp.int32), enc_out)
        toks.append(int(jnp.argmax(lg[0, -1])))
    assert done[0].out_tokens == toks[4:]
