"""Edge cases for the jaxpr collective counter (core/introspect.py).

The one-wire-tensor acceptance contract is structural — N ``all_to_all``
per hop — so the counter itself needs coverage: it must recurse through
nested pjit / closed-call sub-jaxprs, scale with the chunk factor W, and
must NOT let unrelated collectives (a ``psum`` inside the UDF) inflate the
``all_to_all`` count. All cases trace on the 1-device mesh: the collectives
still appear in the jaxpr, so no virtual-device subprocess is needed.
"""

import dataclasses
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.introspect import (COLLECTIVE_PRIMITIVES, collective_counts,
                                   primitive_counts)
from repro.core.shuffle import ShufflePlan

NB = 8


def _mesh():
    return jax.make_mesh((1,), ("data",))


def _plan(mesh, chunks=None):
    plan = ShufflePlan.for_mesh(mesh, NB, 512, 2.5, ("data",))
    return dataclasses.replace(plan, chunks=chunks) if chunks else plan


def _shuffle_fn(plan, extra=None):
    def f(d, b):
        r = plan.shuffle(d, b.reshape(-1))
        data = r.data
        if extra is not None:
            data = extra(data)
        return data, r.valid, r.dropped
    return f


def _wrap(mesh, fn):
    return shard_map(fn, mesh=mesh, in_specs=(P("data"), P("data")),
                     out_specs=(P("data"), P("data"), P()), check_vma=False)


def _args():
    return (jnp.zeros((512, 3), jnp.int32), jnp.zeros((512,), jnp.int32))


def test_chunked_hop_scales_all_to_all_by_w():
    """chunks=W splits the one wire tensor into W chunked exchanges — the
    counter must see exactly W all_to_all, W in {1, 2, 4}."""
    mesh = _mesh()
    d, b = _args()
    for w in (1, 2, 4):
        f = _wrap(mesh, _shuffle_fn(_plan(mesh, chunks=w)))
        counts = collective_counts(f, d, b)
        assert counts["all_to_all"] == w, (w, counts)


def test_counts_recurse_through_nested_pjit():
    """A shuffle buried two jit levels down (pjit sub-jaxpr inside a pjit
    sub-jaxpr) is still counted — the walk recurses through every
    ClosedJaxpr found in equation params."""
    mesh = _mesh()
    d, b = _args()
    inner = jax.jit(_wrap(mesh, _shuffle_fn(_plan(mesh, chunks=2))))

    @jax.jit
    def outer(d, b):
        data, valid, dropped = inner(d, b)
        return data + 1, valid, dropped

    counts = collective_counts(outer, d, b)
    assert counts["all_to_all"] == 2, counts
    # the same program traced without the jit wrappers agrees
    flat = collective_counts(_wrap(mesh, _shuffle_fn(_plan(mesh, chunks=2))),
                             d, b)
    assert flat["all_to_all"] == counts["all_to_all"]


def test_counts_recurse_through_closed_call():
    """jax.checkpoint wraps its body in a closed-call-style sub-jaxpr; the
    collectives inside must still be found."""
    mesh = _mesh()
    d, b = _args()
    body = jax.checkpoint(_wrap(mesh, _shuffle_fn(_plan(mesh))))
    counts = collective_counts(body, d, b)
    assert counts["all_to_all"] == 1, counts


def test_udf_psum_does_not_inflate_all_to_all():
    """Regression: a psum inside the UDF (a legitimate user collective)
    must show up under "psum" and leave the all_to_all hop count alone."""
    mesh = _mesh()
    d, b = _args()

    def with_psum(data):
        s = jax.lax.psum(data.sum(), "data")
        return data + s.astype(data.dtype)

    plain = collective_counts(_wrap(mesh, _shuffle_fn(_plan(mesh))), d, b)
    noisy = collective_counts(
        _wrap(mesh, _shuffle_fn(_plan(mesh), extra=with_psum)), d, b)
    assert plain["all_to_all"] == noisy["all_to_all"] == 1
    # the hop itself psums the drop count; the UDF adds exactly one more,
    # and none of it leaks into the all_to_all tally
    assert noisy["psum"] == plain["psum"] + 1
    # every reported key is a known collective, zero-filled when absent
    assert set(noisy) == set(COLLECTIVE_PRIMITIVES)


def test_primitive_counts_plain_function():
    """primitive_counts on a collective-free function: no collectives, and
    ordinary primitives are tallied."""
    counts = primitive_counts(lambda x: jnp.sin(x) + jnp.cos(x),
                              jnp.ones((4,)))
    assert counts.get("sin") == 1 and counts.get("cos") == 1
    assert all(counts.get(c, 0) == 0 for c in COLLECTIVE_PRIMITIVES)
