"""Fault-injection chaos suite (the test-archetype centerpiece).

Headline invariant, asserted across (executor x topology x failure phase x
seed): **the delivered multiset is unchanged under any single injected
failure between stage A and stage B**, plus drop-count conservation and
bounded retry counts.

- HostExecutor faults run in-process against real Sector deployments in
  tmp dirs (``kill_slave`` exercises master rerouting + §3.5.2 SPE
  re-pooling + daemon re-replication; ``drop_bucket`` exercises the
  ``SectorClient.recover`` mid-job re-replication path).
- SPMDExecutor faults need 8 virtual devices, so they run batched inside
  ``run_spmd`` subprocesses (XLA_FLAGS must be set before jax init): hop
  checkpoints + ``elastic.shrink_mesh``/``remesh`` resume.
"""

import collections
import os

import numpy as np
import pytest

from test_spmd import run_spmd

import jax.numpy as jnp

from repro.core.mapreduce import default_hash, reduce_by_key_sum
from repro.core.records import RecordCodec
from repro.launch.train import make_sector
from repro.sphere.chaos import ChaosSchedule, FaultPlan, HopCheckpoint
from repro.sphere.dataflow import Dataflow, HostExecutor, SPMDExecutor
from repro.sphere.spe import SPE, SegmentLost

NB = 8
N_PAGES = 4
BENCH = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                     "benchmarks"))
#: CI runs this file under a seed matrix (REPRO_CHAOS_SEED in {0, 1, 2});
#: every seeded property below shifts by it, so the matrix explores
#: disjoint victim/ordering draws while any one cell stays deterministic
SEED_BASE = int(os.environ.get("REPRO_CHAOS_SEED", "0"))


def _emit(rec):
    return {"key": rec["word"].astype(jnp.int32),
            "value": jnp.ones_like(rec["word"], jnp.int32)}


def _count(rec, valid):
    k, v, dropped = reduce_by_key_sum(rec["key"], rec["value"], valid)
    return {"key": k, "value": v}, k >= 0, dropped


def _pipeline():
    codec = RecordCodec.from_fields({"word": np.uint8, "page": np.uint8})
    return (Dataflow.source(codec)
            .map(_emit)
            .shuffle(by=lambda r: default_hash(r["key"], NB), num_buckets=NB)
            .reduce(_count))


def _pages(seed=7, n=160):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 26, size=(n, 2), dtype=np.uint8)


def _deploy(tmp_path, pages, num_slaves=6):
    master, client, daemon = make_sector(str(tmp_path), num_slaves=num_slaves)
    client.upload_dataset("/web/page",
                          [p.tobytes() for p in np.split(pages, N_PAGES)])
    daemon.run_until_stable()
    spes = [SPE(i, master.slaves[i].address, master, client.session_id)
            for i in range(num_slaves)]
    paths = [f"/web/page.{i:05d}" for i in range(N_PAGES)]
    return master, client, daemon, spes, paths


def _counts(res):
    rec = res.valid_records()
    return {int(k): int(v) for k, v in zip(rec["key"], rec["value"])}


# -- HostExecutor chaos matrix -------------------------------------------------


@pytest.mark.parametrize("seed", [SEED_BASE, SEED_BASE + 1, SEED_BASE + 2])
@pytest.mark.parametrize("phase", [0, 1])
@pytest.mark.parametrize("kind", ["kill_slave", "drop_bucket"])
def test_host_chaos_multiset_invariant(tmp_path, kind, phase, seed):
    """One injected Sector fault at each phase boundary: the delivered
    multiset equals the ground truth, nothing is dropped, nothing errors,
    and retries stay bounded."""
    pages = _pages()
    want = dict(collections.Counter(pages[:, 0].tolist()))
    master, client, daemon, spes, paths = _deploy(tmp_path, pages)
    chaos = FaultPlan(kind=kind, phase=phase, seed=seed)
    ex = HostExecutor(master, client, spes, daemon=daemon)
    res = ex.run(_pipeline(), paths, chaos=chaos)

    assert chaos.fired, chaos
    assert not res.errors and res.data_errors == 0, res.errors
    assert int(res.dropped) == 0                       # drop conservation
    assert _counts(res) == want                        # multiset invariant
    # retry bound: each segment re-pools at most max_retries + |SPE| times
    n_segments = N_PAGES + NB
    assert res.retries <= n_segments * (ex.max_retries + len(spes))
    if kind == "drop_bucket":
        # the lost bucket was re-replicated mid-job, not just rerouted
        assert res.recoveries >= 1, chaos.events
        assert master.stats["recoveries"] >= 1


def test_host_chaos_is_deterministic(tmp_path):
    """Same FaultPlan + same deployment => byte-identical fault events and
    identical results (the suite is a property matrix, not a flake lottery)."""
    pages = _pages()
    runs = []
    for sub in ("a", "b"):
        d = tmp_path / sub
        d.mkdir()
        master, client, daemon, spes, paths = _deploy(d, pages)
        chaos = FaultPlan(kind="drop_bucket", phase=0, seed=3)
        res = HostExecutor(master, client, spes, daemon=daemon).run(
            _pipeline(), paths, chaos=chaos)
        runs.append((chaos.events, _counts(res)))
    assert runs[0] == runs[1]


def test_host_kill_slave_repools_crashed_spe(tmp_path):
    """§3.5.2 proper: the SPE co-located with the killed slave *gets work
    first* (it ties on distance and wins on id), crashes, and the engine
    re-pools its segment onto the survivor — visible as retries >= 1."""
    pages = _pages()
    want = dict(collections.Counter(pages[:, 0].tolist()))
    master, client, daemon, _, paths = _deploy(tmp_path, pages, num_slaves=4)
    from repro.sector.topology import NodeAddress
    spes = [SPE(0, master.slaves[0].address, master, client.session_id),
            SPE(1, NodeAddress(9, 9, 9), master, client.session_id)]
    chaos = FaultPlan(kind="kill_slave", phase=0, victim=0, wipe=True)
    ex = HostExecutor(master, client, spes, daemon=daemon)
    res = ex.run(_pipeline(), paths, chaos=chaos)
    assert chaos.fired and "crashed SPEs [0]" in chaos.events[0]
    assert res.retries >= 1, "crash was not absorbed via re-pooling"
    assert not res.errors and _counts(res) == want


# -- retry accounting (satellite: DATA_ERROR must be counted) ------------------


def test_host_lost_forever_is_counted_data_error(tmp_path):
    """A segment whose input is gone from EVERY slave (no survivor copy
    anywhere) must not vanish silently: it is reported as a counted
    DATA_ERROR while every other segment still delivers."""
    pages = _pages()
    master, client, daemon, spes, paths = _deploy(tmp_path, pages)
    for slave in master.slaves.values():               # all copies destroyed
        slave.drop_file(paths[0])
    res = HostExecutor(master, client, spes, daemon=daemon).run(
        _pipeline(), paths)
    assert res.data_errors >= 1
    assert any(v.startswith("DATA_ERROR") for v in res.errors.values()), \
        res.errors
    assert master.stats["lost_files"] >= 1
    # the surviving 3/4 of the input still delivered
    got = _counts(res)
    want_survivors = collections.Counter(
        np.concatenate(np.split(pages, N_PAGES)[1:])[:, 0].tolist())
    assert got == dict(want_survivors)


def test_host_udf_error_exhausts_retries_as_data_error(tmp_path):
    """Regression (satellite): a UDF that fails deterministically exhausts
    max_retries and surfaces as a counted DATA_ERROR in the run report —
    previously it sat in ``errors`` unprefixed and uncounted."""
    pages = _pages()
    master, client, daemon, spes, paths = _deploy(tmp_path, pages)

    def poisoned(rec):
        if int(np.asarray(rec["page"]).reshape(-1)[0]) == 0:
            raise ValueError("poisoned segment")
        return _emit(rec)

    codec = RecordCodec.from_fields({"word": np.uint8, "page": np.uint8})
    df = (Dataflow.source(codec).map(poisoned)
          .shuffle(by=lambda r: default_hash(r["key"], NB), num_buckets=NB)
          .reduce(_count))
    pages = pages.copy()
    pages[:, 1] = np.repeat(np.arange(N_PAGES, dtype=np.uint8), 40)
    # re-upload with the page ids that trigger the poison on slice 0
    client.upload_dataset("/web2/page",
                          [p.tobytes() for p in np.split(pages, N_PAGES)])
    daemon.run_until_stable()
    res = HostExecutor(master, client, spes, daemon=daemon).run(
        df, [f"/web2/page.{i:05d}" for i in range(N_PAGES)])
    # every segment of slice 0 fails; each is individually counted
    assert res.data_errors >= 1
    bad = [v for v in res.errors.values() if v.startswith("DATA_ERROR")]
    assert len(bad) == res.data_errors and "poisoned" in bad[0], res.errors
    got = _counts(res)
    want = dict(collections.Counter(
        np.concatenate(np.split(pages, N_PAGES)[1:])[:, 0].tolist()))
    assert got == want


def test_segment_lost_exception_carries_path(tmp_path):
    """SegmentLost (data gone) is distinguishable from a plain IOError (SPE
    crash): it is raised from the download failure and carries the Sector
    path the recovery hook needs."""
    pages = _pages()
    master, client, _, spes, paths = _deploy(tmp_path, pages)
    for slave in master.slaves.values():
        slave.drop_file(paths[1])
    from repro.core.stream import SegmentInfo
    seg = SegmentInfo(0, paths[1], 0, 4)
    with pytest.raises(SegmentLost) as ei:
        spes[0].read_segment(seg, record_bytes=2)
    assert ei.value.path == paths[1]
    assert isinstance(ei.value, IOError)


# -- ChaosSchedule: ordered multi-fault sequences ------------------------------


def test_chaos_schedule_multi_fault_host_multiset(tmp_path):
    """A kill_slave @ boundary 0 followed by rejoin_slave @ boundary 1 — one
    ordered schedule, one shared audit log — still delivers the fault-free
    multiset, and the rejoined slave is live (incarnation bumped,
    re-absorbed by scan) at the end."""
    pages = _pages()
    want = dict(collections.Counter(pages[:, 0].tolist()))
    master, client, daemon, spes, paths = _deploy(tmp_path, pages)
    sched = ChaosSchedule([
        FaultPlan(kind="kill_slave", phase=0),
        FaultPlan(kind="rejoin_slave", phase=1),
    ], seed=SEED_BASE)
    res = HostExecutor(master, client, spes, daemon=daemon).run(
        _pipeline(), paths, chaos=sched)

    assert sched.fired and sched.fired_count == 2
    assert not res.errors and int(res.dropped) == 0
    assert _counts(res) == want
    assert "killed slave" in sched.events[0]
    rejoin = next(e for e in sched.events if "rejoined" in e)
    assert "incarnation 1" in rejoin
    assert all(s.alive for s in master.slaves.values())  # victim is back


def test_chaos_schedule_is_deterministic(tmp_path):
    """Same ChaosSchedule seed + same deployment => byte-identical shared
    events (in firing order) and identical results, across independent
    deployments — the multi-fault replay guarantee."""
    pages = _pages()
    runs = []
    for sub in ("a", "b"):
        d = tmp_path / sub
        d.mkdir()
        master, client, daemon, spes, paths = _deploy(d, pages)
        sched = ChaosSchedule([
            FaultPlan(kind="kill_slave", phase=0),
            FaultPlan(kind="rejoin_slave", phase=1),
        ], seed=SEED_BASE + 3)
        res = HostExecutor(master, client, spes, daemon=daemon).run(
            _pipeline(), paths, chaos=sched)
        runs.append((list(sched.events), _counts(res)))
    assert runs[0] == runs[1]


def test_chaos_schedule_rederives_member_seeds():
    """Two same-kind, same-seed members of one schedule draw from DISTINCT
    derived streams (position-mixed), and the schedule seed perturbs every
    member — so schedules never alias each other or their members."""
    def seeds(schedule_seed):
        s = ChaosSchedule([FaultPlan(kind="lose_device", at_batch=0),
                           FaultPlan(kind="lose_device", at_batch=1)],
                          seed=schedule_seed)
        return [f.seed for f in s.faults]

    a, b = seeds(0), seeds(1)
    assert a[0] != a[1]                 # position decorrelates members
    assert a != b                       # schedule seed perturbs all members
    assert seeds(1) == seeds(1)         # and it is all deterministic
    s = ChaosSchedule([FaultPlan(kind="lose_batch", at_batch=4)])
    assert s.kinds == ("lose_batch",)
    assert s.due_at_batch(3) == [] and s.due_at_batch(4) == s.faults
    assert not s.fired and s.fired_count == 0


def test_stream_checkpoint_roundtrip_byte_deterministic():
    """StreamCheckpoint serialization is byte-deterministic (no timestamps:
    two seals of the same boundary serialize identically) and round-trips
    the carry arrays, step and ticket ids exactly."""
    import dataclasses as dc

    from repro.sphere.chaos import StreamCheckpoint

    @dc.dataclass
    class Tk:
        req_id: int

    rng = np.random.default_rng(0)
    carry = ({"key": rng.integers(0, 99, 16).astype(np.int32),
              "value": rng.integers(0, 9, 16).astype(np.int32)},
             rng.integers(0, 2, 16).astype(bool))
    tickets = [Tk(3), Tk(11), Tk(7)]
    blob = StreamCheckpoint.seal(5, tickets, carry).to_bytes()
    blob2 = StreamCheckpoint.seal(5, tickets, carry).to_bytes()
    assert blob == blob2 and blob.startswith(StreamCheckpoint.MAGIC)

    back = StreamCheckpoint.from_bytes(blob)
    assert back.step == 5 and back.ticket_ids == (3, 11, 7)
    import jax
    mesh = jax.make_mesh((1,), ("data",))
    rec2, valid2 = back.restore_carry(mesh, ("data",))
    for k in carry[0]:
        np.testing.assert_array_equal(np.asarray(rec2[k]), carry[0][k])
    np.testing.assert_array_equal(np.asarray(valid2), carry[1])
    # a carry-less boundary (before the first stateful batch) also rides
    empty = StreamCheckpoint.from_bytes(
        StreamCheckpoint.seal(0, [], None).to_bytes())
    assert empty.carry is None and empty.restore_carry(mesh, ("data",)) is None


def test_stream_chaos_soak_acceptance():
    """Run the real stream-chaos soak end-to-end and apply its acceptance
    gates: >= 30 micro-batches surviving a 4-fault schedule with exactly 2
    recoveries and 2 compiles, exactly-once delivery, stream == fault-free
    batch, byte-identical same-seed replay, bounded recovery overhead."""
    run_spmd(f"""
import sys
sys.path.insert(0, {BENCH!r})
import stream_chaos_bench
res = stream_chaos_bench.soak(chaos=True)
replay = stream_chaos_bench.soak(chaos=True)
baseline = stream_chaos_bench.soak(chaos=False)
failures = stream_chaos_bench.check(res, replay, baseline)
assert not failures, failures
print("stream chaos soak ok:", res["steps"], "batches,",
      res["recoveries"], "recoveries,", len(res["events"]), "audit events")
""")


# -- chaos plan / checkpoint units ---------------------------------------------


def test_fault_plan_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan(kind="meteor_strike")


def test_chaos_guard_rails(tmp_path):
    """Cross-wired fault kinds and unrecoverable configurations fail loudly
    instead of running a meaningless recovery."""
    import jax
    mesh = jax.make_mesh((1,), ("data",))
    ex = SPMDExecutor(mesh)
    data = {"key": np.arange(8, dtype=np.int32)}
    with pytest.raises(ValueError, match="Sector-level fault"):
        ex.run(Dataflow.source().map(lambda r: r), data,
               chaos=FaultPlan(kind="kill_slave"))
    # an auto bucket count would silently re-bucket after a mesh shrink
    auto = Dataflow.source().shuffle(by=lambda r: r["key"] % 2)
    with pytest.raises(ValueError, match="num_buckets"):
        ex.run(auto, data, chaos=FaultPlan(kind="none"))
    # carry state cannot survive a mesh re-form
    df = Dataflow.source().map(lambda r: r)
    with pytest.raises(ValueError, match="carry"):
        ex.run(df, data, chaos=FaultPlan(kind="none"),
               carry=({"key": np.zeros(2, np.int32)}, np.ones(2, bool)))
    # device faults cannot be injected into the Sector data plane
    pages = _pages()
    master, client, daemon, spes, paths = _deploy(tmp_path, pages)
    with pytest.raises(ValueError, match="device-mesh fault"):
        HostExecutor(master, client, spes).run(
            _pipeline(), paths, chaos=FaultPlan(kind="lose_device"))


def test_hop_checkpoint_roundtrip_bit_identical():
    """A HopCheckpoint is layout-agnostic bytes: snapshot -> restore on a
    mesh reproduces every field of a mixed-dtype record pytree exactly."""
    import jax
    rng = np.random.default_rng(0)
    records = {"k": rng.integers(0, 1 << 30, 16).astype(np.int32),
               "v": rng.random((16, 3)).astype(np.float32),
               "b": rng.integers(0, 2, 16).astype(bool)}
    valid = rng.integers(0, 2, 16).astype(bool)
    ckpt = HopCheckpoint.snapshot(records, valid, hop=2, dropped=5)
    assert ckpt.payload.dtype == np.uint8 and ckpt.hop == 2
    mesh = jax.make_mesh((1,), ("data",))
    rec2, valid2 = ckpt.restore(mesh, ("data",))
    for k in records:
        np.testing.assert_array_equal(np.asarray(rec2[k]), records[k])
        assert np.asarray(rec2[k]).dtype == records[k].dtype
    np.testing.assert_array_equal(np.asarray(valid2), valid)


# -- SPMDExecutor chaos (8 virtual devices, batched subprocesses) --------------


def test_spmd_chaos_matrix():
    """Flat and hierarchical topologies x both hop boundaries x 3 seeds:
    segmented-with-checkpoints == fused, and an injected device loss at any
    boundary resumes on a shrunken mesh with the multiset intact."""
    run_spmd(("SEED_BASE = %d\n" % SEED_BASE) + """
import collections
import jax, jax.numpy as jnp, numpy as np
from repro.core.mapreduce import default_hash, reduce_by_key_sum
from repro.sphere.chaos import FaultPlan
from repro.sphere.dataflow import Dataflow, SPMDExecutor

NB = 8
def emit(rec):
    return {"key": rec["word"].astype(jnp.int32),
            "value": jnp.ones_like(rec["word"], jnp.int32)}
def count(rec, valid):
    k, v, dropped = reduce_by_key_sum(rec["key"], rec["value"], valid)
    return {"key": k, "value": v}, k >= 0, dropped
df = (Dataflow.source().map(emit)
      .shuffle(by=lambda r: default_hash(r["key"], NB), num_buckets=NB)
      .reduce(count))
rng = np.random.default_rng(7)
N = 8 * 64
words = rng.integers(0, 26, size=N).astype(np.uint8)
want = dict(collections.Counter(words.tolist()))
src = {"word": jnp.asarray(words)}

def counts(res):
    rec = res.valid_records()
    return {int(k): int(v) for k, v in zip(rec["key"], rec["value"])}

meshes = ((jax.make_mesh((8,), ("data",)), ("data",)),
          (jax.make_mesh((2, 4), ("dc", "node")), ("dc", "node")))
for mesh, axes in meshes:
    ex = SPMDExecutor(mesh, axes=axes)
    with mesh:
        clean = ex.run(df, src)
        assert counts(clean) == want
        # segmented (per-hop checkpoints, no fault) == fused
        seg = ex.run(df, src, chaos=FaultPlan(kind="none"))
        assert counts(seg) == want
        assert int(seg.dropped) == int(clean.dropped) == 0
        for phase in (0, 1):
            for seed in (SEED_BASE, SEED_BASE + 1, SEED_BASE + 2):
                chaos = FaultPlan(kind="lose_device", phase=phase, seed=seed)
                res = ex.run(df, src, chaos=chaos)
                assert chaos.fired, (axes, phase, seed)
                assert res.recoveries == 1
                assert counts(res) == want, (axes, phase, seed)
                assert int(res.dropped) == int(clean.dropped)  # conservation
print("spmd chaos matrix ok")
""")


def test_spmd_chaos_between_two_shuffle_hops():
    """The literal headline scenario: a pipeline with TWO shuffle stages
    loses a device at every boundary — before stage A, between stage A and
    stage B, and after stage B — and always delivers the fault-free
    multiset."""
    run_spmd(("SEED_BASE = %d\n" % SEED_BASE) + """
import collections
import jax, jax.numpy as jnp, numpy as np
from repro.core.mapreduce import default_hash, reduce_by_key_sum
from repro.sphere.chaos import FaultPlan
from repro.sphere.dataflow import Dataflow, SPMDExecutor

NB = 8
def emit(rec):
    return {"key": rec["word"].astype(jnp.int32),
            "value": jnp.ones_like(rec["word"], jnp.int32)}
def count(rec, valid):
    k, v, dropped = reduce_by_key_sum(rec["key"], rec["value"], valid)
    return {"key": k, "value": v}, k >= 0, dropped
# stage A: spread by hash; stage B: regroup by key — 3 phases, 3 boundaries
df = (Dataflow.source().map(emit)
      .shuffle(by=lambda r: default_hash(r["key"] * 7 + 13, NB),
               num_buckets=NB, capacity_factor=6.0)
      .shuffle(by=lambda r: r["key"] % NB, num_buckets=NB,
               capacity_factor=6.0)
      .reduce(count))
rng = np.random.default_rng(13)
N = 8 * 64
words = rng.integers(0, 26, size=N).astype(np.uint8)
want = dict(collections.Counter(words.tolist()))
src = {"word": jnp.asarray(words)}

def counts(res):
    rec = res.valid_records()
    return {int(k): int(v) for k, v in zip(rec["key"], rec["value"])}

mesh = jax.make_mesh((8,), ("data",))
ex = SPMDExecutor(mesh)
with mesh:
    clean = ex.run(df, src)
    assert counts(clean) == want and int(clean.dropped) == 0
    for phase in (0, 1, 2):
        for seed in (SEED_BASE, SEED_BASE + 1):
            chaos = FaultPlan(kind="lose_device", phase=phase, seed=seed)
            res = ex.run(df, src, chaos=chaos)
            assert chaos.fired and res.recoveries == 1
            assert counts(res) == want, (phase, seed)
            assert int(res.dropped) == 0
print("two-hop chaos ok")
""")


def test_spmd_chaos_sort_resume():
    """Device loss against the two-stage sort: the resumed run is still a
    globally sorted permutation of the input."""
    run_spmd(("SEED_BASE = %d\n" % SEED_BASE) + """
import jax, jax.numpy as jnp, numpy as np
from repro.sphere.chaos import FaultPlan
from repro.sphere.dataflow import Dataflow, SPMDExecutor

N = 8 * 128
rng = np.random.default_rng(3)
keys = rng.integers(0, 2**31 - 2, size=N).astype(np.int32)
payload = np.arange(N, dtype=np.int32)
df = Dataflow.source().sort(key=lambda r: r["key"], num_buckets=8,
                            capacity_factor=3.0)
src = {"key": jnp.asarray(keys), "payload": jnp.asarray(payload)}
mesh = jax.make_mesh((8,), ("data",))
ex = SPMDExecutor(mesh)
with mesh:
    clean = ex.run(df, src)
    cvr = clean.valid_records()
    assert int(clean.dropped) == 0
    for seed in (SEED_BASE, SEED_BASE + 1):
        chaos = FaultPlan(kind="lose_device", phase=0, seed=seed)
        res = ex.run(df, src, chaos=chaos)
        vr = res.valid_records()
        assert chaos.fired and int(res.dropped) == 0
        assert (np.diff(vr["key"]) >= 0).all()
        assert (keys[vr["payload"]] == vr["key"]).all()   # permutation
        np.testing.assert_array_equal(vr["key"], cvr["key"])
print("sort resume ok")
""")


def test_elastic_shrink_remesh_divisor_sweep():
    """Satellite: re-shard WireFrame tiles onto EVERY shrunken device count
    that divides the bucket layout (8 -> 4 -> 2 -> 1), asserting the framed
    byte rows survive each re-shard bit-identically; shrink_mesh picks
    exactly those extents and refuses non-divisors."""
    run_spmd("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core.records import WireFrame
from repro.train.elastic import remesh, shrink_mesh

rng = np.random.default_rng(0)
N = 8 * 16
frame = WireFrame.for_payload(np.zeros((1, 4), np.int32),
                              meta=("bucket",), explicit_valid=True)
payload = jnp.asarray(rng.integers(0, 1 << 30, (N, 4), dtype=np.int32))
valid = jnp.asarray(rng.integers(0, 2, N).astype(bool))
rows = frame.frame_rows(payload, valid=valid,
                        bucket=jnp.arange(N, dtype=jnp.int32) % 8)
want = np.asarray(rows)

mesh = jax.make_mesh((8,), ("data",))
spec = P("data")
tiles = jax.device_put(rows, NamedSharding(mesh, spec))
seen = []
NUM_BUCKETS = 8
while mesh.devices.size > 1:
    # lose a different device at every level; extent must divide buckets
    mesh = shrink_mesh(mesh, ("data",), lost_device=mesh.devices.size // 2,
                       num_buckets=NUM_BUCKETS)
    seen.append(mesh.devices.size)
    tiles = remesh(tiles, mesh, spec)
    got = np.asarray(tiles)
    assert got.dtype == np.uint8
    np.testing.assert_array_equal(got, want)   # bit-identical rows
    # and the decoded payload/validity survive too (invalid rows are
    # zeroed by framing, so compare payload under the mask)
    p2, v2, m2 = frame.open_rows(jnp.asarray(got))
    v = np.asarray(valid)
    np.testing.assert_array_equal(np.asarray(v2), v)
    np.testing.assert_array_equal(np.asarray(p2)[v], np.asarray(payload)[v])
assert seen == [4, 2, 1], seen                 # every dividing count

# hierarchical: a lost node shrinks the node axis, never the dc axis
m2 = jax.make_mesh((2, 4), ("dc", "node"))
s2 = shrink_mesh(m2, ("dc", "node"), lost_device=5, num_buckets=8)
assert dict(s2.shape) == {"dc": 2, "node": 2}
survivors = [d.id for d in np.asarray(s2.devices).reshape(-1)]
assert 5 not in survivors and len(survivors) == 4

# no usable smaller extent -> loud refusal, not silent re-bucketing
one = Mesh(np.array(jax.devices()[:1]), ("data",))
try:
    shrink_mesh(one, ("data",), lost_device=0, num_buckets=8)
    raise AssertionError("shrink below 1 device did not raise")
except ValueError as e:
    assert "cannot shrink" in str(e)
# extent must divide num_buckets: 8 devices, 7 buckets -> largest is 1
s3 = shrink_mesh(jax.make_mesh((8,), ("data",)), ("data",),
                 lost_device=0, num_buckets=7)
assert s3.devices.size == 1
print("divisor sweep ok")
""")
