"""Sphere segment scheduler: the paper's rules 1-3, fault tolerance,
straggler speculation (§3.5), plus hypothesis properties."""

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.stream import SegmentInfo, SphereStream
from repro.sector.topology import NodeAddress
from repro.sphere.scheduler import SegmentScheduler, SegStatus, SPEState


def make_segments(n, files=4, recs=1000):
    return [SegmentInfo(i, f"/d/f{i % files:02d}", 0, recs) for i in range(n)]


def locations(files=4):
    return {f"/d/f{i:02d}": [NodeAddress(0, i % 2, i)] for i in range(files)}


def test_all_segments_complete():
    spes = [SPEState(i, NodeAddress(0, i % 2, i % 4), speed=1e3)
            for i in range(4)]
    s = SegmentScheduler(make_segments(16), spes, locations())
    stats = s.run()
    assert stats["done"] == 16 and stats["unfinished"] == 0


def test_locality_rule_prefers_colocated_spe():
    # one SPE sits exactly on the data node; it should get the segment
    spes = [SPEState(0, NodeAddress(0, 0, 0), speed=1e3),
            SPEState(1, NodeAddress(1, 1, 9), speed=1e3)]
    segs = [SegmentInfo(0, "/d/f00", 0, 100)]
    s = SegmentScheduler(segs, spes, locations())
    s.run()
    assert s.segments[0].completed_by == 0


def test_straggler_speculation_wins():
    """A 100x-slow SPE must not gate the makespan: the tail segment is
    duplicated on a fast idle SPE which finishes first (§3.5.2)."""
    spes = [SPEState(0, NodeAddress(0, 0, 0), speed=1e3),
            SPEState(1, NodeAddress(0, 0, 1), speed=10.0)]
    segs = make_segments(4, files=1)
    s = SegmentScheduler(segs, spes, locations(1), speculate=True)
    stats = s.run()
    assert stats["done"] == 4
    # speculation happened and the slow SPE completed almost nothing
    assert any(e.kind == "duplicate" for e in s.events)
    assert stats["makespan"] < 4 * 1000 / 10.0  # far below slow-SPE-only time

    s2 = SegmentScheduler(make_segments(4, files=1),
                          [SPEState(0, NodeAddress(0, 0, 0), speed=1e3),
                           SPEState(1, NodeAddress(0, 0, 1), speed=10.0)],
                          locations(1), speculate=False)
    st2 = s2.run()
    assert stats["makespan"] <= st2["makespan"]


def test_spe_crash_reassigns_segment():
    spes = [SPEState(0, NodeAddress(0, 0, 0), speed=100.0, fail_at=0.5),
            SPEState(1, NodeAddress(0, 0, 1), speed=100.0)]
    s = SegmentScheduler(make_segments(6), spes, locations(), timeout=1.0)
    stats = s.run()
    assert stats["done"] == 6
    assert any(e.kind == "timeout" for e in s.events)
    assert all(seg.completed_by == 1 or seg.completed_by == 0
               for seg in s.segments)


def test_data_error_reported_not_retried_forever():
    spes = [SPEState(i, NodeAddress(0, 0, i), speed=1e3) for i in range(2)]
    s = SegmentScheduler(make_segments(8), spes, locations(),
                         max_data_errors=2)
    stats = s.run(fail_segments={3})
    assert stats["data_errors"] == 1
    assert stats["done"] == 7
    assert s.segments[3].status == SegStatus.DATA_ERROR
    assert s.segments[3].attempts <= 3


def test_static_assignment_partition():
    spes = [SPEState(i, NodeAddress(0, i % 2, i), speed=1e3)
            for i in range(3)]
    s = SegmentScheduler(make_segments(10), spes, locations())
    a = s.static_assignment()
    got = sorted(i for v in a.values() for i in v)
    assert got == list(range(10))
    loads = [len(v) for v in a.values()]
    assert max(loads) - min(loads) <= 1


def test_segment_planning_bounds():
    """§3.5.1: per-segment size clamped to [S_min, S_max], whole records,
    single file."""
    files = [("/f/a", 1000), ("/f/b", 500)]
    segs = SphereStream.plan_segments(1500, record_bytes=100, files=files,
                                      s_min=10_000, s_max=20_000, num_spes=4)
    assert sum(s.num_records for s in segs) == 1500
    for s in segs:
        assert s.num_records <= 200          # S_max / record_bytes
        assert s.file_path in ("/f/a", "/f/b")
    # no segment crosses a file boundary
    for s in segs:
        limit = dict(files)[s.file_path]
        assert s.offset + s.num_records <= limit


@settings(max_examples=30, deadline=None)
@given(
    n_segs=st.integers(1, 24),
    n_spes=st.integers(1, 6),
    crash=st.lists(st.integers(0, 5), max_size=2, unique=True),
)
def test_property_completion_under_failures(n_segs, n_spes, crash):
    """As long as >= 1 SPE survives, every segment completes exactly once."""
    spes = []
    for i in range(n_spes):
        fail = 1.0 if i in crash and i < n_spes - 1 else None
        spes.append(SPEState(i, NodeAddress(0, i % 2, i), speed=100.0,
                             fail_at=fail))
    s = SegmentScheduler(make_segments(n_segs), spes, locations(),
                         timeout=0.5)
    stats = s.run()
    assert stats["done"] == n_segs
    completed = [seg.completed_by for seg in s.segments]
    assert all(c is not None for c in completed)
    # completions only by live-at-the-time SPEs; each segment exactly once
    assert len(completed) == n_segs
