"""Hypothesis property tests on system invariants."""

import io
import json

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.mapreduce import reduce_by_key_sum
from repro.core.sort import uniform_splitters
from repro.kernels.ops import partition_pack
from repro.train.checkpoint import _deserialize_leaves, _serialize_tree


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 7), min_size=1, max_size=200))
def test_partition_pack_layout_partitions(dests):
    """The O(n) fused partition/pack behind every shuffle send: each
    destination row holds exactly its records, in arrival order (the
    stable-sort layout), with consistent counts and no drops at full
    capacity."""
    n = len(dests)
    d = jnp.asarray(dests, jnp.int32)
    (tile,), in_range, origin, dropped = partition_pack(
        [d], d, 8, n, use_pallas=False)
    tile, in_range, origin = map(np.asarray, (tile, in_range, origin))
    assert int(dropped) == 0
    assert in_range.sum() == n
    for b in range(8):
        run = origin[b][in_range[b]]
        assert all(dests[i] == b for i in run)
        if len(run) > 1:
            assert (np.diff(run) > 0).all()   # stability within a dest
        assert (tile[b][in_range[b]] == b).all()
    assert (origin[~in_range] == -1).all()


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 20), st.integers(-5, 5)),
                min_size=1, max_size=150))
def test_reduce_by_key_sum_matches_counter(pairs):
    keys = jnp.asarray([k for k, _ in pairs], jnp.int32)
    vals = jnp.asarray([v for _, v in pairs], jnp.int32)
    valid = jnp.ones((len(pairs),), bool)
    out_k, out_v, dropped = reduce_by_key_sum(keys, vals, valid)
    got = {int(k): int(v) for k, v in zip(np.asarray(out_k),
                                          np.asarray(out_v)) if k >= 0}
    want = {}
    for k, v in pairs:
        want[k] = want.get(k, 0) + v
    assert got == want
    assert int(dropped) == 0  # cap defaults to the input size: no truncation


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 64))
def test_uniform_splitters_monotone(nb):
    s = np.asarray(uniform_splitters(nb))
    assert len(s) == nb - 1
    assert (np.diff(s) > 0).all()


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(1, 6), min_size=1, max_size=5),
       st.sampled_from([np.float32, np.int32, np.float16]))
def test_checkpoint_serialization_roundtrip(dims, dtype):
    rng = np.random.default_rng(0)
    tree = {
        "a": rng.standard_normal(dims).astype(dtype),
        "nested": {"b": rng.integers(0, 100, size=dims).astype(np.int32)},
    }
    blob, meta = _serialize_tree(tree)
    leaves = _deserialize_leaves(blob, meta)
    flat, _ = jax.tree.flatten(tree)
    for a, b in zip(flat, leaves):
        np.testing.assert_array_equal(np.asarray(a), b)
    json.dumps(meta)  # manifest must be JSON-serializable


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 5), st.integers(1, 3))
def test_stream_segments_cover_everything(files, spes):
    from repro.core.stream import SphereStream
    flist = [(f"/x/{i}", 100 * (i + 1)) for i in range(files)]
    total = sum(n for _, n in flist)
    segs = SphereStream.plan_segments(total, 10, flist, s_min=10, s_max=500,
                                      num_spes=spes)
    assert sum(s.num_records for s in segs) == total
    seen = {}
    for s in segs:
        for r in range(s.offset, s.offset + s.num_records):
            key = (s.file_path, r)
            assert key not in seen       # no overlap
            seen[key] = True


def test_collective_bytes_parser():
    import importlib
    dr = importlib.import_module("repro.launch.dryrun")
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256] %x), replica_groups={}
  %ag.1 = bf16[64]{0} all-gather(bf16[8] %y), dimensions={0}
  %a2a = (s32[16,4]{1,0}) all-to-all(s32[16,4] %z)
  %rs-start = ((f32[32]), f32[4]) reduce-scatter-start(f32[32] %w)
  %other = f32[2,2] add(f32[2,2] %a, f32[2,2] %b)
"""
    out = dr.collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 256 * 4 * 2      # 2x for ring
    assert out["all-gather"] == 64 * 2
    assert out["all-to-all"] == 16 * 4 * 4
    assert out["reduce-scatter"] == 4 * 4
    assert out["collective-permute"] == 0


def test_moe_active_fraction():
    import importlib
    dr = importlib.import_module("repro.launch.dryrun")
    from repro.configs import get_config
    from repro.models import build as build_model
    cfg = get_config("qwen3_moe_30b_a3b")
    model = build_model(cfg)
    sds = jax.eval_shape(lambda k: model.init(k)[0], jax.random.PRNGKey(0))
    frac = dr.moe_active_fraction(model, sds)
    assert 0.05 < frac < 0.35     # ~3B active of ~30B total
    n = sum(l.size for l in jax.tree.leaves(sds))
    assert 25e9 < n < 36e9        # total params match the name "30B"


def test_analytic_param_bytes_sharding():
    import importlib
    import types
    from jax.sharding import PartitionSpec as P
    dr = importlib.import_module("repro.launch.dryrun")
    # stub mesh: analytic_param_bytes only reads .shape (a real 256-device
    # mesh cannot be built once jax has initialized with 1 CPU device)
    mesh = types.SimpleNamespace(shape={"data": 16, "model": 16})
    sds = {"w": jax.ShapeDtypeStruct((64, 1600), jnp.float32)}
    specs = {"w": P(None, "model")}
    got = dr.analytic_param_bytes(sds, specs, mesh)
    assert got == 64 * 100 * 4


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 500), st.integers(1, 60))
def test_rope_relative_position_invariance(offset, delta):
    """RoPE scores depend only on relative position: q(p)·k(p+d) is invariant
    to shifting both positions by any offset."""
    from repro.models.layers import apply_rope
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)

    def score(p0, p1):
        qr = apply_rope(q, jnp.asarray([[p0]]), 10_000.0)
        kr = apply_rope(k, jnp.asarray([[p1]]), 10_000.0)
        return float(jnp.sum(qr * kr))

    a = score(0, delta)
    b = score(offset, offset + delta)
    assert abs(a - b) < 1e-3, (a, b)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 400))
def test_rope_preserves_norm(pos):
    """RoPE is a rotation: vector norms are preserved at any position."""
    from repro.models.layers import apply_rope
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 1, 2, 64)), jnp.float32)
    y = apply_rope(x, jnp.asarray([[pos]]), 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x)),
                               np.linalg.norm(np.asarray(y)), rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 10), st.integers(1, 8))
def test_rms_norm_scale_invariance(seq, mult):
    """rms_norm(c*x) == rms_norm(x) for any positive scalar c."""
    from repro.models.layers import rms_norm
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((1, seq, 16)), jnp.float32)
    g = jnp.ones((16,), jnp.float32)
    a = np.asarray(rms_norm(x, g), np.float32)
    b = np.asarray(rms_norm(x * float(mult), g), np.float32)
    np.testing.assert_allclose(a, b, atol=2e-2)
