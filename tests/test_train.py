"""Trainer + Sector checkpointing + data pipeline integration."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import (SectorDataPipeline, synthetic_tokens,
                        upload_token_dataset)
from repro.models import build
from repro.sector import (Master, NodeAddress, ReplicationDaemon,
                          SectorClient, SecurityServer, SlaveNode, Topology)
from repro.train.checkpoint import SectorCheckpointer
from repro.train.optimizer import (AdamWConfig, adamw_update, init_opt_state,
                                   lr_schedule, zero1_specs)
from repro.train.trainer import build_train_step


@pytest.fixture
def sector(tmp_path):
    sec = SecurityServer()
    sec.add_user("u", "pw")
    sec.allow_slaves("10.0.0.0/8")
    m = Master(sec, replication_factor=2)
    topo = Topology(pods=1, racks=2, nodes_per_rack=2)
    for i, addr in enumerate(topo.all_addresses()):
        m.register_slave(SlaveNode(i, addr, str(tmp_path / f"s{i}"),
                                   ip=f"10.0.0.{i + 1}"))
    c = SectorClient(m, "u", "pw", client_addr=NodeAddress(0, 0, 0))
    return m, c, ReplicationDaemon(m)


def tiny_model():
    cfg = get_smoke_config("tinyllama_1_1b")
    return cfg, build(cfg)


def test_loss_decreases(sector):
    m, c, daemon = sector
    cfg, model = tiny_model()
    toks = synthetic_tokens(60_000, cfg.vocab)
    upload_token_dataset(c, "/corpus/t", toks, num_slices=4)
    pipe = SectorDataPipeline(m, c, "/corpus/t", batch=8, seq_len=32)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    step = jax.jit(build_train_step(model, opt_cfg, None))
    losses = []
    it = iter(pipe)
    for i in range(60):
        try:
            batch = next(it)
        except StopIteration:
            it = iter(pipe)
            batch = next(it)
        params, opt, metrics = step(params, opt,
                                    {k: jnp.asarray(v)
                                     for k, v in batch.items()})
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.3
    assert np.isfinite(losses).all()


def test_grad_accumulation_matches_big_batch():
    cfg, model = tiny_model()
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                     cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0,
                                     cfg.vocab),
    }
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    s1 = jax.jit(build_train_step(model, opt_cfg, None, accum_steps=1))
    s4 = jax.jit(build_train_step(model, opt_cfg, None, accum_steps=4))
    p1, _, _ = s1(params, opt, batch)
    p4, _, _ = s4(params, init_opt_state(params), batch)
    diffs = [float(jnp.max(jnp.abs(a - b)))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4))]
    assert max(diffs) < 5e-3  # same update up to microbatch loss-mean jitter


def test_checkpoint_roundtrip_and_md5(sector):
    m, c, daemon = sector
    cfg, model = tiny_model()
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    ck = SectorCheckpointer(c, "/ckpt/t", num_slices=4)
    ck.save(10, {"params": params, "opt": opt})
    daemon.run_until_stable()
    like = {"params": params, "opt": opt}
    restored, step = ck.restore(like)
    assert step == 10
    for a, b in zip(jax.tree.leaves(like), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_survives_slave_loss(sector):
    m, c, daemon = sector
    cfg, model = tiny_model()
    params, _ = model.init(jax.random.PRNGKey(0))
    ck = SectorCheckpointer(c, "/ckpt/t", num_slices=4)
    ck.save(5, params)
    daemon.run_until_stable()      # replication factor 2 reached
    # kill one slave holding a slice; download must use the replica
    slice_path = "/ckpt/t/step_00000005/slice.00000"
    victim = next(iter(m.lookup(slice_path).locations))
    m.slaves[victim].kill(wipe=True)
    restored, step = ck.restore(params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpoint(sector):
    m, c, daemon = sector
    cfg, model = tiny_model()
    params, _ = model.init(jax.random.PRNGKey(0))
    ck = SectorCheckpointer(c, "/ckpt/a", num_slices=2)
    ck.save(1, params, blocking=False)
    ck.wait()
    assert ck.list_steps() == [1]


def test_checkpoint_gc_keeps_last(sector):
    m, c, daemon = sector
    cfg, model = tiny_model()
    params, _ = model.init(jax.random.PRNGKey(0))
    ck = SectorCheckpointer(c, "/ckpt/g", num_slices=2, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, params)
    assert ck.list_steps() == [3, 4]


def test_pipeline_locality_and_failover(sector):
    m, c, daemon = sector
    cfg, model = tiny_model()
    toks = synthetic_tokens(30_000, cfg.vocab)
    upload_token_dataset(c, "/corpus/f", toks, num_slices=4)
    daemon.run_until_stable()
    pipe = SectorDataPipeline(m, c, "/corpus/f", batch=4, seq_len=32,
                              host_id=0, num_hosts=2)
    b0 = next(iter(pipe))
    assert b0["tokens"].shape == (4, 32)
    # tokens/labels are shifted views of the same stream
    np.testing.assert_array_equal(b0["tokens"][:, 1:], b0["labels"][:, :-1])
    # kill a slave: the pipeline keeps reading via replicas
    victim = list(m.slaves)[0]
    m.slaves[victim].kill()
    count = sum(1 for _ in pipe)
    assert count > 0


def test_lr_schedule_and_clipping():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_ratio=0.1, grad_clip=1.0)
    assert float(lr_schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(lr_schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr_schedule(cfg, jnp.asarray(110))) == pytest.approx(0.1)
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 100.0)}
    opt = init_opt_state(params)
    _, _, metrics = adamw_update(cfg, params, grads, opt)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_zero1_specs_shards_largest_replicated_dim():
    from jax.sharding import PartitionSpec as P
    specs = {"emb": P("model", None), "w": P(None, "model")}
    shapes = {"emb": jax.ShapeDtypeStruct((64, 32), jnp.float32),
              "w": jax.ShapeDtypeStruct((32, 64), jnp.float32)}
    out = zero1_specs(specs, shapes, ("data",), {"data": 8, "model": 4})
    assert out["emb"] == P("model", "data")
    assert out["w"] == P("data", "model")


def test_bf16_params_with_fp32_master_trains():
    """bf16 weights + fp32 master: loss decreases and params stay bf16."""
    import dataclasses
    cfg, model = tiny_model()
    params, _ = model.init(jax.random.PRNGKey(0))
    params = jax.tree.map(lambda p: p.astype(jnp.bfloat16), params)
    opt = init_opt_state(params, master=True)
    assert "master" in opt
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=0, total_steps=30)
    step = jax.jit(build_train_step(model, opt_cfg, None))
    rng = np.random.default_rng(0)
    losses = []
    for i in range(30):
        batch = {
            "tokens": jnp.asarray(rng.integers(0, 64, (8, 32)), jnp.int32),
        }
        batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert all(p.dtype == jnp.bfloat16 for p in jax.tree.leaves(params))
    assert all(w.dtype == jnp.float32
               for w in jax.tree.leaves(opt["master"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
