"""End-to-end behaviour tests for the full Sector/Sphere system (paper §3.1
pseudo-code, §3.6 inverted index, and checkpoint-restart training)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import synthetic_tokens, upload_token_dataset, \
    SectorDataPipeline
from repro.models import build
from repro.sector import (Master, NodeAddress, ReplicationDaemon,
                          SectorClient, SecurityServer, SlaveNode, Topology)
from repro.sphere.engine import SphereProcess
from repro.sphere.spe import SPE
from repro.train.checkpoint import SectorCheckpointer
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.trainer import build_train_step


@pytest.fixture
def deployment(tmp_path):
    sec = SecurityServer()
    sec.add_user("u", "pw")
    sec.allow_slaves("10.0.0.0/8")
    m = Master(sec, replication_factor=2)
    topo = Topology(pods=1, racks=2, nodes_per_rack=3)
    for i, addr in enumerate(topo.all_addresses()):
        m.register_slave(SlaveNode(i, addr, str(tmp_path / f"s{i}"),
                                   ip=f"10.0.0.{i + 1}"))
    c = SectorClient(m, "u", "pw", client_addr=NodeAddress(0, 0, 0))
    return m, c, ReplicationDaemon(m)


def test_sphere_process_find_brown_dwarfs(deployment):
    """The paper's §3.1 example: apply findBrownDwarf to every 'image'
    record of a sliced dataset; one SPE crashes mid-run and its segments are
    re-executed elsewhere (no data loss, no duplicates)."""
    m, c, daemon = deployment
    rng = np.random.default_rng(0)
    record_bytes = 64
    slices = [rng.integers(0, 256, size=(50, record_bytes), dtype=np.uint8)
              for _ in range(4)]
    c.upload_dataset("/sdss/slice", [s.tobytes() for s in slices])
    daemon.run_until_stable()

    def find_brown_dwarf(records: np.ndarray) -> np.ndarray:
        return records[:, 0][records[:, 0] > 200]  # "detect" bright pixels

    # SPE 0 dies on its FIRST segment (locality assignment may give a
    # given SPE only one segment, so a later fail_after might never fire)
    spes = [SPE(i, m.slaves[i].address, m, c.session_id,
                fail_after=0 if i == 0 else None)
            for i in range(4)]
    proc = SphereProcess(m, c.session_id, spes)
    result = proc.run([f"/sdss/slice.{i:05d}" for i in range(4)],
                      find_brown_dwarf, record_bytes)
    assert not result.errors
    got = np.sort(result.concat())
    want = np.sort(np.concatenate([find_brown_dwarf(s) for s in slices]))
    np.testing.assert_array_equal(got, want)
    assert result.retries >= 1  # the crash was absorbed


def test_sphere_bucket_output_inverted_index(deployment):
    """§3.6: two-stage inverted index via buckets. Stage 1 hashes words to
    buckets; stage 2 aggregates per bucket."""
    m, c, daemon = deployment
    rng = np.random.default_rng(1)
    # "web pages": records of (word, page) uint8 pairs
    pages = [rng.integers(0, 26, size=(40, 2), dtype=np.uint8)
             for _ in range(3)]
    for i, p in enumerate(pages):
        p[:, 1] = i
    c.upload_dataset("/web/page", [p.tobytes() for p in pages])

    n_buckets = 4
    spes = [SPE(i, m.slaves[i].address, m, c.session_id) for i in range(3)]
    proc = SphereProcess(m, c.session_id, spes)

    def extract(records):
        return records.reshape(-1, 2)

    def bucket_fn(out):
        return {b: out[out[:, 0] % n_buckets == b] for b in range(n_buckets)}

    stage1 = proc.run([f"/web/page.{i:05d}" for i in range(3)], extract,
                      record_bytes=2, bucket_fn=bucket_fn,
                      num_buckets=n_buckets)
    # stage 2: per-bucket aggregation into word -> sorted page list
    index = {}
    for b, recs in stage1.outputs.items():
        recs = recs.reshape(-1, 2)
        for w in np.unique(recs[:, 0]):
            index[int(w)] = sorted(set(recs[recs[:, 0] == w][:, 1].tolist()))
    want = {}
    for i, p in enumerate(pages):
        for w in p[:, 0]:
            want.setdefault(int(w), set()).add(i)
    assert index == {k: sorted(v) for k, v in want.items()}


def test_train_checkpoint_restart_continuity(deployment):
    """Kill the 'job' mid-training, restore from Sector, verify bitwise
    state continuity (same loss trajectory after restart)."""
    m, c, daemon = deployment
    cfg = get_smoke_config("tinyllama_1_1b")
    model = build(cfg)
    toks = synthetic_tokens(40_000, cfg.vocab)
    upload_token_dataset(c, "/corpus/ckpt", toks, num_slices=4)
    pipe = SectorDataPipeline(m, c, "/corpus/ckpt", batch=4, seq_len=32,
                              seed=7)
    batches = [b for _, b in zip(range(20), iter(pipe))]
    assert len(batches) == 20
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(build_train_step(model, AdamWConfig(lr=1e-3,
                                                       warmup_steps=0,
                                                       total_steps=20), None))
    ck = SectorCheckpointer(c, "/ckpt/job", num_slices=4)

    ref_losses = []
    for i, b in enumerate(batches):
        jb = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, metrics = step(params, opt, jb)
        ref_losses.append(float(metrics["loss"]))
        if i == 9:
            ck.save(10, {"params": params, "opt": opt})
            daemon.run_until_stable()

    # "crash": throw everything away, restore, replay the tail
    like = {"params": params, "opt": opt}
    restored, s = ck.restore(like)
    assert s == 10
    p2, o2 = restored["params"], restored["opt"]
    for i, b in enumerate(batches[10:]):
        jb = {k: jnp.asarray(v) for k, v in b.items()}
        p2, o2, metrics = step(p2, o2, jb)
        assert float(metrics["loss"]) == pytest.approx(
            ref_losses[10 + i], rel=1e-5), i
