"""SPMD tests (shuffle / terasort / mapreduce / MoE sphere dispatch / elastic
re-mesh) on 8 virtual CPU devices.

These run in subprocesses because --xla_force_host_platform_device_count must
be set before jax initializes, and the rest of the suite must see 1 device.
"""

import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_spmd(code: str, devices: int = 8, timeout: int = 520):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    return proc.stdout


PRELUDE = """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
"""


def test_terasort_global_sort_and_permutation():
    run_spmd(PRELUDE + """
from repro.core.sort import terasort, is_globally_sorted
N = 8 * 2048
keys = rng.integers(0, 2**31 - 2, size=N).astype(np.int32)
payload = np.arange(N, dtype=np.int32)
kd = jax.device_put(jnp.asarray(keys), NamedSharding(mesh, P("data")))
pd = jax.device_put(jnp.asarray(payload), NamedSharding(mesh, P("data")))
with mesh:
    res = terasort(kd, pd, mesh, use_pallas=True)
assert int(res.dropped) == 0
assert is_globally_sorted(res, 8)
vk = np.asarray(res.keys)[np.asarray(res.valid)]
vp = np.asarray(res.payload)[np.asarray(res.valid)]
assert len(vk) == N
assert (keys[vp] == vk).all()          # payload association intact
assert (np.sort(vk) == np.sort(keys)).all()   # permutation
""")


def test_terasort_segmented_stage2_buckets_per_device():
    """With several buckets per device, stage 2 regroups bucket-major via
    the fused partition and sorts bpd independent segments — the result
    must still be a globally sorted permutation with zero drops (uniform
    keys, capacity_factor headroom)."""
    run_spmd(PRELUDE + """
from repro.core.sort import terasort, is_globally_sorted
N = 8 * 2048
keys = rng.integers(0, 2**31 - 2, size=N).astype(np.int32)
payload = np.arange(N, dtype=np.int32)
kd = jax.device_put(jnp.asarray(keys), NamedSharding(mesh, P("data")))
pd = jax.device_put(jnp.asarray(payload), NamedSharding(mesh, P("data")))
for use_pallas in (True, False):
    with mesh:
        res = terasort(kd, pd, mesh, use_pallas=use_pallas,
                       buckets_per_device=4)
    assert int(res.dropped) == 0
    assert is_globally_sorted(res, 8)
    vk = np.asarray(res.keys)[np.asarray(res.valid)]
    vp = np.asarray(res.payload)[np.asarray(res.valid)]
    assert len(vk) == N
    assert (keys[vp] == vk).all()
    assert (np.sort(vk) == np.sort(keys)).all()
""")


def test_hadoop_baseline_matches_terasort_output():
    run_spmd(PRELUDE + """
from repro.core.sort import terasort, hadoop_style_sort
N = 8 * 1024
keys = rng.integers(0, 2**31 - 2, size=N).astype(np.int32)
payload = np.arange(N, dtype=np.int32)
kd = jax.device_put(jnp.asarray(keys), NamedSharding(mesh, P("data")))
pd = jax.device_put(jnp.asarray(payload), NamedSharding(mesh, P("data")))
with mesh:
    a = terasort(kd, pd, mesh, use_pallas=False)
    b = hadoop_style_sort(kd, pd, mesh)
    c = hadoop_style_sort(kd, pd, mesh, use_pallas=True)
ka = np.asarray(a.keys)[np.asarray(a.valid)]
kb = np.asarray(b.keys)[np.asarray(b.valid)]
kc = np.asarray(c.keys)[np.asarray(c.valid)]
assert (ka == kb).all()
assert (ka == kc).all()        # use_pallas is honored, not dead
""")


def test_sphere_shuffle_invariants():
    run_spmd(PRELUDE + """
from repro.core.shuffle import sphere_shuffle
from repro.compat import shard_map
N = 8 * 512
data = rng.integers(0, 1000, size=(N, 3)).astype(np.int32)
buckets = rng.integers(0, 16, size=N).astype(np.int32)
dd = jax.device_put(jnp.asarray(data), NamedSharding(mesh, P("data")))
bd = jax.device_put(jnp.asarray(buckets), NamedSharding(mesh, P("data")))
def udf(d, b):
    res = sphere_shuffle(d, b.reshape(-1), 16, 256, "data")
    return (res.data.reshape(-1, 3), res.valid.reshape(-1),
            res.bucket.reshape(-1), res.dropped)
with mesh:
    rd, rv, rb, dropped = shard_map(
        udf, mesh=mesh, in_specs=(P("data"), P("data")),
        out_specs=(P("data"), P("data"), P("data"), P()), check_vma=False)(dd, bd)
rd, rv, rb = np.asarray(rd), np.asarray(rv), np.asarray(rb)
assert int(dropped) == 0
# every record delivered exactly once
got = sorted(map(tuple, rd[rv]))
want = sorted(map(tuple, data))
assert got == want
# delivered to the right device: bucket b lives on device b // 2
per_dev = rb.reshape(8, -1)
vv = rv.reshape(8, -1)
for d in range(8):
    bs = per_dev[d][vv[d]]
    assert ((bs // 2) == d).all()
""")


def test_moe_sphere_matches_dense_dispatch():
    run_spmd(PRELUDE + """
import dataclasses
from repro.configs import get_smoke_config
from repro.models import moe as moe_mod
mesh2 = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_smoke_config("qwen3_moe_30b_a3b")
cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops -> exact match
key = jax.random.PRNGKey(0)
params, _ = moe_mod.moe_init(key, cfg, tp=4)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.bfloat16)
with mesh2:
    xs = jax.device_put(x, NamedSharding(mesh2, P("data", None, None)))
    out_s, aux_s = moe_mod.moe_apply_sphere(params, xs, cfg, mesh2, ("data",))
out_d, aux_d = moe_mod.moe_apply_dense(params, x, cfg)
err = float(jnp.max(jnp.abs(out_s.astype(jnp.float32) - out_d.astype(jnp.float32))))
assert int(aux_s["moe_dropped"]) == 0, aux_s
print("moe sphere-vs-dense max err:", err)
# sphere path ships tokens+probs in bf16 (EXPERIMENTS §Perf H4) while dense
# keeps f32 probs -> ~1-2% relative difference on O(1) outputs
assert err < 0.3, err
""")


def test_elastic_remesh_roundtrip():
    run_spmd(PRELUDE + """
from repro.configs import get_smoke_config
from repro.models import build
from repro.train.elastic import remesh
from repro.train.trainer import init_train_state
cfg = get_smoke_config("tinyllama_1_1b")
model = build(cfg)
_, specs = model.init(jax.random.PRNGKey(1))
mesh8 = jax.make_mesh((4, 2), ("data", "model"))
params, opt = init_train_state(model, jax.random.PRNGKey(0), mesh8, specs)
# "lose half the cluster": re-mesh onto 4 devices
import numpy as np
mesh4 = jax.sharding.Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                          ("data", "model"))
p2 = remesh(params, mesh4, specs)
a = jax.tree.leaves(params)[0]
b = jax.tree.leaves(p2)[0]
assert (np.asarray(a) == np.asarray(b)).all()
batch = {"tokens": jnp.ones((4, 16), jnp.int32),
         "labels": jnp.ones((4, 16), jnp.int32)}
with mesh4:
    loss, _ = model.train_loss(p2, batch)
assert bool(jnp.isfinite(loss))
print("remesh ok, loss", float(loss))
""")


def test_mapreduce_wordcount():
    run_spmd(PRELUDE + """
from repro.core.mapreduce import map_reduce, reduce_by_key_sum
import collections
words = rng.integers(0, 50, size=8 * 256).astype(np.int32)
wd = jax.device_put(jnp.asarray(words), NamedSharding(mesh, P("data")))
with mesh:
    k, v, valid, dropped = map_reduce(lambda s: (s, jnp.ones_like(s)),
                                      reduce_by_key_sum, wd, mesh)
k, v, valid = np.asarray(k), np.asarray(v), np.asarray(valid)
got = {int(a): int(b) for a, b, ok in zip(k, v, valid) if ok and a >= 0}
assert got == dict(collections.Counter(words.tolist()))
assert int(dropped) == 0
""")
