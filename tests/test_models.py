"""Per-arch smoke tests (reduced configs) + decode/prefill consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, SHAPES
from repro.models import build
from repro.models.transformer import lm_forward

KEY = jax.random.PRNGKey(0)


def smoke_batch(cfg, B=2, S=16):
    t = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": t, "labels": jnp.roll(t, -1, axis=1)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(
            KEY, (B, cfg.img_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one grad step on the reduced config: shapes + finite."""
    cfg = get_smoke_config(arch)
    model = build(cfg)
    params, specs = model.init(KEY)
    # specs tree mirrors params tree
    assert (jax.tree.structure(jax.tree.map(lambda x: 0, params)) ==
            jax.tree.structure(jax.tree.map(
                lambda x: 0, specs, is_leaf=lambda x: not isinstance(x, dict)
                and not isinstance(x, list))))
    batch = smoke_batch(cfg)
    loss, metrics = model.train_loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    grads = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full (published) config matches the assigned table."""
    cfg = get_config(arch)
    table = {
        "minicpm3_4b": (62, 2560, 40, 40, 6400, 73448),
        "h2o_danube_1_8b": (24, 2560, 32, 8, 6912, 32000),
        "granite_34b": (88, 6144, 48, 1, 24576, 49152),
        "tinyllama_1_1b": (22, 2048, 32, 4, 5632, 32000),
        "qwen3_moe_30b_a3b": (48, 2048, 32, 4, 768, 151936),
        "qwen2_moe_a2_7b": (24, 2048, 16, 16, 1408, 151936),
        "whisper_small": (12, 768, 12, 12, 3072, 51865),
        "xlstm_125m": (12, 768, 4, 4, 0, 50304),
        "internvl2_1b": (24, 896, 14, 2, 4864, 151655),
        "zamba2_1_2b": (38, 2048, 32, 32, 8192, 32000),
    }[arch]
    L, d, h, kv, ff, v = table
    assert cfg.num_layers == L and cfg.d_model == d
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert (cfg.d_ff or cfg.expert_d_ff) == ff or ff == 0
    assert cfg.vocab == v
    if arch == "qwen3_moe_30b_a3b":
        assert cfg.num_experts == 128 and cfg.top_k == 8
    if arch == "qwen2_moe_a2_7b":
        assert cfg.num_experts == 60 and cfg.top_k == 4
        assert cfg.n_shared_experts == 4
    if arch == "zamba2_1_2b":
        assert cfg.ssm_state == 64


@pytest.mark.parametrize("arch", ["tinyllama_1_1b", "minicpm3_4b",
                                  "h2o_danube_1_8b", "zamba2_1_2b",
                                  "xlstm_125m", "qwen2_moe_a2_7b"])
def test_decode_matches_prefill(arch):
    import dataclasses
    cfg = get_smoke_config(arch)
    if cfg.is_moe:
        # capacity drops depend on the token count per dispatch; use a
        # no-drop capacity so prefill and decode see identical expert sets
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    model = build(cfg)
    params, _ = model.init(KEY)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full_logits, _, _ = lm_forward(params, cfg, toks)
    caches = model.init_caches(B, S)
    outs = []
    for t in range(S):
        lg, caches = model.decode_step(
            params, caches,
            {"tokens": toks[:, t:t + 1],
             "pos": jnp.full((B, 1), t, jnp.int32)})
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - full_logits)))
    assert err < 0.25, (arch, err)


def test_swa_ring_buffer_window():
    """SWA decode cache is O(window): positions beyond the window are
    overwritten and masked out."""
    cfg = get_smoke_config("h2o_danube_1_8b")  # window 16
    model = build(cfg)
    params, _ = model.init(KEY)
    B, S = 1, 40  # > 2x window
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    caches = model.init_caches(B, max_len=S)
    # ring buffer: cache length is window, not S
    leaf = jax.tree.leaves(caches)[0]
    assert cfg.window in leaf.shape
    for t in range(S):
        lg, caches = model.decode_step(
            params, caches, {"tokens": toks[:, t:t + 1],
                             "pos": jnp.full((B, 1), t, jnp.int32)})
    assert bool(jnp.isfinite(lg).all())


def test_mla_absorb_matches_naive():
    from repro.models import attention as attn
    cfg = get_smoke_config("minicpm3_4b")
    params, _ = attn.attn_init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model),
                          jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (2, 8))
    naive, _ = attn.mla_apply(params, x, cfg, pos, absorb=False)
    absorbed, _ = attn.mla_apply(params, x, cfg, pos, absorb=True)
    err = float(jnp.max(jnp.abs(naive.astype(jnp.float32)
                                - absorbed.astype(jnp.float32))))
    assert err < 0.1, err


def test_moe_dense_capacity_drops_are_counted():
    import dataclasses
    from repro.models import moe as moe_mod
    cfg = dataclasses.replace(get_smoke_config("qwen3_moe_30b_a3b"),
                              capacity_factor=0.1)
    params, _ = moe_mod.moe_init(KEY, cfg, tp=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.bfloat16)
    out, aux = moe_mod.moe_apply_dense(params, x, cfg)
    assert out.shape == x.shape
    assert float(aux["moe_dropped"]) > 0     # tight capacity must drop


def test_runnable_shapes_long_context_gating():
    subquad = {"h2o_danube_1_8b", "xlstm_125m", "zamba2_1_2b"}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shapes = set(cfg.runnable_shapes())
        if arch in subquad:
            assert "long_500k" in shapes, arch
        else:
            assert "long_500k" not in shapes, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_runnable_shapes(arch):
    cfg = get_config(arch)
    model = build(cfg)
    for shape in cfg.runnable_shapes():
        specs = model.input_specs(shape)
        assert specs, (arch, shape)
        bspecs = model.batch_specs(shape, dp=("data",))
        assert set(bspecs) == set(specs)
        sp = SHAPES[shape]
        for k, sds in specs.items():
            assert sds.shape[0] == sp.global_batch


def test_whisper_decode_matches_teacher_forcing():
    """Enc-dec decode path: step-by-step decoder with self-attn cache equals
    the teacher-forced decoder stack."""
    from repro.models import encdec
    cfg = get_smoke_config("whisper_small")
    model = build(cfg)
    params, _ = model.init(KEY)
    B, S = 2, 10
    frames = jax.random.normal(KEY, (B, cfg.enc_seq, cfg.d_model),
                               jnp.bfloat16)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    enc_out = encdec.encode(params, cfg, frames)
    full_logits, _ = encdec.decode_stack(params, cfg, toks, enc_out)
    caches = model.init_caches(B, S)
    outs = []
    for t in range(S):
        lg, caches = model.decode_step(
            params, caches,
            {"tokens": toks[:, t:t + 1],
             "pos": jnp.full((B, 1), t, jnp.int32), "enc_out": enc_out})
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - full_logits)))
    assert err < 0.25, err


def test_sampled_splitters_balance_skewed_keys():
    """Paper §3.6 'more advanced hashing': sampled splitters balance a
    skewed key distribution far better than uniform range splitters."""
    import os as _os
    import subprocess, sys
    code = """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.sort import sampled_splitters, uniform_splitters
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
keys = (rng.gamma(2.0, 1e7, size=8 * 2048)).astype(np.int32)  # skewed low
kd = jax.device_put(jnp.asarray(keys), NamedSharding(mesh, P("data")))
with mesh:
    spl = np.asarray(sampled_splitters(kd, 8, 256, mesh))
uni = np.asarray(uniform_splitters(8))
def imbalance(s):
    b = np.searchsorted(s, keys)
    counts = np.bincount(b, minlength=8)
    return counts.max() / max(counts.mean(), 1)
print("RESULT", imbalance(spl), imbalance(uni))
assert imbalance(spl) < 1.5 < imbalance(uni)
"""
    env = dict(_os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _os.path.join(_os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=520)
    assert r.returncode == 0, r.stdout + r.stderr
