"""One-wire-tensor shuffle hops (WireFrame framing + chunked exchange).

Covers the ISSUE-5 acceptance surface:

- host-side WireFrame row/tile codec round-trips across dtypes;
- jaxpr-inspection: exactly 1 ``all_to_all`` per flat shuffle hop, 2 per
  hierarchical hop (shuffle and combine each), × chunks;
- delivery bit-identical to the retired multi-collective (4-tensor) path
  across dtypes, skew, and drop cases;
- the chunked (W=4) exchange delivers the same multiset as W=1 and
  conserves the drop accounting;
- ShufflePlan wire/frame geometry (chunks, recv_slots, wan_profile frame
  accounting).

SPMD tests run in subprocesses on 8 virtual CPU devices (see test_spmd.py).
"""

import sys

import numpy as np
import pytest

from test_spmd import SRC, run_spmd

from repro.core.records import WireFrame


# -- host-side WireFrame codec -------------------------------------------------


@pytest.mark.parametrize("dtype,shape", [
    ("int32", (3,)), ("float32", (4,)), ("uint8", (5,)), ("int16", ()),
    ("bool", (2,)),
])
def test_frame_rows_roundtrip(dtype, shape):
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    n = 17
    if dtype == "bool":
        payload = rng.random((n,) + shape) > 0.5
    elif dtype == "float32":
        payload = rng.random((n,) + shape).astype(dtype)
    else:
        info = np.iinfo(dtype)
        payload = rng.integers(info.min, int(info.max) + 1,
                               size=(n,) + shape).astype(dtype)
    bucket = rng.integers(0, 1 << 20, n).astype(np.int32)
    src = np.arange(n, dtype=np.int32)
    frame = WireFrame.for_payload(jnp.asarray(payload),
                                  meta=("bucket", "src"))
    rows = frame.frame_rows(jnp.asarray(payload), bucket=bucket, src=src)
    assert rows.shape == (n, frame.row_nbytes)
    pay, valid, metas = frame.open_rows(rows)
    assert valid is None
    np.testing.assert_array_equal(np.asarray(pay), payload)
    np.testing.assert_array_equal(np.asarray(metas["bucket"]), bucket)
    np.testing.assert_array_equal(np.asarray(metas["src"]), src)


def test_frame_explicit_valid_zeroes_invalid_rows():
    import jax.numpy as jnp
    rng = np.random.default_rng(1)
    payload = rng.random((8, 3)).astype(np.float32)
    valid = np.array([1, 0, 1, 1, 0, 1, 0, 1], bool)
    src = np.arange(8, dtype=np.int32)
    frame = WireFrame.for_payload(jnp.asarray(payload), meta=("src",),
                                  explicit_valid=True)
    rows = np.asarray(frame.frame_rows(jnp.asarray(payload),
                                       valid=jnp.asarray(valid), src=src))
    assert (rows[~valid] == 0).all(), "invalid rows must not leak bytes"
    pay, v, metas = frame.open_rows(jnp.asarray(rows))
    np.testing.assert_array_equal(np.asarray(v), valid)
    np.testing.assert_array_equal(np.asarray(pay)[valid], payload[valid])
    np.testing.assert_array_equal(np.asarray(metas["src"])[valid], src[valid])


def test_frame_seal_open_counts():
    """seal/open carry per-tile counts through the wire: valid is the
    prefix mask, clamped against corrupt counts."""
    import jax.numpy as jnp
    rng = np.random.default_rng(2)
    d, cap = 4, 6
    frame = WireFrame("int32", (2,))
    tiles = jnp.asarray(rng.integers(0, 255, (d, cap, frame.row_nbytes))
                        .astype(np.uint8))
    counts = jnp.asarray([0, 3, 6, 99], jnp.int32)   # 99 -> clamped to cap
    wire = frame.seal(tiles, counts)
    assert wire.shape == (d, cap + 1, frame.row_nbytes)
    _, valid, _ = frame.open(wire)
    np.testing.assert_array_equal(np.asarray(valid).sum(axis=1),
                                  [0, 3, 6, cap])


def test_frame_geometry_and_validation():
    # rows pad to the count header width in positional mode
    assert WireFrame("uint8", ()).row_nbytes == 4
    assert WireFrame("uint8", (), explicit_valid=True).row_nbytes == 2
    f = WireFrame("int32", (2,), meta=("bucket", "src"))
    assert f.row_nbytes == 8 + 8
    assert f.tile_nbytes(10) == 11 * 16        # + count header row
    fe = WireFrame("int32", (2,), meta=("src",), explicit_valid=True)
    assert fe.row_nbytes == 1 + 4 + 8
    assert fe.tile_nbytes(10) == 10 * 13       # no header row
    import jax.numpy as jnp
    with pytest.raises(ValueError):
        f.frame_rows(jnp.zeros((3, 2), jnp.int32))          # missing meta
    with pytest.raises(ValueError):
        fe.frame_rows(jnp.zeros((3, 2), jnp.int32),
                      src=jnp.zeros(3, jnp.int32))          # missing valid
    with pytest.raises(ValueError):
        f.open_rows(jnp.zeros((3, 5), jnp.uint8))           # wrong width
    with pytest.raises(ValueError):
        fe.seal(jnp.zeros((2, 4, 13), jnp.uint8), jnp.zeros(2, jnp.int32))


def test_plan_chunk_geometry():
    sys.path.insert(0, SRC)
    from repro.core.shuffle import ShufflePlan

    p = ShufflePlan(num_buckets=16, axes=("data",), shape=(8,),
                    capacities=(10,), chunks=4)
    assert p.stage_slots(0) == 4 * 3           # ceil(10/4)=3 per chunk
    assert p.recv_slots == 8 * 12
    h = ShufflePlan(num_buckets=16, axes=("dc", "node"), shape=(2, 4),
                    capacities=(24, 40), chunks=1)
    assert h.recv_slots == 2 * 40
    with pytest.raises(ValueError):
        ShufflePlan(num_buckets=16, axes=("data",), shape=(8,),
                    capacities=(10,), chunks=0)


def test_wan_profile_frame_accounting():
    sys.path.insert(0, SRC)
    from repro.core.shuffle import ShufflePlan

    flat = ShufflePlan(num_buckets=8, axes=("w",), shape=(8,),
                       capacities=(100,))
    p = flat.wan_profile(2, 4, rec_bytes=8)
    # legacy = data + valid + bucket + src; fused(min) = payload + count row
    assert p["wan_legacy_bytes"] == p["wan_tiles"] * 100 * 17
    pm = flat.wan_profile(2, 4, rec_bytes=8, wire_meta="min")
    assert pm["wan_frame_bytes"] == p["wan_tiles"] * 101 * 8
    assert p["wan_legacy_bytes"] / pm["wan_frame_bytes"] > 2.0
    # chunked rounds: W tiles of ceil(cap/W)+1 rows each
    flat4 = ShufflePlan(num_buckets=8, axes=("w",), shape=(8,),
                        capacities=(100,), chunks=4)
    p4 = flat4.wan_profile(2, 4, rec_bytes=8, wire_meta="min")
    assert p4["wan_rounds"] == 4
    assert p4["wan_frame_bytes"] == p["wan_tiles"] * 4 * 26 * 8
    # hierarchical full meta carries bucket+src+pos and the legacy path
    # shipped 5 tensors
    hier = ShufflePlan(num_buckets=8, axes=("d", "n"), shape=(2, 4),
                       capacities=(50, 100))
    ph = hier.wan_profile(2, 4, rec_bytes=8)
    assert ph["wan_legacy_bytes"] == ph["wan_tiles"] * 100 * 21
    assert ph["wan_frame_bytes"] == ph["wan_tiles"] * 101 * 20
    with pytest.raises(ValueError):
        hier.wan_profile(2, 4, rec_bytes=8, wire_meta="bogus")


# -- SPMD (subprocess) ---------------------------------------------------------


PRELUDE = """
import dataclasses
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import shard_map
from repro.core.shuffle import ShufflePlan
from repro.kernels import ops as kops
mesh1 = jax.make_mesh((8,), ("data",))
mesh2 = jax.make_mesh((2, 4), ("dc", "node"))
rng = np.random.default_rng(0)


def legacy_sphere_shuffle(data, bucket_ids, num_buckets, capacity, axis_name):
    \"\"\"The retired multi-collective path: four separate all_to_all
    (data/valid/bucket/src), kept verbatim as the equivalence oracle.\"\"\"
    axis_size = 8
    bpd = num_buckets // axis_size
    a2a = lambda x: jax.lax.all_to_all(x, axis_name, split_axis=0,
                                       concat_axis=0, tiled=True)
    ids = bucket_ids.astype(jnp.int32)
    ok = (ids >= 0) & (ids < num_buckets)
    dest = jnp.where(ok, ids // bpd, axis_size)
    (send_data, send_ids), in_range, origin, dropped_local = \\
        kops.partition_pack([data, ids], dest, axis_size, capacity)
    send_bucket = jnp.where(in_range, send_ids, -1)
    send_src = jnp.where(in_range, origin, -1)
    return (a2a(send_data), a2a(in_range), a2a(send_bucket), a2a(send_src),
            jax.lax.psum(dropped_local, axis_name))


def run_flat(plan, data, buckets):
    spec = P("data")
    dd = jax.device_put(jnp.asarray(data), NamedSharding(mesh1, spec))
    bd = jax.device_put(jnp.asarray(buckets), NamedSharding(mesh1, spec))
    def udf(d, b):
        r = plan.shuffle(d, b.reshape(-1))
        return r.data, r.valid, r.bucket, r.src_pos, r.dropped
    with mesh1:
        out = shard_map(udf, mesh=mesh1, in_specs=(spec, spec),
                        out_specs=(spec,) * 4 + (P(),),
                        check_vma=False)(dd, bd)
    return [np.asarray(o) for o in out]


def run_legacy(num_buckets, capacity, data, buckets):
    spec = P("data")
    dd = jax.device_put(jnp.asarray(data), NamedSharding(mesh1, spec))
    bd = jax.device_put(jnp.asarray(buckets), NamedSharding(mesh1, spec))
    def udf(d, b):
        return legacy_sphere_shuffle(d, b.reshape(-1), num_buckets,
                                     capacity, "data")
    with mesh1:
        out = shard_map(udf, mesh=mesh1, in_specs=(spec, spec),
                        out_specs=(spec,) * 4 + (P(),),
                        check_vma=False)(dd, bd)
    return [np.asarray(o) for o in out]
"""


def test_collective_counts_per_hop():
    """Acceptance: exactly 1 all_to_all per flat hop, 2 per hierarchical
    hop, for shuffle and combine each — and chunks=W multiplies the shuffle
    counts by W."""
    run_spmd(PRELUDE + """
from repro.core.introspect import collective_counts
N = 8 * 512
n_local = N // 8
d0 = jnp.zeros((N, 3), jnp.int32)
b0 = jnp.zeros((N,), jnp.int32)
flat = ShufflePlan.for_mesh(mesh1, 16, n_local, 2.5, ("data",))
hier = ShufflePlan.for_mesh(mesh2, 16, n_local, 2.5, ("dc", "node"))

def shuffle_only(plan):
    def f(d, b):
        r = plan.shuffle(d, b.reshape(-1))
        return r.data, r.valid, r.dropped
    return f

def shuffle_combine(plan):
    def f(d, b):
        r = plan.shuffle(d, b.reshape(-1))
        return plan.combine(r.data.astype(jnp.float32) * 2.0, r, n_local)
    return f

def a2a_count(fn, mesh, spec, outs):
    f = shard_map(fn, mesh=mesh, in_specs=(spec, spec), out_specs=outs,
                  check_vma=False)
    return collective_counts(f, d0, b0)["all_to_all"]

s1, s2 = P("data"), P(("dc", "node"))
assert a2a_count(shuffle_only(flat), mesh1, s1, (s1, s1, P())) == 1
assert a2a_count(shuffle_only(hier), mesh2, s2, (s2, s2, P())) == 2
for w in (2, 4):
    fw = dataclasses.replace(flat, chunks=w)
    hw = dataclasses.replace(hier, chunks=w)
    assert a2a_count(shuffle_only(fw), mesh1, s1, (s1, s1, P())) == w
    assert a2a_count(shuffle_only(hw), mesh2, s2, (s2, s2, P())) == 2 * w
# shuffle + combine: flat 1+1, hier 2+2
assert a2a_count(shuffle_combine(flat), mesh1, s1, (s1, s1)) == 2
assert a2a_count(shuffle_combine(hier), mesh2, s2, (s2, s2)) == 4
print("collective counts ok")
""")


def test_fused_matches_legacy_multicollective_path():
    """Acceptance: the one-tensor hop is bit-identical to the retired
    4-collective path — same valid mask, same data/bucket/src on every
    valid slot, same drop count — across dtypes, skew, and drop pressure."""
    run_spmd(PRELUDE + """
N = 8 * 512
cases = []
# uniform int32 records, no pressure
b = rng.integers(0, 16, size=N).astype(np.int32)
cases.append(("uniform_i32",
              rng.integers(0, 1000, (N, 3)).astype(np.int32), b, 256))
# float32 payload rides the same byte frame
cases.append(("uniform_f32",
              rng.standard_normal((N, 4)).astype(np.float32), b, 256))
# invalid ids (emit-nothing) sprinkled in
b2 = b.copy(); b2[rng.random(N) < 0.1] = -1
cases.append(("padding", rng.integers(0, 1000, (N, 3)).astype(np.int32),
              b2, 256))
# heavy skew under capacity pressure -> drops, earliest-kept
b3 = np.where(rng.random(N) < 0.7, 3, b).astype(np.int32)
cases.append(("skew_drops", rng.integers(0, 1000, (N, 3)).astype(np.int32),
              b3, 64))
for name, data, buckets, cap in cases:
    plan = ShufflePlan(num_buckets=16, axes=("data",), shape=(8,),
                       capacities=(cap,))
    nd, nv, nb_, ns, ndrop = run_flat(plan, data, buckets)
    ld, lv, lb, ls, ldrop = run_legacy(16, cap, data, buckets)
    assert int(ndrop) == int(ldrop), (name, int(ndrop), int(ldrop))
    np.testing.assert_array_equal(nv, lv.reshape(nv.shape), err_msg=name)
    m = nv  # compare only real slots (empty slots hold zeros vs garbage)
    np.testing.assert_array_equal(nd[m], ld.reshape(nd.shape)[m],
                                  err_msg=name)
    np.testing.assert_array_equal(nb_[m], lb.reshape(nb_.shape)[m],
                                  err_msg=name)
    np.testing.assert_array_equal(ns[m], ls.reshape(ns.shape)[m],
                                  err_msg=name)
    print(name, "ok, dropped", int(ndrop))
""")


def test_chunked_exchange_matches_unchunked():
    """W=4 delivers the identical multiset as W=1 (no pressure), conserves
    records under drop pressure, and the hierarchical chunked path still
    equals the flat delivery multiset."""
    run_spmd(PRELUDE + """
N = 8 * 512
data = rng.integers(0, 1 << 20, size=(N, 3)).astype(np.int32)
buckets = rng.integers(0, 16, size=N).astype(np.int32)
base = ShufflePlan.for_mesh(mesh1, 16, N // 8, 2.5, ("data",))

def multiset(d, v, b):
    d2 = d.reshape(-1, 3); v2 = v.reshape(-1); b2 = b.reshape(-1)
    return sorted(map(tuple, np.concatenate([b2[v2][:, None], d2[v2]], 1)))

d1, v1, b1, _, drop1 = run_flat(base, data, buckets)
ref = multiset(d1, v1, b1)
assert int(drop1) == 0 and len(ref) == N
for w in (2, 4):
    dw, vw, bw, _, dropw = run_flat(dataclasses.replace(base, chunks=w),
                                    data, buckets)
    assert int(dropw) == 0
    assert multiset(dw, vw, bw) == ref, w

# hierarchical chunked == flat delivery
hier = dataclasses.replace(
    ShufflePlan.for_mesh(mesh2, 16, N // 8, 2.5, ("dc", "node")), chunks=2)
spec = P(("dc", "node"))
dd = jax.device_put(jnp.asarray(data), NamedSharding(mesh2, spec))
bd = jax.device_put(jnp.asarray(buckets), NamedSharding(mesh2, spec))
def udf(d, b):
    r = hier.shuffle(d, b.reshape(-1))
    return r.data, r.valid, r.bucket, r.dropped
with mesh2:
    hd, hv, hb, hdrop = shard_map(udf, mesh=mesh2, in_specs=(spec, spec),
                                  out_specs=(spec,) * 3 + (P(),),
                                  check_vma=False)(dd, bd)
hd, hv, hb = map(np.asarray, (hd, hv, hb))
assert int(hdrop) == 0
assert multiset(hd, hv, hb) == ref

# drop conservation under chunked capacity pressure
buckets3 = np.where(rng.random(N) < 0.7, 3, buckets).astype(np.int32)
tight = ShufflePlan(num_buckets=16, axes=("data",), shape=(8,),
                    capacities=(64,), chunks=4)
dt, vt, bt, _, dropt = run_flat(tight, data, buckets3)
assert int(dropt) > 0
assert int(vt.sum()) + int(dropt) == N
print("chunked ok")
""")


def test_chunked_combine_roundtrip_and_moe():
    """Combine still inverts a chunked shuffle, and the chunked MoE
    dispatch matches the dense reference."""
    run_spmd(PRELUDE + """
N = 8 * 256
n_local = N // 8
data = rng.standard_normal((N, 4)).astype(np.float32)
buckets = rng.integers(0, 16, size=N).astype(np.int32)
plan = dataclasses.replace(
    ShufflePlan.for_mesh(mesh2, 16, n_local, 2.5, ("dc", "node")), chunks=2)
spec = P(("dc", "node"))
dd = jax.device_put(jnp.asarray(data), NamedSharding(mesh2, spec))
bd = jax.device_put(jnp.asarray(buckets), NamedSharding(mesh2, spec))
def udf(d, b):
    r = plan.shuffle(d, b.reshape(-1))
    combined, hits = plan.combine(r.data * 3.0, r, n_local)
    return combined, hits, r.dropped
with mesh2:
    comb, hits, drop = shard_map(udf, mesh=mesh2, in_specs=(spec, spec),
                                 out_specs=(spec, spec, P()),
                                 check_vma=False)(dd, bd)
assert int(drop) == 0
assert (np.asarray(hits) == 1).all()
np.testing.assert_allclose(np.asarray(comb), data * 3.0, rtol=1e-6)

import dataclasses as dc
from repro.configs import get_smoke_config
from repro.models import moe as moe_mod
cfg = get_smoke_config("qwen3_moe_30b_a3b")
cfg = dc.replace(cfg, capacity_factor=8.0)
params, _ = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, tp=8)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                      jnp.bfloat16)
with mesh2:
    xs = jax.device_put(x, NamedSharding(mesh2, P("dc", "node", None)))
    out_h, aux_h = moe_mod.moe_apply_sphere(params, xs, cfg, mesh2, (),
                                            ep_axes=("dc", "node"), chunks=2)
out_d, aux_d = moe_mod.moe_apply_dense(params, x, cfg)
err = float(jnp.max(jnp.abs(out_h.astype(jnp.float32)
                            - out_d.astype(jnp.float32))))
assert int(aux_h["moe_dropped"]) == 0, aux_h
assert err < 0.3, err
print("chunked combine + moe ok, err", err)
""")


def test_wire_meta_min_ships_no_metadata():
    """wire_meta='min' (the dataflow executor's setting) returns bucket and
    src_pos as None and still delivers the full record multiset."""
    run_spmd(PRELUDE + """
N = 8 * 512
data = rng.integers(0, 1000, size=(N, 3)).astype(np.int32)
buckets = rng.integers(0, 16, size=N).astype(np.int32)
plan = ShufflePlan.for_mesh(mesh1, 16, N // 8, 2.5, ("data",))
spec = P("data")
dd = jax.device_put(jnp.asarray(data), NamedSharding(mesh1, spec))
bd = jax.device_put(jnp.asarray(buckets), NamedSharding(mesh1, spec))
def udf(d, b):
    r = plan.shuffle(d, b.reshape(-1), wire_meta="min")
    assert r.bucket is None and r.src_pos is None
    return r.data, r.valid, r.dropped
with mesh1:
    rd, rv, drop = shard_map(udf, mesh=mesh1, in_specs=(spec, spec),
                             out_specs=(spec, spec, P()),
                             check_vma=False)(dd, bd)
rd, rv = np.asarray(rd).reshape(-1, 3), np.asarray(rv).reshape(-1)
assert int(drop) == 0
assert sorted(map(tuple, rd[rv])) == sorted(map(tuple, data))
print("wire_meta=min ok")
""")
