"""Hierarchical (two-level, wide-area) shuffle: equivalence with the flat
path, drop accounting under capacity pressure, and the threaded consumers
(terasort over a (dc, node) mesh, wide-area MoE expert parallelism).

SPMD tests run in subprocesses on 8 virtual CPU devices (see test_spmd.py
for why); plan-geometry and WAN-model tests run host-side.
"""

import os
import sys

import pytest

from test_spmd import SRC, run_spmd

ROOT = os.path.join(os.path.dirname(__file__), "..")


PRELUDE = """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import shard_map
from repro.core.shuffle import ShufflePlan, sphere_shuffle
mesh1 = jax.make_mesh((8,), ("data",))
mesh2 = jax.make_mesh((2, 4), ("dc", "node"))
rng = np.random.default_rng(0)

def run_plan(mesh, spec, plan, data, buckets):
    dd = jax.device_put(jnp.asarray(data), NamedSharding(mesh, spec))
    bd = jax.device_put(jnp.asarray(buckets), NamedSharding(mesh, spec))
    def udf(d, b):
        r = plan.shuffle(d, b.reshape(-1))
        return (r.data.reshape(-1, 3), r.valid.reshape(-1),
                r.bucket.reshape(-1), r.dropped)
    with mesh:
        out = shard_map(udf, mesh=mesh, in_specs=(spec, spec),
                        out_specs=(spec, spec, spec, P()),
                        check_vma=False)(dd, bd)
    return [np.asarray(o) for o in out]
"""


def test_hier_delivery_multiset_equals_flat():
    """Acceptance property: a (dc=2, node=4) hierarchical shuffle delivers
    exactly the same multiset of (bucket, record) pairs as the flat 8-device
    shuffle, each record landing on its bucket's owner device."""
    run_spmd(PRELUDE + """
N = 8 * 512
data = rng.integers(0, 1000, size=(N, 3)).astype(np.int32)
buckets = rng.integers(0, 16, size=N).astype(np.int32)
flat_plan = ShufflePlan.for_mesh(mesh1, 16, N // 8, 2.5, ("data",))
hier_plan = ShufflePlan.for_mesh(mesh2, 16, N // 8, 2.5, ("dc", "node"))
fd, fv, fb, fdrop = run_plan(mesh1, P("data"), flat_plan, data, buckets)
hd, hv, hb, hdrop = run_plan(mesh2, P(("dc", "node")), hier_plan, data, buckets)
assert int(fdrop) == 0 and int(hdrop) == 0
flat_set = sorted(map(tuple, np.concatenate([fb[fv][:, None], fd[fv]], 1)))
hier_set = sorted(map(tuple, np.concatenate([hb[hv][:, None], hd[hv]], 1)))
assert len(flat_set) == N
assert flat_set == hier_set
# ownership: global device d = (dc, node) row-major owns buckets 2d, 2d+1
per = hb.reshape(8, -1); pv = hv.reshape(8, -1)
for d in range(8):
    bs = per[d][pv[d]]
    assert ((bs // 2) == d).all()
""")


def test_hier_drop_accounting_under_capacity_pressure():
    """Every record is either delivered once or counted dropped exactly once
    — across both stages, including records invalidated by out-of-range
    bucket ids (sent nowhere, dropped nowhere)."""
    run_spmd(PRELUDE + """
N = 8 * 512
data = rng.integers(0, 1000, size=(N, 3)).astype(np.int32)
buckets = rng.integers(0, 16, size=N).astype(np.int32)
buckets[rng.random(N) < 0.1] = -1                      # padding records
n_valid = int((buckets >= 0).sum())

for caps in [(2048, 40), (24, 2048), (24, 40)]:        # squeeze B, A, both
    plan = ShufflePlan(num_buckets=16, axes=("dc", "node"), shape=(2, 4),
                       capacities=caps)
    hd, hv, hb, hdrop = run_plan(mesh2, P(("dc", "node")), plan, data, buckets)
    delivered = int(hv.sum())
    assert int(hdrop) > 0, caps                        # pressure was real
    assert delivered + int(hdrop) == n_valid, (caps, delivered, int(hdrop))
    # delivered records still live on their owner device
    per = hb.reshape(8, -1); pv = hv.reshape(8, -1)
    for d in range(8):
        assert ((per[d][pv[d]] // 2) == d).all()

# flat baseline obeys the same conservation law
flat = ShufflePlan(num_buckets=16, axes=("data",), shape=(8,),
                   capacities=(40,))
fd, fv, fb, fdrop = run_plan(mesh1, P("data"), flat, data, buckets)
assert int(fv.sum()) + int(fdrop) == n_valid
""")


def test_hier_combine_roundtrip():
    """plan.combine inverts the two-level route: every processed record
    returns to its origin row exactly once."""
    run_spmd(PRELUDE + """
N = 8 * 256
n_local = N // 8
data = rng.standard_normal((N, 4)).astype(np.float32)
buckets = rng.integers(0, 16, size=N).astype(np.int32)
plan = ShufflePlan.for_mesh(mesh2, 16, n_local, 2.5, ("dc", "node"))
dd = jax.device_put(jnp.asarray(data), NamedSharding(mesh2, P(("dc", "node"))))
bd = jax.device_put(jnp.asarray(buckets), NamedSharding(mesh2, P(("dc", "node"))))
def udf(d, b):
    r = plan.shuffle(d, b.reshape(-1))
    combined, hits = plan.combine(r.data * 3.0, r, n_local)
    return combined, hits, r.dropped
with mesh2:
    comb, hits, drop = shard_map(
        udf, mesh=mesh2, in_specs=(P(("dc", "node")), P(("dc", "node"))),
        out_specs=(P(("dc", "node")), P(("dc", "node")), P()),
        check_vma=False)(dd, bd)
assert int(drop) == 0
assert (np.asarray(hits) == 1).all()
np.testing.assert_allclose(np.asarray(comb), data * 3.0, rtol=1e-6)

# under stage-B capacity pressure the flat-path contract must hold: a
# dropped record comes back with hits == 0 (not a silent zero with hits 1)
tight = ShufflePlan(num_buckets=16, axes=("dc", "node"), shape=(2, 4),
                    capacities=(2048, 40))
def udf2(d, b):
    r = tight.shuffle(d, b.reshape(-1))
    combined, hits = tight.combine(r.data * 3.0, r, n_local)
    return combined, hits, r.dropped
with mesh2:
    comb2, hits2, drop2 = shard_map(
        udf2, mesh=mesh2, in_specs=(P(("dc", "node")), P(("dc", "node"))),
        out_specs=(P(("dc", "node")), P(("dc", "node")), P()),
        check_vma=False)(dd, bd)
comb2, hits2 = np.asarray(comb2), np.asarray(hits2)
assert int(drop2) > 0
assert int(hits2.sum()) + int(drop2) == N
np.testing.assert_allclose(comb2[hits2 == 1], data[hits2 == 1] * 3.0,
                           rtol=1e-6)
assert (comb2[hits2 == 0] == 0).all()
""")


def test_hier_terasort_globally_sorted():
    run_spmd(PRELUDE + """
from repro.core.sort import terasort, is_globally_sorted
N = 8 * 2048
keys = rng.integers(0, 2**31 - 2, size=N).astype(np.int32)
payload = np.arange(N, dtype=np.int32)
kd = jax.device_put(jnp.asarray(keys), NamedSharding(mesh2, P(("dc", "node"))))
pd = jax.device_put(jnp.asarray(payload), NamedSharding(mesh2, P(("dc", "node"))))
with mesh2:
    res = terasort(kd, pd, mesh2, axis=("dc", "node"), use_pallas=True)
assert int(res.dropped) == 0
assert is_globally_sorted(res, 8)
vk = np.asarray(res.keys)[np.asarray(res.valid)]
vp = np.asarray(res.payload)[np.asarray(res.valid)]
assert len(vk) == N
assert (keys[vp] == vk).all()
assert (np.sort(vk) == np.sort(keys)).all()
""")


def test_hier_moe_matches_dense_dispatch():
    run_spmd(PRELUDE + """
import dataclasses
from repro.configs import get_smoke_config
from repro.models import moe as moe_mod
cfg = get_smoke_config("qwen3_moe_30b_a3b")
cfg = dataclasses.replace(cfg, capacity_factor=8.0)    # no drops -> exact
params, _ = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, tp=8)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.bfloat16)
with mesh2:
    xs = jax.device_put(x, NamedSharding(mesh2, P("dc", "node", None)))
    out_h, aux_h = moe_mod.moe_apply_sphere(params, xs, cfg, mesh2, (),
                                            ep_axes=("dc", "node"))
out_d, aux_d = moe_mod.moe_apply_dense(params, x, cfg)
err = float(jnp.max(jnp.abs(out_h.astype(jnp.float32)
                            - out_d.astype(jnp.float32))))
assert int(aux_h["moe_dropped"]) == 0, aux_h
assert err < 0.3, err
print("wide-area moe sphere-vs-dense max err:", err)
""")


# -- host-side (no subprocess) ------------------------------------------------


def test_plan_geometry_and_validation():
    sys.path.insert(0, SRC)
    from repro.core.shuffle import ShufflePlan
    from repro.sector.topology import Topology

    p = ShufflePlan.from_topology(Topology(pods=4, racks=1, nodes_per_rack=30),
                                  num_buckets=120, n_local=1200)
    assert p.hierarchical and p.shape == (4, 30)
    assert p.num_devices == 120 and p.buckets_per_device == 1
    assert p.recv_slots == 4 * p.capacities[1]

    flat = ShufflePlan.from_topology(Topology(pods=1, racks=2,
                                              nodes_per_rack=4),
                                     num_buckets=16, n_local=64)
    assert not flat.hierarchical and flat.shape == (8,)

    with pytest.raises(ValueError):
        ShufflePlan(num_buckets=7, axes=("a",), shape=(4,), capacities=(1,))
    with pytest.raises(ValueError):
        ShufflePlan(num_buckets=8, axes=("a", "b"), shape=(2, 4),
                    capacities=(1,))
    with pytest.raises(ValueError):
        p.wan_profile(2, 4, rec_bytes=100)  # topology mismatch


def test_wan_model_hier_bytes_at_most_inverse_nodes_of_flat():
    """Acceptance criterion: on the paper's 4×30 testbed model, the
    hierarchical shuffle puts ≤ 1/nodes_per_dc of the flat shuffle's bytes
    on the WAN (wire accounting), and exactly 1/nodes of the flows."""
    sys.path.insert(0, SRC)
    sys.path.insert(0, ROOT)
    from benchmarks.wan_shuffle import model_wan_round

    m = model_wan_round(dcs=4, nodes=30)
    assert m["wire_ratio"] <= 1.0 / 30 + 1e-9
    assert m["flow_ratio"] == pytest.approx(1.0 / 30)
    # both paths move the identical useful payload; hierarchical never
    # ships more padded slots than flat
    assert m["slot_ratio"] <= 1.0
    assert m["hier"]["wan_slot_bytes"] >= m["useful_bytes"]
