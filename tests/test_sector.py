"""Sector storage cloud: replication, recovery, security, topology."""

import os

import pytest

from repro.sector import (AccessDenied, Master, NodeAddress,
                          ReplicationDaemon, SectorClient, SecurityServer,
                          SlaveNode, Topology)
from repro.sector.topology import distance, spread_choice


def make_deployment(tmp_path, pods=2, racks=2, nodes=3, replication=3,
                    block_mode=False):
    sec = SecurityServer()
    sec.add_user("u", "pw")
    sec.add_user("reader", "pw2", acls=[("/public", "r")])
    sec.allow_slaves("10.1.0.0/16")
    m = Master(sec, replication_factor=replication, block_mode=block_mode,
               block_size=64)
    topo = Topology(pods=pods, racks=racks, nodes_per_rack=nodes)
    for i, addr in enumerate(topo.all_addresses()):
        m.register_slave(SlaveNode(i, addr, str(tmp_path / f"s{i}"),
                                   ip=f"10.1.0.{i}"))
    return sec, m


def test_upload_download_roundtrip(tmp_path):
    _, m = make_deployment(tmp_path)
    c = SectorClient(m, "u", "pw", client_addr=NodeAddress(0, 0, 0))
    data = b"x" * 10_000
    meta = c.upload("/d/a.dat", data)
    assert meta.size == 10_000
    assert c.download("/d/a.dat") == data


def test_replication_daemon_reaches_factor_and_spreads(tmp_path):
    _, m = make_deployment(tmp_path)
    c = SectorClient(m, "u", "pw")
    c.upload("/d/a.dat", b"payload" * 100)
    d = ReplicationDaemon(m)
    d.run_until_stable()
    meta = m.lookup("/d/a.dat")
    assert len(meta.locations) == 3
    # replicas span > 1 pod (topology-aware placement)
    pods = {m.slaves[s].address.pod for s in meta.locations}
    assert len(pods) > 1


def test_slave_failure_rereplicates_and_download_survives(tmp_path):
    _, m = make_deployment(tmp_path)
    c = SectorClient(m, "u", "pw")
    data = b"abc" * 1000
    c.upload("/d/a.dat", data)
    d = ReplicationDaemon(m)
    d.run_until_stable()
    victim = next(iter(m.lookup("/d/a.dat").locations))
    m.slaves[victim].kill(wipe=True)
    d.run_until_stable()
    live = [s for s in m.lookup("/d/a.dat").locations if m.slaves[s].alive]
    assert len(live) >= 3
    assert c.download("/d/a.dat") == data


def test_metadata_scan_recovery(tmp_path):
    sec, m = make_deployment(tmp_path)
    c = SectorClient(m, "u", "pw")
    c.upload("/d/a.dat", b"a" * 100)
    c.upload("/d/b.dat", b"b" * 200)
    ReplicationDaemon(m).run_until_stable()
    # new master, same slaves: index rebuilt purely from directory scans
    m2 = Master(sec, replication_factor=3)
    for s in m.slaves.values():
        m2.register_slave(s)
    assert set(m2.index) == {"/d/a.dat", "/d/b.dat"}
    assert len(m2.index["/d/a.dat"].locations) == 3
    assert m2.index["/d/b.dat"].size == 200


def test_security_acl_and_ip(tmp_path):
    sec, m = make_deployment(tmp_path)
    with pytest.raises(AccessDenied):
        SectorClient(m, "u", "wrong")
    reader = SectorClient(m, "reader", "pw2")
    with pytest.raises(AccessDenied):
        reader.upload("/public/x", b"nope")  # read-only ACL
    with pytest.raises(AccessDenied):
        m.download(reader.session_id, "/private/y")
    writer = SectorClient(m, "u", "pw")
    writer.upload("/public/x", b"data")
    assert reader.download("/public/x") == b"data"


def test_slave_ip_allowlist(tmp_path):
    sec, m = make_deployment(tmp_path)
    rogue = SlaveNode(99, NodeAddress(0, 0, 99), str(tmp_path / "rogue"),
                      ip="192.168.1.1")
    with pytest.raises(AccessDenied):
        m.register_slave(rogue)


def test_ip_restricted_user(tmp_path):
    sec, m = make_deployment(tmp_path)
    sec.add_user("locked", "pw", ip_ranges=["10.5.0.0/24"])
    with pytest.raises(AccessDenied):
        SectorClient(m, "locked", "pw", client_ip="10.9.9.9")
    SectorClient(m, "locked", "pw", client_ip="10.5.0.7")  # ok


def test_block_mode_roundtrip(tmp_path):
    """Hadoop-style block store baseline: chunked + replicate-at-write."""
    _, m = make_deployment(tmp_path, block_mode=True, replication=2)
    c = SectorClient(m, "u", "pw")
    data = bytes(range(256)) * 4  # 1024 bytes -> 16 blocks of 64
    c.upload("/blk/a.dat", data)
    assert c.download("/blk/a.dat") == data
    blocks = [p for p in m.index if p.startswith("/blk/a.dat.blk")]
    assert len(blocks) == 16
    assert all(len(m.index[b].locations) == 2 for b in blocks)


def test_locality_preference(tmp_path):
    _, m = make_deployment(tmp_path)
    c_far = SectorClient(m, "u", "pw", client_addr=NodeAddress(1, 1, 0))
    c_far.upload("/d/here.dat", b"z" * 64)
    meta = m.lookup("/d/here.dat")
    src = m.slaves[next(iter(meta.locations))]
    assert src.address.pod == 1  # stored near the uploader


def test_topology_distance_and_spread():
    a = NodeAddress(0, 0, 0)
    assert distance(a, NodeAddress(0, 0, 0)) == 0
    assert distance(a, NodeAddress(0, 0, 1)) == 1
    assert distance(a, NodeAddress(0, 1, 0)) == 2
    assert distance(a, NodeAddress(1, 0, 0)) == 3
    pick = spread_choice(
        [NodeAddress(0, 0, 1), NodeAddress(0, 1, 0), NodeAddress(1, 0, 0)],
        existing=[a])
    assert pick == NodeAddress(1, 0, 0)  # max topology spread


def test_transport_udt_vs_tcp_and_disk_cap():
    """§2.4: UDT holds wide-area bandwidth where TCP collapses with RTT;
    disk bandwidth caps everything when configured (Fig 4)."""
    from repro.sector.transport import (PAPER_LINKS, PAPER_DISK_BW,
                                        TransferSimulator)
    src, dst = NodeAddress(0, 0, 0), NodeAddress(1, 0, 0)
    udt = TransferSimulator(links=PAPER_LINKS, protocol="udt")
    tcp = TransferSimulator(links=PAPER_LINKS, protocol="tcp")
    assert udt.effective_bandwidth(src, dst) > \
        3 * tcp.effective_bandwidth(src, dst)
    # same-rack short RTT: TCP nearly keeps up
    near = NodeAddress(0, 0, 1)
    assert tcp.effective_bandwidth(src, near) > \
        0.9 * udt.effective_bandwidth(src, near)
    capped = TransferSimulator(links=PAPER_LINKS, protocol="udt",
                               disk_bw=PAPER_DISK_BW)
    assert capped.effective_bandwidth(src, dst) == PAPER_DISK_BW
    t = udt.transfer_time(src, dst, 10 ** 9)
    assert t > 0 and udt.bytes_moved == 10 ** 9


def test_storage_mode_read_amplification():
    """Paper Table 2: file mode reads touch ONE slave; block mode touches
    ceil(size/block) slaves."""
    import sys, os as _os
    sys.path.insert(0, _os.path.abspath(
        _os.path.join(_os.path.dirname(__file__), "..")))
    from benchmarks.storage_modes import run as run_modes
    lines = run_modes()
    file_line = next(l for l in lines if l.startswith("storage_file"))
    block_line = next(l for l in lines if l.startswith("storage_block"))
    assert "read_transfers_per_file=1" in file_line
    assert "read_transfers_per_file=8" in block_line


# -- mid-job recovery + periodic replication (chaos PR satellites) -------------


def test_replica_count_convergence_after_slave_death(tmp_path):
    """After a slave dies, run_until_stable converges every file back to
    exactly replication_factor live copies — and a further pass is a no-op
    (fixpoint, no over-replication)."""
    _, m = make_deployment(tmp_path)
    c = SectorClient(m, "u", "pw")
    for i in range(3):
        c.upload(f"/d/f{i}.dat", bytes([i]) * 300)
    d = ReplicationDaemon(m)
    d.run_until_stable()
    victim = next(iter(m.lookup("/d/f0.dat").locations))
    m.slaves[victim].kill(wipe=True)
    d.run_until_stable()
    for i in range(3):
        live = [s for s in m.lookup(f"/d/f{i}.dat").locations
                if m.slaves[s].alive]
        assert len(live) == m.replication_factor, f"/d/f{i}.dat"
    assert d.run_until_stable() == 0           # converged: nothing to do


def test_no_replication_storm_on_flapping_slave(tmp_path):
    """The paper's replication is lazy and *periodic*: a slave flapping
    faster than the period must not trigger a copy per flap. With a 10s
    period and 30 one-second flaps, at most ceil(30/10)+1 effective ticks
    run; without the period every flap would replicate."""
    _, m = make_deployment(tmp_path, replication=2)
    c = SectorClient(m, "u", "pw")
    c.upload("/d/flap.dat", b"f" * 200)
    clock = [0.0]
    d = ReplicationDaemon(m, period=10.0, clock=lambda: clock[0])
    d.run_until_stable()
    base = m.stats["replications"]
    victim = next(iter(m.lookup("/d/flap.dat").locations))
    for _ in range(30):
        m.slaves[victim].kill(wipe=False)      # flap down...
        d.tick()                               # chaos monkey pokes the timer
        m.slaves[victim].restart()             # ...and right back up
        clock[0] += 1.0
    made = m.stats["replications"] - base
    assert made <= 4, f"replication storm: {made} copies for 30 flaps"
    # the timer only *defers*: once the slave stays dead past the period,
    # the next tick restores the factor
    m.slaves[victim].kill(wipe=True)
    clock[0] += 10.0
    d.tick()
    live = [s for s in m.lookup("/d/flap.dat").locations if m.slaves[s].alive]
    assert len(live) >= m.replication_factor


def test_lost_then_recovered_bucket_roundtrip(tmp_path):
    """A file vanishes from every slave the index lists while an unlisted
    copy survives (stale metadata): download fails, ``client.recover``
    prunes the stale locations, rediscovers the survivor by directory scan
    (§2.2), re-replicates to factor, and the download round-trips."""
    _, m = make_deployment(tmp_path)
    c = SectorClient(m, "u", "pw", client_addr=NodeAddress(0, 0, 0))
    data = b"bucket-bytes" * 50
    c.upload("/job/bucket.00001", data)
    ReplicationDaemon(m).run_until_stable()
    meta = m.lookup("/job/bucket.00001")
    listed = set(meta.locations)
    survivor = next(s for s in m.live_slaves() if s.slave_id not in listed)
    survivor.write_file("/job/bucket.00001", data)   # behind the master's back
    for sid in listed:
        m.slaves[sid].drop_file("/job/bucket.00001")
    with pytest.raises(IOError):
        c.download("/job/bucket.00001")
    recovered = c.recover("/job/bucket.00001")
    assert survivor.slave_id in recovered.locations
    # no stale entries survive: every listed location really holds the bytes
    # (re-replication may legally re-use a formerly-stale slave)
    assert all(m.slaves[s].has_file("/job/bucket.00001")
               for s in recovered.locations)
    assert len(recovered.locations) == m.replication_factor
    assert c.download("/job/bucket.00001") == data
    assert m.stats["recoveries"] >= 1


# -- scan-recovery majority vote + heartbeat failure detection (PR 10) ---------


@pytest.mark.parametrize("stale_on_low_id", [True, False])
def test_recover_from_scan_majority_vote_both_orders(tmp_path,
                                                     stale_on_low_id):
    """Regression: 1 stale replica vs 2 good ones must crown the GOOD md5
    whichever slave is scanned first — majority across live holders, not
    scan order — and the stale copy is deleted from its slave."""
    _, m = make_deployment(tmp_path, replication=3)
    good, stale = b"good" * 50, b"STALE" * 40
    holders = sorted(m.slaves)[:3]
    stale_holder = holders[0] if stale_on_low_id else holders[-1]
    for sid in holders:
        m.slaves[sid].write_file(
            "/d/vote.dat", stale if sid == stale_holder else good)
    m.recover_from_scan()
    meta = m.lookup("/d/vote.dat")
    assert meta.size == len(good)
    assert set(meta.locations) == set(holders) - {stale_holder}
    assert not m.slaves[stale_holder].has_file("/d/vote.dat")  # purged
    c = SectorClient(m, "u", "pw")
    assert c.download("/d/vote.dat") == good


def test_recover_from_scan_tie_breaks_deterministically(tmp_path):
    """A 1-vs-1 split has no majority: the lexicographically smallest md5
    wins, so every rebuild of the same disks yields the same index."""
    import hashlib
    _, m = make_deployment(tmp_path, replication=2)
    a, b = b"copy-a" * 30, b"copy-b" * 30
    s0, s1 = sorted(m.slaves)[:2]
    m.slaves[s0].write_file("/d/tie.dat", a)
    m.slaves[s1].write_file("/d/tie.dat", b)
    m.recover_from_scan()
    first = (m.lookup("/d/tie.dat").md5, set(m.lookup("/d/tie.dat").locations))
    want_md5 = min(hashlib.md5(a).hexdigest(), hashlib.md5(b).hexdigest())
    assert first[0] == want_md5
    # rebuilding from the surviving disks reproduces the same verdict
    m.recover_from_scan()
    assert (m.lookup("/d/tie.dat").md5,
            set(m.lookup("/d/tie.dat").locations)) == first


def test_failure_detector_state_machine(tmp_path):
    """alive -> suspect -> down -> rejoined on a virtual clock: suspicion
    after ``suspect_after`` without a heartbeat (still believed alive), down
    after ``down_after`` (locations pruned exactly once), and a restarted
    slave is re-absorbed by the scan path on its next heartbeat."""
    from repro.sector import FailureDetector

    _, m = make_deployment(tmp_path, replication=2)
    c = SectorClient(m, "u", "pw")
    c.upload("/d/hb.dat", b"h" * 100)
    ReplicationDaemon(m).run_until_stable()
    clock = [0.0]
    det = FailureDetector(m, suspect_after=2.0, down_after=5.0,
                          clock=lambda: clock[0])
    assert det.tick() == []                    # everyone beat at t=0
    victim = next(iter(m.lookup("/d/hb.dat").locations))
    m.slaves[victim].kill(wipe=False)
    clock[0] = 1.0
    assert det.tick() == []
    assert det.state[victim] == det.ALIVE      # age 1 <= suspect_after
    clock[0] = 3.0
    assert det.tick() == []
    assert det.state[victim] == det.SUSPECT
    assert det.believes_alive(victim)          # suspicion is not death
    clock[0] = 6.0
    assert det.tick() == [victim]
    assert det.state[victim] == det.DOWN
    assert not det.believes_alive(victim)
    assert victim not in m.lookup("/d/hb.dat").locations   # pruned
    clock[0] = 7.0
    assert det.tick() == []                    # down is declared ONCE
    m.slaves[victim].restart()                 # disk intact (wipe=False)
    clock[0] = 8.0
    assert det.tick() == []
    assert det.state[victim] == det.ALIVE
    assert det.stats == {"suspected": 1, "downed": 1, "rejoined": 1}
    assert victim in m.lookup("/d/hb.dat").locations       # scan re-absorbed
    assert any("rejoined" in e for e in det.events)


def test_detector_driven_daemon_waits_for_down(tmp_path):
    """Re-replication is driven by detector BELIEF, not omniscient liveness:
    a dead slave's replicas still count while it is merely suspect (no
    premature healing), and the first tick after ``down_after`` restores
    the factor."""
    from repro.sector import FailureDetector

    _, m = make_deployment(tmp_path, replication=2)
    c = SectorClient(m, "u", "pw")
    c.upload("/d/bel.dat", b"b" * 100)
    clock = [0.0]
    det = FailureDetector(m, suspect_after=1.0, down_after=3.0,
                          clock=lambda: clock[0])
    d = ReplicationDaemon(m, clock=lambda: clock[0], detector=det)
    d.run_until_stable()
    base = m.stats["replications"]
    victim = next(iter(m.lookup("/d/bel.dat").locations))
    m.slaves[victim].kill(wipe=True)
    clock[0] = 2.0                             # past suspect, before down
    d.tick()
    assert det.state[victim] == det.SUSPECT
    assert m.stats["replications"] == base     # believed alive: no healing
    clock[0] = 4.0                             # past down_after
    d.tick()
    assert det.state[victim] == det.DOWN
    assert m.stats["replications"] > base
    live = [s for s in m.lookup("/d/bel.dat").locations if m.slaves[s].alive]
    assert len(live) >= m.replication_factor


def test_recover_raises_when_all_copies_gone(tmp_path):
    """No survivor anywhere: recover must fail loudly (counted as a lost
    file), never fabricate data."""
    _, m = make_deployment(tmp_path)
    c = SectorClient(m, "u", "pw")
    c.upload("/d/gone.dat", b"g" * 100)
    ReplicationDaemon(m).run_until_stable()
    for s in m.slaves.values():
        s.drop_file("/d/gone.dat")
    with pytest.raises(IOError, match="no surviving replica"):
        c.recover("/d/gone.dat")
    assert m.stats["lost_files"] >= 1
    # a healthy file is untouched by a (pointless but legal) recover call
    c.upload("/d/fine.dat", b"ok" * 50)
    before = m.stats["recoveries"]
    c.recover("/d/fine.dat")
    assert c.download("/d/fine.dat") == b"ok" * 50
