"""Observability subsystem tests: span tracer, metrics registry, and the
instrumentation wired through the SPMD / host / streaming executors.

Tracer determinism follows the repo's virtual-clock discipline: inject a
counter clock and every duration is an exact integer, so assertions never
race the wall clock. Executor tests run on the 1-device mesh (collectives
still appear in the jaxpr; hop geometry is still recorded) and against
real tmp-dir Sector deployments for the host path."""

import collections
import json
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.mapreduce import default_hash, reduce_by_key_sum
from repro.core.records import RecordCodec
from repro.launch.train import make_sector
from repro.obs import NULL_TRACER, REGISTRY, MetricsRegistry, Tracer
from repro.sphere.dataflow import Dataflow, HostExecutor, SPMDExecutor
from repro.sphere.spe import SPE
from repro.sphere.streaming import StreamExecutor, TenantQueue

NB = 8


@pytest.fixture(autouse=True)
def _fresh_registry():
    REGISTRY.reset()
    yield
    REGISTRY.reset()


def _fake_clock():
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    return clock


# -- tracer ------------------------------------------------------------------


def test_span_nesting_and_virtual_clock():
    tr = Tracer(clock=_fake_clock())
    with tr.span("outer", kind="test") as outer:
        with tr.span("inner") as inner:
            pass
        outer.set(post=1)
    spans = {s.name: s for s in tr.buffer.spans()}
    assert spans["inner"].parent_id == spans["outer"].span_id
    assert spans["outer"].parent_id is None
    # clock ticks: outer@1, inner@2..3, outer ends @4
    assert spans["inner"].duration == 1.0
    assert spans["outer"].duration == 3.0
    assert spans["outer"].attrs == {"kind": "test", "post": 1}


def test_span_records_exception_and_still_closes():
    tr = Tracer(clock=_fake_clock())
    with pytest.raises(ValueError):
        with tr.span("boom"):
            raise ValueError("nope")
    (sp,) = tr.buffer.spans()
    assert sp.end is not None
    assert sp.attrs["error"] == "ValueError: nope"


def test_perfetto_export_is_valid_trace_event_json(tmp_path):
    tr = Tracer(clock=_fake_clock())
    with tr.span("stage[0]", records=4):
        with tr.span("hop[0]"):
            tr.event("retry", segment=1)
    fork = tr.fork("host")
    with fork.span("phase[0]"):
        pass
    path = tr.to_perfetto(str(tmp_path / "t.json"))
    payload = json.loads(open(path).read())
    assert payload["displayTimeUnit"] == "ms"
    evs = payload["traceEvents"]
    kinds = collections.Counter(e["ph"] for e in evs)
    assert kinds == {"M": 2, "X": 3, "i": 1}       # 2 tracks, 3 spans, 1 evt
    names = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert names == {"main", "host"}
    # nesting is expressed by time containment on the same tid
    xs = {e["name"]: e for e in evs if e["ph"] == "X"}
    outer, inner = xs["stage[0]"], xs["hop[0]"]
    assert outer["tid"] == inner["tid"]
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert xs["phase[0]"]["tid"] != outer["tid"]


def test_flame_self_time_excludes_children():
    tr = Tracer(clock=_fake_clock())
    with tr.span("a"):                 # 1..6: dur 5
        with tr.span("b"):             # 2..5: dur 3
            with tr.span("c"):         # 3..4: dur 1
                pass
    flame = tr.flame()
    rows = {l.split()[-1]: l.split() for l in flame.splitlines()[1:]}
    assert float(rows["main/a"][0]) == 5000.0          # total ms
    assert float(rows["main/a"][1]) == 2000.0          # self = 5 - 3
    assert float(rows["main/a/b"][1]) == 2000.0        # self = 3 - 1
    assert float(rows["main/a/b/c"][1]) == 1000.0


def test_tracer_thread_safety_and_per_thread_parenting():
    tr = Tracer()
    errs = []

    def work(i):
        try:
            for j in range(50):
                with tr.span(f"w{i}"):
                    with tr.span(f"w{i}.child"):
                        pass
        except Exception as e:          # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    spans = tr.buffer.spans()
    assert len(spans) == 4 * 50 * 2
    by_id = {s.span_id: s for s in spans}
    for s in spans:                     # children parent within their thread
        if s.parent_id is not None:
            assert by_id[s.parent_id].name == s.name.split(".")[0]


def test_null_tracer_is_falsy_noop():
    assert not NULL_TRACER
    assert NULL_TRACER.fork("x") is NULL_TRACER
    with NULL_TRACER.span("a", k=1) as sp:
        sp.set(more=2)                  # all swallowed
    NULL_TRACER.event("e")


# -- metrics -----------------------------------------------------------------


def test_counter_gauge_labels_and_type_clash():
    reg = MetricsRegistry()
    reg.counter("x.n").inc()
    reg.counter("x.n").inc(2)
    reg.counter("x.n", tenant="a").inc(5)
    reg.gauge("x.g").set(3.5)
    with pytest.raises(ValueError):
        reg.gauge("x.n")                # name already a counter
    with pytest.raises(ValueError):
        reg.counter("x.n").inc(-1)      # counters are monotonic
    snap = reg.snapshot()
    assert snap["x.n"]["value"] == 3
    assert snap['x.n{tenant="a"}']["value"] == 5
    assert snap["x.g"] == {"type": "gauge", "value": 3.5}


def test_histogram_percentiles_are_deterministic():
    reg = MetricsRegistry()
    h = reg.histogram("lat", bounds=(1.0, 2.0, 4.0, 8.0))
    for v in (0.5, 1.5, 1.6, 3.0, 3.5, 100.0):
        h.observe(v)
    # percentile = smallest bucket UPPER bound covering the quantile — a
    # pure function of the multiset, independent of observation order
    assert h.percentile(50) == 2.0
    assert h.percentile(99) == float("inf")   # overflow bucket
    snap = h.snapshot()
    assert snap["count"] == 6 and snap["sum"] == pytest.approx(110.1)
    assert snap["buckets"] == {"1.0": 1, "2.0": 2, "4.0": 2, "inf": 1}
    # same observations, shuffled: identical snapshot
    h2 = MetricsRegistry().histogram("lat", bounds=(1.0, 2.0, 4.0, 8.0))
    for v in (100.0, 3.0, 1.5, 0.5, 3.5, 1.6):
        h2.observe(v)
    assert h2.snapshot() == snap


def test_snapshot_json_roundtrip_sorted(tmp_path):
    reg = MetricsRegistry()
    reg.counter("b").inc()
    reg.counter("a", x="1").inc()
    reg.histogram("c").observe(0.5)
    path = str(tmp_path / "m.json")
    reg.to_json(path)
    loaded = json.loads(open(path).read())
    assert list(loaded) == sorted(loaded)
    assert loaded == json.loads(reg.to_json())


# -- SPMD executor instrumentation -------------------------------------------


def _wordcount(stream=False):
    def _emit(rec):
        return {"key": rec["key"].astype(jnp.int32),
                "value": jnp.ones_like(rec["key"], jnp.int32)}

    def _count(rec, valid):
        k, v, dropped = reduce_by_key_sum(rec["key"], rec["value"], valid)
        return {"key": k, "value": v}, k >= 0, dropped

    src = Dataflow.stream_source() if stream else Dataflow.source()
    return (src.map(_emit)
            .shuffle(by=lambda r: default_hash(r["key"], NB), num_buckets=NB)
            .reduce(_count))


def _records(n=64, seed=0):
    rng = np.random.default_rng(seed)
    return {"key": rng.integers(0, 9, size=n).astype(np.int32)}


def _counts(res):
    rec = res.valid_records()
    return {int(k): int(v) for k, v in zip(rec["key"], rec["value"])}


def test_spmd_traced_run_spans_hops_and_metrics():
    mesh = jax.make_mesh((1,), ("data",))
    ex = SPMDExecutor(mesh)
    tr = Tracer()
    res = ex.run(_wordcount(), _records(), trace=tr)
    assert res.trace is tr
    names = [s.name for s in tr.buffer.spans()]
    # compile miss: lower / compile / introspect, then execute, then root
    assert names == ["spmd.lower", "spmd.compile", "spmd.introspect",
                     "spmd.execute", "spmd.run"]
    root = tr.buffer.spans()[-1]
    assert root.attrs["cache"] == "miss"
    assert root.attrs["wire_bytes"] > 0
    assert root.attrs["hops"], "hop geometry missing from the root span"
    snap = REGISTRY.snapshot()
    assert snap["spmd.runs"]["value"] == 1
    assert snap["spmd.shuffle.hops"]["value"] == 1
    assert snap["spmd.shuffle.wire_bytes"]["value"] == root.attrs["wire_bytes"]
    assert snap["spmd.collectives.all_to_all"]["value"] >= 1
    assert snap["spmd.cache.misses"]["value"] == 1
    assert snap["spmd.dropped"]["value"] == 0


def test_spmd_cache_hit_skips_compile_spans():
    mesh = jax.make_mesh((1,), ("data",))
    ex = SPMDExecutor(mesh)
    df = _wordcount()
    ex.run(df, _records())                       # untraced warm-up
    tr = Tracer()
    ex.run(df, _records(), trace=tr)
    names = [s.name for s in tr.buffer.spans()]
    assert names == ["spmd.execute", "spmd.run"]
    assert tr.buffer.spans()[-1].attrs["cache"] == "hit"
    assert REGISTRY.snapshot()["spmd.cache.hits"]["value"] == 1


def test_untraced_run_records_no_spans_but_counts_runs():
    mesh = jax.make_mesh((1,), ("data",))
    ex = SPMDExecutor(mesh)
    res = ex.run(_wordcount(), _records())
    assert res.trace is None
    snap = REGISTRY.snapshot()
    assert snap["spmd.runs"]["value"] == 1
    assert snap["spmd.shuffle.wire_bytes"]["value"] > 0
    # sync-requiring series are only recorded under a tracer
    assert "spmd.dropped" not in snap


def test_staged_trace_matches_fused_result():
    mesh = jax.make_mesh((1,), ("data",))
    ex = SPMDExecutor(mesh)
    df = _wordcount()
    fused = ex.run(df, _records())
    tr = Tracer()
    staged = ex.run(df, _records(), trace=tr, trace_stages=True)
    assert _counts(staged) == _counts(fused)
    names = [s.name for s in tr.buffer.spans()]
    assert "spmd.run.staged" in names
    stage_names = [n for n in names
                   if n.startswith(("stage[", "hop["))]
    assert stage_names == ["stage[0]:map", "hop[1]:shuffle",
                           "stage[2]:reduce"]
    hop = next(s for s in tr.buffer.spans() if s.name == "hop[1]:shuffle")
    assert hop.attrs["wire_bytes_per_device"] > 0


def test_trace_stages_rejects_carry():
    mesh = jax.make_mesh((1,), ("data",))
    ex = SPMDExecutor(mesh)
    with pytest.raises(ValueError, match="carry"):
        ex.run(_wordcount(stream=True), _records(), trace=Tracer(),
               trace_stages=True,
               carry=({"key": jnp.zeros((4,), jnp.int32),
                       "value": jnp.zeros((4,), jnp.int32)},
                      jnp.zeros((4,), jnp.bool_)))


# -- host executor instrumentation -------------------------------------------


def _deploy(tmp_path, n=160, num_slaves=4, n_files=4):
    rng = np.random.default_rng(7)
    pages = rng.integers(0, 9, size=n).astype(np.int32)
    codec = RecordCodec.from_fields({"key": np.int32})
    master, client, daemon = make_sector(str(tmp_path), num_slaves=num_slaves)
    slices = np.split(codec.encode({"key": pages}), n_files)
    client.upload_dataset("/obs/in", [s.tobytes() for s in slices])
    daemon.run_until_stable()
    spes = [SPE(i, master.slaves[i].address, master, client.session_id)
            for i in range(num_slaves)]
    paths = [f"/obs/in.{i:05d}" for i in range(n_files)]
    want = dict(collections.Counter(pages.tolist()))
    codec_df = (Dataflow.source(codec)
                .map(lambda r: {"key": r["key"].astype(jnp.int32),
                                "value": jnp.ones_like(r["key"],
                                                       jnp.int32)})
                .shuffle(by=lambda r: default_hash(r["key"], NB),
                         num_buckets=NB)
                .reduce(lambda r, v: _reduce(r, v)))
    return master, client, spes, paths, want, codec_df


def _reduce(rec, valid):
    k, v, dropped = reduce_by_key_sum(rec["key"], rec["value"], valid)
    return {"key": k, "value": v}, k >= 0, dropped


def test_host_phase_times_without_tracer(tmp_path):
    master, client, spes, paths, want, df = _deploy(tmp_path)
    res = HostExecutor(master, client, spes).run(df, paths)
    assert _counts(res) == want
    assert res.trace is None
    assert [p["phase"] for p in res.phase_times] == [0, 1]
    assert res.phase_times[0]["terminator"] == "shuffle"
    assert res.phase_times[1]["terminator"] == "output"
    for p in res.phase_times:
        assert p["seconds"] > 0
        assert p["engine_s"] > 0          # SphereResult.elapsed_s flows in
        assert p["seconds"] >= p["engine_s"]
        assert p["segments"] > 0
    snap = REGISTRY.snapshot()
    assert snap["host.segments"]["value"] == sum(
        p["segments"] for p in res.phase_times)
    assert snap["host.phase_seconds"]["count"] == 2


def test_host_traced_run_segment_and_retry_spans(tmp_path):
    master, client, spes, paths, want, df = _deploy(tmp_path)
    spes[0].fail_after = 0                # first pick crashes -> retry
    tr = Tracer(track="host")
    res = HostExecutor(master, client, spes).run(df, paths, trace=tr)
    assert _counts(res) == want
    assert res.retries >= 1
    names = [s.name for s in tr.buffer.spans()]
    kinds = {n.split("[")[0] for n in names}
    assert {"host.run", "phase", "segment", "spe.read", "spe.udf",
            "hop"} <= kinds
    failed = [s for s in tr.buffer.spans()
              if s.name.startswith("segment[")
              and s.attrs.get("outcome") == "spe_failure"]
    assert failed, "the injected SPE crash left no failed-segment span"
    retry_events = [e for e in tr.buffer.events() if e.name == "retry"]
    assert len(retry_events) == res.retries
    snap = REGISTRY.snapshot()
    assert snap["host.retries"]["value"] == res.retries
    # spans parent correctly: every segment span sits under a phase span
    by_id = {s.span_id: s for s in tr.buffer.spans()}
    for s in tr.buffer.spans():
        if s.name.startswith("segment["):
            assert by_id[s.parent_id].name.startswith("phase[")


# -- streaming instrumentation -----------------------------------------------


def test_stream_batch_spans_and_tenant_latency_series():
    mesh = jax.make_mesh((1,), ("data",))
    q = TenantQueue()
    q.register("rt", weight=2.0, priority=0)
    q.register("batch", weight=1.0, priority=1)
    tr = Tracer(track="stream")
    ex = StreamExecutor(SPMDExecutor(mesh), _wordcount(stream=True),
                        micro_batch=16, carry_capacity=8, queue=q, trace=tr)
    for i in range(4):
        ex.submit(_records(8, seed=i), tenant="rt" if i % 2 else "batch")
    batches = ex.drain()
    assert batches
    batch_spans = [s for s in tr.buffer.spans()
                   if s.name.startswith("stream.batch[")]
    assert len(batch_spans) == len(batches)
    for s in batch_spans:
        assert s.attrs["records"] > 0
        assert "carry_rows" in s.attrs and "admission_wait_max" in s.attrs
    # the inner SPMD spans share the buffer (same trace through the stack)
    assert any(s.name == "spmd.run" for s in tr.buffer.spans())
    snap = REGISTRY.snapshot()
    assert snap["stream.batches"]["value"] == len(batches)
    for tenant in ("rt", "batch"):
        assert snap[f'tenant.admitted{{tenant="{tenant}"}}']["value"] == 2
        assert snap[f'tenant.delivered{{tenant="{tenant}"}}']["value"] == 2
        lat = snap[f'tenant.latency{{tenant="{tenant}"}}']
        assert lat["type"] == "histogram" and lat["count"] == 2
