"""Fused O(n) partition/pack kernel vs the stable-argsort oracle.

The acceptance contract of ISSUE 4: `partition_pack` (Pallas kernel and jnp
oracle alike) must reproduce the historical stable-argsort send layout
*exactly* — per-bucket stability, counts, drop accounting — across dtypes,
skewed/empty buckets and out-of-range destinations, so the shuffle send
path could drop its O(n log n) sort without changing a single delivered
byte. (Flat-vs-hierarchical delivery equivalence stays locked in by
tests/test_hier_shuffle.py, unchanged.)
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.kernels import ops, ref
from repro.kernels.partition import partition_rank_pallas

RNG = np.random.default_rng(0)


def argsort_layout(columns, dest, num_dest, capacity):
    """The pre-kernel send path (stable argsort + histogram + gather),
    kept here as the oracle the fused kernel must match."""
    n = dest.shape[0]
    order = np.argsort(dest, kind="stable")
    ok = (dest >= 0) & (dest < num_dest)
    counts = np.bincount(dest[ok], minlength=num_dest)
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
    # argsort puts out-of-range ids (always >= num_dest in the shuffle, the
    # overflow destination) after all real ones; negatives would sort first,
    # so skip them explicitly the way the slot map does.
    order = order[np.argsort(~ok[order], kind="stable")]  # ok records first
    in_range = np.arange(capacity)[None, :] < counts[:, None]
    origin = np.full((num_dest, capacity), -1, np.int64)
    for d in range(num_dest):
        take = min(counts[d], capacity)
        origin[d, :take] = order[offsets[d]:offsets[d] + take]
    tiles = []
    for col in columns:
        t = np.zeros((num_dest, capacity) + col.shape[1:], col.dtype)
        t[in_range] = col[origin[in_range]]
        tiles.append(t)
    dropped = int(np.maximum(counts - capacity, 0).sum())
    return tiles, in_range, origin, dropped


def _check_equal(dest, columns, num_dest, capacity, use_pallas):
    got_t, got_ir, got_or, got_dr = ops.partition_pack(
        [jnp.asarray(c) for c in columns], jnp.asarray(dest),
        num_dest, capacity, use_pallas=use_pallas)
    want_t, want_ir, want_or, want_dr = argsort_layout(
        columns, dest, num_dest, capacity)
    got_ir = np.asarray(got_ir)
    np.testing.assert_array_equal(got_ir, want_ir)
    np.testing.assert_array_equal(np.asarray(got_or)[got_ir],
                                  want_or[want_ir])
    assert (np.asarray(got_or)[~got_ir] == -1).all()
    for g, w in zip(got_t, want_t):
        np.testing.assert_array_equal(np.asarray(g)[got_ir], w[want_ir])
    assert int(got_dr) == want_dr


@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("n,num_dest,capacity", [
    (1, 1, 1), (7, 3, 2), (200, 8, 10), (1000, 8, 300),
    (513, 16, 40), (4096, 4, 4096),
])
def test_matches_argsort_oracle_shapes(n, num_dest, capacity, use_pallas):
    dest = RNG.integers(0, num_dest, size=n).astype(np.int32)
    cols = [RNG.integers(0, 1 << 30, size=(n, 3)).astype(np.int32),
            np.arange(n, dtype=np.int32)]
    _check_equal(dest, cols, num_dest, capacity, use_pallas)


@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("dtype", ["int32", "float32", "uint8", "bfloat16",
                                   "bool"])
def test_pack_preserves_dtypes(dtype, use_pallas):
    n, num_dest, cap = 300, 5, 80
    dest = RNG.integers(0, num_dest, size=n).astype(np.int32)
    if dtype == "bool":
        col = RNG.random((n, 2)) > 0.5
    elif dtype == "bfloat16":
        col = jnp.asarray(RNG.standard_normal((n, 2)), jnp.bfloat16)
    else:
        col = RNG.standard_normal((n, 2)).astype(dtype) \
            if np.dtype(dtype).kind == "f" \
            else RNG.integers(0, 200, size=(n, 2)).astype(dtype)
    (tile,), in_rng, origin, _ = ops.partition_pack(
        [jnp.asarray(col)], jnp.asarray(dest), num_dest, cap,
        use_pallas=use_pallas)
    assert tile.dtype == jnp.asarray(col).dtype
    got = np.asarray(tile)[np.asarray(in_rng)]
    want = np.asarray(jnp.asarray(col))[np.asarray(origin)[np.asarray(in_rng)]]
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_skew_empty_and_overflow_destinations(use_pallas):
    n, num_dest, cap = 500, 8, 40
    # everything lands in bucket 3 (max skew), plus overflow ids num_dest
    # and -1 padding — none of which may be packed or counted
    dest = np.full(n, 3, np.int32)
    dest[::7] = num_dest
    dest[::11] = -1
    cols = [np.arange(n, dtype=np.int32)]
    _check_equal(dest, cols, num_dest, cap, use_pallas)
    (tile,), in_rng, origin, dropped = ops.partition_pack(
        [jnp.asarray(cols[0])], jnp.asarray(dest), num_dest, cap,
        use_pallas=use_pallas)
    in_rng = np.asarray(in_rng)
    n_valid = int((dest == 3).sum())
    assert in_rng[3].sum() == min(n_valid, cap)
    assert int(dropped) == n_valid - cap
    for d in range(num_dest):
        if d != 3:
            assert in_rng[d].sum() == 0          # empty buckets stay empty


@pytest.mark.parametrize("use_pallas", [False, True])
def test_capacity_drop_keeps_earliest_arrivals(use_pallas):
    """Bounded-skew contract: when a bucket overflows, the *first* arrivals
    (original order) are kept — exactly the records the argsort layout
    kept."""
    dest = np.array([0, 1, 0, 0, 1, 0, 0], np.int32)
    col = np.arange(7, dtype=np.int32)
    (tile,), in_rng, origin, dropped = ops.partition_pack(
        [jnp.asarray(col)], jnp.asarray(dest), 2, 3, use_pallas=use_pallas)
    np.testing.assert_array_equal(np.asarray(tile)[0], [0, 2, 3])
    np.testing.assert_array_equal(np.asarray(tile)[1][:2], [1, 4])
    assert int(dropped) == 2                     # rows 5, 6 of bucket 0


@pytest.mark.parametrize("use_pallas", [False, True])
def test_zero_records(use_pallas):
    (tile,), in_rng, origin, dropped = ops.partition_pack(
        [jnp.zeros((0, 2), jnp.float32)], jnp.zeros((0,), jnp.int32), 4, 5,
        use_pallas=use_pallas)
    assert tile.shape == (4, 5, 2)
    assert not np.asarray(in_rng).any()
    assert (np.asarray(origin) == -1).all()
    assert int(dropped) == 0


def test_rank_kernel_matches_oracle():
    """The fused Pallas rank pass ≡ the jnp oracle, including across tile
    boundaries (n > tile forces multi-step base accumulation)."""
    for n, num_dest in [(10, 4), (1024, 8), (3000, 8), (2500, 130)]:
        dest = RNG.integers(0, num_dest, size=n).astype(np.int32)
        kr, kc = partition_rank_pallas(jnp.asarray(dest), num_dest,
                                       tile=1024, interpret=True)
        rr, rc = ref.partition_rank_ref(jnp.asarray(dest), num_dest)
        np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))
        ok = (dest >= 0) & (dest < num_dest)   # rank defined only in-range
        np.testing.assert_array_equal(np.asarray(kr)[ok], np.asarray(rr)[ok])


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(-1, 9), min_size=1, max_size=250),
       st.integers(1, 20))
def test_property_layout_equals_argsort_oracle(dests, capacity):
    """Randomized acceptance property: fused layout ≡ stable-argsort layout
    (ids above num_dest act as the shuffle's overflow destination; -1 as
    padding)."""
    dest = np.asarray(dests, np.int32)
    n = len(dests)
    cols = [np.arange(n, dtype=np.int32),
            (np.arange(n)[:, None] * np.ones((1, 2))).astype(np.float32)]
    _check_equal(dest, cols, 8, capacity, use_pallas=False)


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 5), min_size=1, max_size=120),
       st.integers(1, 10))
def test_property_kernel_equals_oracle(dests, capacity):
    """Pallas kernel path ≡ jnp oracle path, bit-for-bit."""
    dest = np.asarray(dests, np.int32)
    cols = [np.arange(len(dests), dtype=np.int32)]
    for up in (False, True):
        _check_equal(dest, cols, 6, capacity, up)


# -- segmented stage-2 sort ----------------------------------------------------


@pytest.mark.parametrize("rows,cols", [(1, 7), (8, 64), (20, 257), (13, 1)])
def test_multi_segment_sort_matches_per_row_oracle(rows, cols):
    """The upgraded bitonic kernel sorts many sublane-packed segments per
    grid step; every row must equal an independent sort of that row."""
    keys = RNG.integers(0, 1 << 30, size=(rows, cols)).astype(np.int32)
    vals = np.arange(rows * cols, dtype=np.int32).reshape(rows, cols)
    gk, gv = ops.sort_kv_segments(jnp.asarray(keys), jnp.asarray(vals))
    np.testing.assert_array_equal(np.asarray(gk), np.sort(keys, axis=-1))
    for r in range(rows):
        assert (sorted(zip(np.asarray(gk)[r], np.asarray(gv)[r]))
                == sorted(zip(keys[r], vals[r])))


def test_segmented_sort_equals_single_segment_multiset():
    """Segmenting a bucket-major buffer must not lose or invent records:
    the concatenated sorted segments hold the same multiset as one giant
    sorted segment, and each segment is internally sorted."""
    n, bpd = 4096, 8
    keys = RNG.integers(0, 1 << 20, size=n).astype(np.int32)
    seg = keys.reshape(bpd, n // bpd)
    got = np.asarray(ops.sort_segments(jnp.asarray(seg)))
    assert (np.diff(got, axis=1) >= 0).all()
    single = np.asarray(ops.sort_segments(jnp.asarray(keys[None, :])))[0]
    np.testing.assert_array_equal(np.sort(got.reshape(-1)), single)


# -- sampled_splitters small-shard regression ----------------------------------


def test_sampled_splitters_shard_smaller_than_sample():
    """n < sample_per_shard used to slice out of bounds; now the sample is
    clamped to the shard size."""
    import jax
    from repro.core.sort import sampled_splitters

    mesh = jax.make_mesh((1,), ("data",))
    keys = jnp.asarray(np.arange(4, dtype=np.int32) * 1000)
    spl = sampled_splitters(keys, num_buckets=4, sample_per_shard=16,
                            mesh=mesh)
    spl = np.asarray(spl)
    assert spl.shape == (3,)
    assert (np.diff(spl) >= 0).all()
    assert set(spl).issubset(set(np.asarray(keys)))
