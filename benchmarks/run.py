"""Benchmark runner: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (plus the roofline summary from
the dry-run artifacts when present).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run terasort   # one section
"""

import sys
import traceback


def main() -> None:
    from benchmarks import (kernel_bench, moe_dispatch, obs_bench, roofline,
                            scalability, sdss_distribution, storage_modes,
                            streaming_bench, terasort, wan_shuffle)
    sections = {
        "terasort": terasort.run,            # paper Table 1
        "wan_shuffle": wan_shuffle.run,      # §2.2 wide-area shuffle
        "sdss": sdss_distribution.run,       # paper Figs 4-5 + stream demo
        "scalability": scalability.run,      # §3.5.2 claims
        "storage": storage_modes.run,        # paper Table 2 (files vs blocks)
        "moe_dispatch": moe_dispatch.run,    # §3.6 generalization
        "kernels": kernel_bench.run,
        "streaming": streaming_bench.run,    # §3.2 continuous micro-batches
        "obs": obs_bench.run,                # tracing/metrics overhead gate
        "roofline": roofline.run,            # dry-run aggregation
    }
    want = sys.argv[1:] or list(sections)
    print("name,us_per_call,derived")
    failed = False
    for name in want:
        try:
            for line in sections[name]():
                print(line, flush=True)
        except Exception as e:
            failed = True
            print(f"{name}_FAILED,0,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
