"""Render the EXPERIMENTS.md roofline tables from the dry-run artifacts
(baseline + optimized) and splice them into the markers.

Also hosts :func:`phase_table`, the markdown renderer for the host
executor's ``DataflowResult.phase_times`` — per-phase wall time is
recorded unconditionally (a cheap monotonic pair), so a phase breakdown is
printable from any run without attaching a tracer."""

from __future__ import annotations

import json
import os
import sys
from typing import Any, Dict, List

from benchmarks.roofline import load_rows

BASE = os.path.join(os.path.dirname(__file__), "results")


def phase_table(phase_times: List[Dict[str, Any]]) -> str:
    """Markdown table from ``DataflowResult.phase_times`` (host executor):
    one row per phase with wall / engine / materialize seconds and the
    fault-tolerance counters."""
    hdr = ("| phase | terminator | wall_s | engine_s | materialize_s | "
           "segments | retries | recoveries | data_errors |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for p in phase_times:
        lines.append(
            f"| {p['phase']} | {p['terminator']} | {p['seconds']:.3f} "
            f"| {p['engine_s']:.3f} | {p['materialize_s']:.3f} "
            f"| {p['segments']} | {p['retries']} | {p['recoveries']} "
            f"| {p['data_errors']} |")
    return "\n".join(lines)


def key(r):
    return (r["arch"], r["shape"], r["mesh"])


def table(opt_rows, base_rows) -> str:
    base = {key(r): r for r in base_rows}
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | useful | step_s (base→opt) | bound-MFU |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(opt_rows, key=key):
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} |  |  |"
                         f"  | SKIP: full attn @500k |  |  |  |")
            continue
        rf = r["roofline"]
        b = base.get(key(r), {})
        bstep = b.get("roofline", {}).get("step_time_s")
        bs = f"{bstep:.3f}→" if bstep is not None else ""
        uf = r.get("useful_flops_ratio") or 0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
            f"| {rf['collective_s']:.4f} | {rf['dominant'].replace('_s','')} "
            f"| {uf:.2f} | {bs}{rf['step_time_s']:.3f} "
            f"| {rf['mfu_bound'] * 100:.1f}% |")
    return "\n".join(lines)


def main() -> None:
    opt = load_rows(os.path.join(BASE, "dryrun"))
    base = load_rows(os.path.join(BASE, "dryrun_baseline"))
    tbl = table(opt, base)
    path = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
    text = open(path).read()
    marker = "<!-- ROOFLINE_TABLE -->"
    if marker in text:
        pre, _, post = text.partition(marker)
        # drop any previously rendered table up to the next heading
        rest = post.lstrip().split("\n\nObservations:", 1)
        tail = "\n\nObservations:" + rest[1] if len(rest) > 1 else post
        text = pre + marker + "\n\n" + tbl + tail
        open(path, "w").write(text)
        print(f"wrote {tbl.count(chr(10)) - 1} rows into EXPERIMENTS.md")
    else:
        print(tbl)


if __name__ == "__main__":
    main()
