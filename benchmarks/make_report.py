"""Render the EXPERIMENTS.md roofline tables from the dry-run artifacts
(baseline + optimized) and splice them into the markers."""

from __future__ import annotations

import json
import os
import sys

from benchmarks.roofline import load_rows

BASE = os.path.join(os.path.dirname(__file__), "results")


def key(r):
    return (r["arch"], r["shape"], r["mesh"])


def table(opt_rows, base_rows) -> str:
    base = {key(r): r for r in base_rows}
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | useful | step_s (base→opt) | bound-MFU |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(opt_rows, key=key):
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} |  |  |"
                         f"  | SKIP: full attn @500k |  |  |  |")
            continue
        rf = r["roofline"]
        b = base.get(key(r), {})
        bstep = b.get("roofline", {}).get("step_time_s")
        bs = f"{bstep:.3f}→" if bstep is not None else ""
        uf = r.get("useful_flops_ratio") or 0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
            f"| {rf['collective_s']:.4f} | {rf['dominant'].replace('_s','')} "
            f"| {uf:.2f} | {bs}{rf['step_time_s']:.3f} "
            f"| {rf['mfu_bound'] * 100:.1f}% |")
    return "\n".join(lines)


def main() -> None:
    opt = load_rows(os.path.join(BASE, "dryrun"))
    base = load_rows(os.path.join(BASE, "dryrun_baseline"))
    tbl = table(opt, base)
    path = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
    text = open(path).read()
    marker = "<!-- ROOFLINE_TABLE -->"
    if marker in text:
        pre, _, post = text.partition(marker)
        # drop any previously rendered table up to the next heading
        rest = post.lstrip().split("\n\nObservations:", 1)
        tail = "\n\nObservations:" + rest[1] if len(rest) > 1 else post
        text = pre + marker + "\n\n" + tbl + tail
        open(path, "w").write(text)
        print(f"wrote {tbl.count(chr(10)) - 1} rows into EXPERIMENTS.md")
    else:
        print(tbl)


if __name__ == "__main__":
    main()
