"""Terasort benchmark (paper Table 1).

Two parts:

1. ``simulate_table1()`` — a first-principles wide-area model of the paper's
   testbed (4 racks x 30 nodes, 1 GE in-rack / 10 GE between sites, single
   SATA disk ~50 MB/s, 10 GB/node) comparing Sphere against Hadoop-style
   execution at replication 1 and 3. The model encodes exactly the design
   deltas the paper credits for its 2x win: UDT vs TCP on the WAN, direct
   bucket sends overlapped with the map scan vs barrier + HTTP pull, and
   replicate-periodically vs replicate-at-write.

2. ``measured_microsort()`` — the real compiled sort as a dataflow pipeline
   (``Dataflow.source().sort(...)`` on :class:`repro.sphere.dataflow
   .SPMDExecutor`, Pallas or XLA stage-2 — the executor's compile cache
   makes the timed iterations pure execution) vs the ``hadoop_style_sort``
   all-gather baseline on virtual devices.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List

from repro.sector.topology import NodeAddress
from repro.sector.transport import PAPER_DISK_BW, PAPER_LINKS, \
    TransferSimulator

GB = 1e9
DATA_PER_NODE = 10 * GB
SORT_CPU_BW = 100e6          # bytes/s/node in-memory sort+merge throughput
PAPER_TABLE1 = {             # seconds, from the paper
    1: {"sphere": 1265, "hadoop3": 2889, "hadoop1": 2252},
    2: {"sphere": 1361, "hadoop3": 2896, "hadoop1": 2617},
    3: {"sphere": 1430, "hadoop3": 4341, "hadoop1": 3069},
    4: {"sphere": 1526, "hadoop3": 6675, "hadoop1": 3702},
}


def _net_share(locations: int, nodes_per_loc: int, protocol: str) -> float:
    """Effective per-node network bandwidth for the shuffle (bytes/s).

    In-rack traffic rides 1 GE per node; the fraction of records whose
    bucket lives at another site ((L-1)/L) shares the site's 10 GE uplink
    with all its nodes. TCP additionally loses throughput to WAN RTT
    (the paper's UDT argument, §2.4).
    """
    sim = TransferSimulator(links=PAPER_LINKS, protocol=protocol)
    local = sim.effective_bandwidth(NodeAddress(0, 0, 0),
                                    NodeAddress(0, 0, 1))     # 1 GE
    if locations == 1:
        return local
    wan_total = sim.effective_bandwidth(NodeAddress(0, 0, 0),
                                        NodeAddress(1, 0, 0))  # 10 GE WAN
    cross_frac = (locations - 1) / locations
    wan_per_node = wan_total / nodes_per_loc
    # harmonic combination: cross_frac of bytes at wan share, rest local
    return 1.0 / (cross_frac / wan_per_node + (1 - cross_frac) / local)


def simulate_table1(nodes_per_loc: int = 30) -> Dict[int, Dict[str, float]]:
    """Disk-pass model of terasort on the Open Cloud Testbed.

    A node has ONE spindle; simultaneous sequential read+write interleaves
    seeks, so effective bandwidth is DISK_EFF * 50 MB/s. Costs are counted in
    *passes over the 10 GB* plus network phases:

    Sphere: stage 1 reads input while streaming records to their bucket
    nodes over UDT (overlapped); the receiving side writes the bucket (pass
    2). Stage 2 external-sorts the bucket (read + write = passes 3,4). Total
    4 passes; network only binds if UDT share < disk.

    Hadoop: map reads input, writes spill, merge-sorts spills (read+write)
    = 3 passes; BARRIER; reducers pull everything over TCP (not overlapped
    with map); reduce merge + final write = 3 passes. Replication factor R
    writes the output (R-1) more times across the network at write time.
    """
    out: Dict[int, Dict[str, float]] = {}
    D = DATA_PER_NODE
    disk_eff = 0.65 * PAPER_DISK_BW        # read/write seek interleave
    for loc in (1, 2, 3, 4):
        bw_udt = _net_share(loc, nodes_per_loc, "udt")
        bw_tcp = _net_share(loc, nodes_per_loc, "tcp")

        t1 = max(2 * D / disk_eff, D / bw_udt)     # scan+bucket-write | UDT
        t2 = max(2 * D / disk_eff, D / SORT_CPU_BW)
        sphere = t1 + t2

        def hadoop(replicas: int) -> float:
            t_map = 3 * D / disk_eff                       # read+spill+merge
            t_shuffle = max(D / disk_eff, D / bw_tcp)      # after barrier
            t_reduce = 3 * D / disk_eff
            t_repl = (replicas - 1) * max(D / disk_eff, D / bw_tcp)
            return t_map + t_shuffle + t_reduce + t_repl

        out[loc] = {"sphere": sphere, "hadoop3": hadoop(3),
                    "hadoop1": hadoop(1),
                    "paper_sphere": PAPER_TABLE1[loc]["sphere"],
                    "paper_hadoop3": PAPER_TABLE1[loc]["hadoop3"],
                    "paper_hadoop1": PAPER_TABLE1[loc]["hadoop1"]}
    return out


_MEASURE_CODE = """
import time, numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.sort import hadoop_style_sort, is_globally_sorted, SortResult
from repro.sphere.dataflow import Dataflow, SPMDExecutor
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
N = 8 * 8192
keys = rng.integers(0, 2**31 - 2, size=N).astype(np.int32)
payload = np.arange(N, dtype=np.int32)
kd = jax.device_put(jnp.asarray(keys), NamedSharding(mesh, P("data")))
pd = jax.device_put(jnp.asarray(payload), NamedSharding(mesh, P("data")))
df = Dataflow.source().sort(key=lambda r: r["key"], num_buckets=8)
def sphere(ex):
    res = ex.run(df, {"key": kd, "payload": pd})
    return SortResult(res.records["key"], res.records["payload"],
                      res.valid, res.dropped)
for name, fn in (
        ("sphere_pallas",
         lambda ex=SPMDExecutor(mesh, use_pallas=True): sphere(ex)),
        ("sphere_xla",
         lambda ex=SPMDExecutor(mesh, use_pallas=False): sphere(ex)),
        ("hadoop_style", lambda: hadoop_style_sort(kd, pd, mesh))):
    with mesh:
        res = fn()                      # compile (cached per executor) + run
        jax.block_until_ready(res.keys)
        t0 = time.time(); iters = 3
        for _ in range(iters):
            res = fn()                  # pipeline cache hit: execution only
            jax.block_until_ready(res.keys)
        dt = (time.time() - t0) / iters
    assert is_globally_sorted(res, 8), name
    print(f"RESULT {name} {dt * 1e6:.1f} us_per_call {N / dt / 1e6:.2f} Mrec/s")
"""


def measured_microsort() -> List[str]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _MEASURE_CODE], env=env,
                          capture_output=True, text=True, timeout=520)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    return [l for l in proc.stdout.splitlines() if l.startswith("RESULT")]


def run(csv: bool = True) -> List[str]:
    lines = []
    table = simulate_table1()
    for loc, row in table.items():
        ratio = row["hadoop1"] / row["sphere"]
        lines.append(
            f"terasort_sim_{loc}loc,"
            f"{row['sphere'] * 1e6:.0f},"
            f"sphere={row['sphere']:.0f}s hadoop1={row['hadoop1']:.0f}s "
            f"hadoop3={row['hadoop3']:.0f}s ratio={ratio:.2f} "
            f"(paper: {row['paper_sphere']}/{row['paper_hadoop1']}/"
            f"{row['paper_hadoop3']})")
    for r in measured_microsort():
        parts = r.split()
        lines.append(f"terasort_measured_{parts[1]},{parts[2]},{' '.join(parts[4:])}")
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
