"""Observability overhead benchmark: tracing must cost < 5% on terasort.

One subprocess on 8 virtual devices (XLA_FLAGS must be set before jax
initializes) runs the whole instrumented surface:

- **SPMD terasort** (``Dataflow.source().sort``): warm up WITH a tracer so
  the compile miss records hop geometry and collective counts, then time
  untraced vs traced runs on the warm compile cache in interleaved pairs —
  ``obs_overhead`` is the median traced/untraced ratio and ``--check``
  gates it below :data:`OVERHEAD_BOUND`.
- **Staged trace** (``trace_stages=True``): one compiled program per
  stage → per-stage ``hop[i]:sort`` rows for BENCH_kernels.json.
- **Host terasort** over a real in-process Sector deployment, with a
  ``drop_bucket`` fault injected so the retry AND mid-job recovery series
  show up in the metrics snapshot.
- **Streaming wordcount** through a two-tenant :class:`TenantQueue` for
  the per-tenant latency series.

All three executors share ONE trace buffer (``tracer.fork``), so the
Perfetto file written to ``--trace PATH`` shows them as side-by-side
threads; CI uploads it as a workflow artifact every run. ``--check``
additionally validates the trace_event JSON (nested stage→hop spans on
both executor tracks) and that every required metric series is present in
the registry snapshot.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List

OWNER = "obs"
OVERHEAD_BOUND = 1.05            # traced/untraced wall-clock, warm cache

#: metric series the snapshot must contain after the bench (labels matter
#: for the tenant series — substring match against the snapshot keys)
REQUIRED_SERIES = [
    "spmd.runs", "spmd.shuffle.wire_bytes", "spmd.shuffle.hops",
    "spmd.collectives.all_to_all", "spmd.dropped", "spmd.cache.misses",
    "host.segments", "host.retries", "host.recoveries",
    "host.phase_seconds", "stream.batches", 'tenant.latency{tenant="',
]

_BENCH_CODE = """
import json, sys, tempfile, time
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.mapreduce import default_hash, reduce_by_key_sum
from repro.core.records import RecordCodec
from repro.launch.train import make_sector
from repro.obs import Tracer, REGISTRY
from repro.sphere.chaos import FaultPlan
from repro.sphere.dataflow import Dataflow, HostExecutor, SPMDExecutor
from repro.sphere.spe import SPE
from repro.sphere.streaming import StreamExecutor, TenantQueue

trace_path = sys.argv[1]
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
N = 8 * 8192
keys = rng.integers(0, 2**31 - 2, size=N).astype(np.int32)
payload = np.arange(N, dtype=np.int32)
kd = jax.device_put(jnp.asarray(keys), NamedSharding(mesh, P("data")))
pd = jax.device_put(jnp.asarray(payload), NamedSharding(mesh, P("data")))
df = Dataflow.source().sort(key=lambda r: r["key"], num_buckets=16)
data = {"key": kd, "payload": pd}

tracer = Tracer(track="spmd")
ex = SPMDExecutor(mesh)
with mesh:
    # warm-up WITH the tracer: the compile miss records hop geometry and
    # collective counts into the registry exactly once
    res = ex.run(df, data, trace=tracer)
    jax.block_until_ready(res.records["key"])
    assert (np.diff(res.valid_records()["key"]) >= 0).all()
    iters = 15
    t_un, t_tr = [], []
    for _ in range(iters):            # interleaved pairs, warm cache
        t0 = time.perf_counter()
        r = ex.run(df, data)
        jax.block_until_ready(r.records["key"])
        t_un.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        r = ex.run(df, data, trace=tracer)   # blocks internally (fencing)
        t_tr.append(time.perf_counter() - t0)
    overhead = float(np.median(np.asarray(t_tr) / np.asarray(t_un)))
    # staged mode: one compiled program per stage -> per-stage spans
    sres = ex.run(df, data, trace=tracer, trace_stages=True)
    assert (np.diff(sres.valid_records()["key"]) >= 0).all()
stage_rows = [
    {"name": s.name, "ms": (s.end - s.start) * 1e3,
     "attrs": {k: v for k, v in s.attrs.items()
               if k in ("records", "dropped", "wire_bytes_per_device",
                        "chunks")}}
    for s in tracer.buffer.spans()
    if s.track == "spmd"
    and (s.name.startswith("hop[") or s.name.startswith("stage["))]

# -- host executor: same sort over a real Sector deployment ------------------
htr = tracer.fork("host")
codec = RecordCodec.from_fields({"key": np.int32, "payload": np.int32})
hdf = Dataflow.source(codec).sort(key=lambda r: r["key"], num_buckets=8)
hk, hp = keys[:2048], payload[:2048]
root = tempfile.mkdtemp()
master, client, daemon = make_sector(root, num_slaves=4)
slices = np.split(codec.encode({"key": hk, "payload": hp}), 4)
client.upload_dataset("/ts/in", [s.tobytes() for s in slices])
daemon.run_until_stable()
spes = [SPE(i, master.slaves[i].address, master, client.session_id)
        for i in range(4)]
# drop_bucket fault: exercises SectorClient.recover mid-job, so the
# host.retries AND host.recoveries series are non-empty in the snapshot
chaos = FaultPlan(kind="drop_bucket", phase=0, seed=0)
hres = HostExecutor(master, client, spes, daemon=daemon).run(
    hdf, [f"/ts/in.{i:05d}" for i in range(4)], trace=htr, chaos=chaos)
hvr = hres.valid_records()
assert (np.diff(hvr["key"]) >= 0).all()
assert not hres.errors, hres.errors

# -- streaming: two-tenant queue -> tenant.latency series --------------------
strr = tracer.fork("stream")
def _emit(rec):
    return {"key": rec["key"].astype(jnp.int32),
            "value": jnp.ones_like(rec["key"], jnp.int32)}
def _count(rec, valid):
    k, v, dropped = reduce_by_key_sum(rec["key"], rec["value"], valid)
    return {"key": k, "value": v}, k >= 0, dropped
sdf = (Dataflow.stream_source()
       .map(_emit)
       .shuffle(by=lambda r: default_hash(r["key"], 8), num_buckets=8)
       .reduce(_count))
q = TenantQueue()
q.register("rt", weight=2.0, priority=0)
q.register("batch", weight=1.0, priority=1)
sex = StreamExecutor(SPMDExecutor(mesh), sdf, micro_batch=64,
                     carry_capacity=16, queue=q, trace=strr)
for i in range(6):
    sex.submit({"key": np.arange(16, dtype=np.int32) % 5},
               tenant="rt" if i % 2 else "batch")
batches = sex.drain()

tracer.to_perfetto(trace_path)
out = {
    "overhead": overhead, "iters": iters, "n_records": N,
    "untraced_us": float(np.median(t_un) * 1e6),
    "traced_us": float(np.median(t_tr) * 1e6),
    "stage_rows": stage_rows,
    "phase_times": hres.phase_times,
    "host_retries": hres.retries, "host_recoveries": hres.recoveries,
    "stream_batches": len(batches),
    "snapshot": REGISTRY.snapshot(),
}
print("RESULT " + json.dumps(out))
"""


def bench(trace_path: str) -> Dict[str, object]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _BENCH_CODE, trace_path],
                          env=env, capture_output=True, text=True,
                          timeout=520)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT "):])


def _contained(events: List[dict], outer: dict, prefix: str) -> List[dict]:
    """X-events on ``outer``'s tid, named ``prefix*``, inside its window."""
    lo, hi = outer["ts"], outer["ts"] + outer["dur"]
    return [e for e in events
            if e.get("ph") == "X" and e["tid"] == outer["tid"]
            and e["name"].startswith(prefix)
            and lo <= e["ts"] and e["ts"] + e["dur"] <= hi]


def check_trace(trace_path: str) -> List[str]:
    """Validate the Perfetto trace_event JSON: loadable, and the stage→hop
    nesting exists on BOTH executor tracks."""
    failures: List[str] = []
    try:
        with open(trace_path) as f:
            payload = json.load(f)
        events = payload["traceEvents"]
    except (OSError, ValueError, KeyError) as e:
        return [f"trace {trace_path} unreadable: {e!r}"]
    xs = [e for e in events if e.get("ph") == "X"]
    for e in xs:
        if not all(k in e for k in ("name", "ts", "dur", "pid", "tid")):
            failures.append(f"malformed trace event {e}")
            return failures
    tracks = {e["args"]["name"]: e["tid"] for e in events
              if e.get("ph") == "M" and e.get("name") == "thread_name"}
    for want in ("spmd", "host", "stream"):
        if want not in tracks:
            failures.append(f"missing {want!r} track in trace")
    # SPMD: the staged root span must contain per-stage hop spans
    staged = [e for e in xs if e["name"] == "spmd.run.staged"]
    if not staged:
        failures.append("no spmd.run.staged span")
    elif not _contained(xs, staged[0], "hop["):
        failures.append("no hop[i] span nested inside spmd.run.staged")
    # host: each phase span must contain segment spans; phase 1 (bucket
    # sort) follows the hop[0]:buckets materialization span
    phases = [e for e in xs if e["tid"] == tracks.get("host")
              and e["name"].startswith("phase[")]
    if not phases:
        failures.append("no host phase[i] spans")
    elif not _contained(xs, phases[0], "segment["):
        failures.append("no segment[i] span nested inside host phase[0]")
    if not any(e["name"].startswith("hop[") and
               e["tid"] == tracks.get("host") for e in xs):
        failures.append("no host hop[i]:buckets span")
    if not any(e["name"].startswith("stream.batch[") for e in xs):
        failures.append("no stream.batch[i] spans")
    return failures


def check(res: Dict[str, object], trace_path: str) -> List[str]:
    failures: List[str] = []
    ratio = float(res["overhead"])
    if not ratio == ratio or ratio > OVERHEAD_BOUND:   # NaN-safe
        failures.append(f"tracing overhead {ratio:.3f}x exceeds the "
                        f"{OVERHEAD_BOUND:.2f}x bound")
    snap = res["snapshot"]
    for series in REQUIRED_SERIES:
        if not any(k.startswith(series) for k in snap):
            failures.append(f"metric series {series!r} missing from "
                            f"snapshot")
    # schema stability: every snapshot entry carries its type and the
    # type-specific required fields
    for k, v in snap.items():
        t = v.get("type")
        want = {"counter": ("value",), "gauge": ("value",),
                "histogram": ("count", "sum", "p50", "p99")}.get(t)
        if want is None or any(f not in v for f in want):
            failures.append(f"snapshot entry {k!r} breaks schema: {v}")
            break
    if int(res["host_recoveries"]) < 1:
        failures.append("drop_bucket fault produced no recovery")
    if not res["stage_rows"]:
        failures.append("staged trace produced no per-stage rows")
    failures.extend(check_trace(trace_path))
    return failures


def _merge_json(json_path: str, res: Dict[str, object]) -> None:
    try:
        with open(json_path) as f:
            payload = json.load(f)
    except (OSError, ValueError):
        payload = {"schema": "repro.kernel_bench.v1", "results": {}}
    payload.setdefault("results", {})
    payload["results"]["obs_overhead"] = {
        "owner": OWNER,
        "ratio": res["overhead"], "bound": OVERHEAD_BOUND,
        "untraced_us": res["untraced_us"], "traced_us": res["traced_us"],
        "iters": res["iters"], "records": res["n_records"],
        "note": "traced/untraced terasort wall time, warm compile cache, "
                "median of interleaved pairs, 8 virtual devices",
    }
    payload["results"]["obs_stage_times"] = {
        "owner": OWNER,
        "stages": res["stage_rows"],
        "host_phases": res["phase_times"],
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)


def run(csv: bool = True, json_path: str | None = None,
        trace_path: str = "obs_trace.json") -> List[str]:
    res = bench(trace_path)
    lines = [
        f"obs_overhead,{res['traced_us']:.0f},"
        f"ratio={res['overhead']:.3f}x (bound {OVERHEAD_BOUND:.2f}x) "
        f"untraced={res['untraced_us']:.0f}us over {res['iters']} pairs",
        f"obs_trace,{len(res['stage_rows'])},"
        f"stage rows; perfetto written to {trace_path}",
        f"obs_host_phases,{len(res['phase_times'])},"
        f"retries={res['host_retries']} recoveries={res['host_recoveries']}",
    ]
    if json_path:
        _merge_json(json_path, res)
        lines.append(f"obs_bench_json,0,merged into {json_path}")
    run.last_result = res
    return lines


def main() -> None:
    args = sys.argv[1:]
    do_check = "--check" in args
    json_path = None
    trace_path = "obs_trace.json"
    usage = "usage: obs_bench.py [--json PATH] [--trace PATH] [--check]"
    if "--json" in args:
        idx = args.index("--json") + 1
        if idx >= len(args):
            print(usage)
            sys.exit(2)
        json_path = args[idx]
    elif do_check:
        json_path = "BENCH_kernels.json"
    if "--trace" in args:
        idx = args.index("--trace") + 1
        if idx >= len(args):
            print(usage)
            sys.exit(2)
        trace_path = args[idx]
    for line in run(json_path=json_path, trace_path=trace_path):
        print(line)
    if do_check:
        res = run.last_result
        failures = check(res, trace_path)
        if failures:
            for msg in failures:
                print(f"CHECK FAILED: {msg}")
            sys.exit(1)
        print(f"CHECK OK: tracing overhead {res['overhead']:.3f}x < "
              f"{OVERHEAD_BOUND:.2f}x on warm-cache terasort; Perfetto "
              f"trace and metrics-snapshot schema valid")


if __name__ == "__main__":
    main()
