"""Chaos recovery benchmark: what does surviving a device loss cost?

Runs the word-count shuffle pipeline on 8 virtual devices three ways:

- ``clean``      — the fused ``jit(shard_map)`` fast path;
- ``segmented``  — the same pipeline under ``chaos=FaultPlan(kind="none")``,
  i.e. per-hop execution with a :class:`~repro.sphere.chaos.HopCheckpoint`
  sealed at every boundary but no fault injected (the checkpointing tax);
- ``recovered``  — ``lose_device`` injected between stage A and stage B:
  the executor shrinks the mesh (8 -> 4 devices), restores the last hop
  checkpoint via ``elastic.remesh`` and resumes.

``chaos_recovery_overhead`` = recovered wall time / clean wall time, measured
after one warm-up pass of each path so compile time is excluded and the ratio
reflects the steady-state cost (checkpoint encode/decode + remesh + running
the tail of the job at half width). The row is merged into
``BENCH_kernels.json`` without clobbering the kernel/stream rows.

``--check`` gates the acceptance criteria, not the timing noise:

- the recovered multiset equals the clean multiset (headline invariant);
- exactly one recovery happened and the fault actually fired;
- drop counts are conserved across the fault;
- the overhead ratio is finite and under a deliberately lenient bound.

Run:  PYTHONPATH=src python benchmarks/chaos_bench.py [--check] [--json P]
"""

from __future__ import annotations

import os
import sys

if "jax" not in sys.modules:        # standalone: give the bench 8 devices
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import collections
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

NB = 8
N_RECORDS = 8 * 256
# steady-state recovery should cost well under this multiple of a clean run;
# lenient on purpose — correctness is gated hard, wall time only sanity-checked
OVERHEAD_BOUND = 100.0


def _build_pipeline():
    from repro.core.mapreduce import default_hash, reduce_by_key_sum
    from repro.sphere.dataflow import Dataflow

    def emit(rec):
        return {"key": rec["word"].astype(jnp.int32),
                "value": jnp.ones_like(rec["word"], jnp.int32)}

    def count(rec, valid):
        k, v, dropped = reduce_by_key_sum(rec["key"], rec["value"], valid)
        return {"key": k, "value": v}, k >= 0, dropped

    return (Dataflow.source()
            .map(emit)
            .shuffle(by=lambda r: default_hash(r["key"], NB), num_buckets=NB)
            .reduce(count))


def _counts(res) -> Dict[int, int]:
    rec = res.valid_records()
    return {int(k): int(v) for k, v in zip(rec["key"], rec["value"])}


def bench(repeats: int = 3) -> Dict[str, object]:
    from repro.sphere.chaos import FaultPlan
    from repro.sphere.dataflow import SPMDExecutor

    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("data",))
    ex = SPMDExecutor(mesh)
    df = _build_pipeline()

    rng = np.random.default_rng(7)
    words = rng.integers(0, 26, size=N_RECORDS).astype(np.uint8)
    want = dict(collections.Counter(words.tolist()))
    src = {"word": jnp.asarray(words)}

    def timed(fn):
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = fn()
            jax.block_until_ready(out.records)
            best = min(best, time.perf_counter() - t0)
        return best, out

    with mesh:
        # warm-up passes: compile the fused path, the per-hop sub-pipelines
        # and the shrunken-mesh sub-executor before any clock starts
        clean_warm = ex.run(df, src)
        seg_warm = ex.run(df, src, chaos=FaultPlan(kind="none"))
        ex.run(df, src, chaos=FaultPlan(kind="lose_device", phase=1, seed=0))

        t_clean, clean = timed(lambda: ex.run(df, src))
        t_seg, seg = timed(
            lambda: ex.run(df, src, chaos=FaultPlan(kind="none")))

        plans: List[FaultPlan] = []

        def recovered_run():
            plan = FaultPlan(kind="lose_device", phase=1, seed=0)
            plans.append(plan)
            return ex.run(df, src, chaos=plan)

        t_rec, rec = timed(recovered_run)

    clean_counts = _counts(clean)
    rec_counts = _counts(rec)
    last_plan = plans[-1]
    return {
        "ndev": ndev,
        "records": N_RECORDS,
        "num_buckets": NB,
        "clean_us": t_clean * 1e6,
        "segmented_us": t_seg * 1e6,
        "recovered_us": t_rec * 1e6,
        "checkpoint_overhead": t_seg / t_clean,
        "recovery_overhead": t_rec / t_clean,
        "fault_fired": last_plan.fired,
        "fault_events": list(last_plan.events),
        "recoveries": int(rec.recoveries),
        "dropped_clean": int(clean.dropped),
        "dropped_recovered": int(rec.dropped),
        "multiset_equal": rec_counts == clean_counts == want
        and _counts(seg) == want
        and _counts(clean_warm) == _counts(seg_warm) == want,
    }


def check(res: Dict[str, object]) -> List[str]:
    failures = []
    if not res["multiset_equal"]:
        failures.append("recovered multiset != clean multiset")
    if not res["fault_fired"]:
        failures.append("lose_device fault never fired")
    if res["recoveries"] != 1:
        failures.append(f"expected exactly 1 recovery, got {res['recoveries']}")
    if res["dropped_recovered"] != res["dropped_clean"]:
        failures.append(f"drop count not conserved: clean dropped "
                        f"{res['dropped_clean']}, recovered dropped "
                        f"{res['dropped_recovered']}")
    ratio = res["recovery_overhead"]
    if not np.isfinite(ratio) or ratio > OVERHEAD_BOUND:
        failures.append(f"recovery overhead {ratio:.1f}x exceeds the "
                        f"{OVERHEAD_BOUND:.0f}x sanity bound")
    return failures


def _merge_json(json_path: str, res: Dict[str, object]) -> None:
    try:
        with open(json_path) as f:
            payload = json.load(f)
    except (OSError, ValueError):
        payload = {"schema": "repro.kernel_bench.v1", "results": {}}
    payload.setdefault("results", {})
    payload["results"]["chaos_recovery_overhead"] = {
        "owner": "chaos",
        "value": res["recovery_overhead"],
        "checkpoint_overhead": res["checkpoint_overhead"],
        "clean_us": res["clean_us"],
        "segmented_us": res["segmented_us"],
        "recovered_us": res["recovered_us"],
        "ndev": res["ndev"], "records": res["records"],
        "recoveries": res["recoveries"],
        "multiset_equal": res["multiset_equal"],
        "note": "recovered/clean wall time, warm caches; lose_device at the "
                "stage-A/stage-B boundary, mesh 8 -> 4",
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)


def run(json_path: str | None = None) -> List[str]:
    res = bench()
    lines = [
        f"chaos_clean,{res['clean_us']:.0f},fused run "
        f"({res['records']} records, {res['ndev']} devices)",
        f"chaos_segmented,{res['segmented_us']:.0f},per-hop checkpoints, "
        f"no fault ({res['checkpoint_overhead']:.2f}x clean)",
        f"chaos_recovery_overhead,{res['recovered_us']:.0f},"
        f"{res['recovery_overhead']:.2f}x clean (lose_device at boundary 1, "
        f"recoveries={res['recoveries']}, "
        f"multiset_equal={res['multiset_equal']})",
    ]
    if json_path:
        _merge_json(json_path, res)
        lines.append(f"chaos_bench_json,0,merged into {json_path}")
    run.last_result = res
    return lines


def main() -> None:
    args = sys.argv[1:]
    do_check = "--check" in args
    json_path = None
    if "--json" in args:
        idx = args.index("--json") + 1
        if idx >= len(args):
            print("usage: chaos_bench.py [--json PATH] [--check]")
            sys.exit(2)
        json_path = args[idx]
    elif do_check:
        json_path = "BENCH_kernels.json"
    for line in run(json_path=json_path):
        print(line)
    if do_check:
        res = run.last_result
        failures = check(res)
        if failures:
            for msg in failures:
                print(f"CHECK FAILED: {msg}")
            sys.exit(1)
        print(f"CHECK OK: device loss at the stage boundary recovered in "
              f"{res['recovery_overhead']:.2f}x clean wall time "
              f"(recoveries={res['recoveries']}, multiset unchanged, "
              f"drops conserved)")


if __name__ == "__main__":
    main()
