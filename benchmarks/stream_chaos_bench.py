"""Chaos-hardened streaming soak: one stream, one multi-fault schedule.

The soak runs the carried word-count stream of ``streaming_bench`` (3
tenants at weights 1:3:4, permanently backlogged) for >= 30 micro-batches
while a seeded :class:`~repro.sphere.chaos.ChaosSchedule` injects FOUR
faults at batch boundaries:

- ``lose_batch``  @ batch 4  — in-flight batch dropped, tickets requeue;
- ``lose_device`` @ batch 10 — mesh shrinks 8 -> 4 devices, carry remeshed
  from the boundary's :class:`~repro.sphere.chaos.StreamCheckpoint`,
  exactly one recompile, tickets requeue;
- ``kill_slave``  @ batch 16 — a Sector slave holding stream checkpoints
  dies; the heartbeat :class:`~repro.sector.master.FailureDetector`
  (suspect @ 0.5 steps, down @ 1.5 steps on the stream's virtual clock)
  declares it down two boundaries later, triggering checkpoint
  re-replication via ``client.recover``;
- ``rejoin_slave`` @ batch 24 — the dead slave restarts and is re-absorbed
  by the scan path; the detector logs the rejoin on its next heartbeat.

The stream runs durably on the Sector deployment (``attach_sector``): every
boundary uploads a versioned checkpoint, ticks the detector, and runs the
belief-driven :class:`~repro.sector.master.ReplicationDaemon`.

``--check`` asserts the ISSUE-10 acceptance criteria:

- >= 30 micro-batches over >= 3 tenants, all 4 scheduled faults fired;
- ``recoveries == 2`` (one elastic mesh recovery + one detector-driven
  Sector recovery) and exactly 2 compile-cache misses (warm-up + the one
  post-shrink recompile);
- the final carry snapshot is multiset-identical to a fault-free one-shot
  batch run over everything delivered, with zero duplicate deliveries and
  zero failed requests (exactly-once end to end);
- a second same-seed soak replays byte-identical ``(events, counts)``;
- recovery overhead (run_seconds vs a chaos-free soak) stays bounded.

Merges ``stream_chaos_*`` rows into ``BENCH_kernels.json`` and (with
``--events-log``) writes the chaos audit log for the CI artifact.

Run:  PYTHONPATH=src python benchmarks/stream_chaos_bench.py \
          [--check] [--json P] [--events-log P]
"""

from __future__ import annotations

import os
import sys

if "jax" not in sys.modules:        # standalone: give the soak 8 devices
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import collections
import json
import tempfile
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

VOCAB = 64
NUM_BUCKETS = 8
WEIGHTS = {"free": 1.0, "pro": 3.0, "enterprise": 4.0}
DEPTH_TARGET = 12
SCHEDULE_SEED = 7
STEPS = 34                          # 2 batches fail -> 32 complete (>= 30)


def _build_pipeline():
    from repro.core.mapreduce import default_hash, reduce_by_key_sum
    from repro.sphere.dataflow import Dataflow

    def emit(rec):
        return {"key": rec["word"].astype(jnp.int32),
                "value": jnp.ones_like(rec["word"], jnp.int32)}

    def count(rec, valid):
        k, v, dropped = reduce_by_key_sum(rec["key"], rec["value"], valid)
        return {"key": k, "value": v}, k >= 0, dropped

    return (Dataflow.stream_source()
            .map(emit)
            .shuffle(by=lambda r: default_hash(r["key"], NUM_BUCKETS),
                     num_buckets=NUM_BUCKETS)
            .reduce(count))


def _schedule():
    from repro.sphere.chaos import ChaosSchedule, FaultPlan
    return ChaosSchedule([
        FaultPlan(kind="lose_batch", at_batch=4),
        FaultPlan(kind="lose_device", at_batch=10),
        FaultPlan(kind="kill_slave", at_batch=16),
        FaultPlan(kind="rejoin_slave", at_batch=24),
    ], seed=SCHEDULE_SEED)


def soak(chaos: bool = True, steps: int = STEPS) -> Dict[str, object]:
    from repro.core.retry import RetryPolicy
    from repro.launch.train import make_sector
    from repro.sector.master import FailureDetector, ReplicationDaemon
    from repro.sphere.dataflow import SPMDExecutor
    from repro.sphere.streaming import (QueueFull, StreamExecutor,
                                        TenantQueue)

    ndev = len(jax.devices())
    micro_batch = 64 * ndev
    cost = micro_batch // 8
    mesh = jax.make_mesh((ndev,), ("data",))
    inner = SPMDExecutor(mesh)
    queue = TenantQueue(quantum=float(cost), capacity=DEPTH_TARGET,
                        max_requeues=5,
                        # deterministic backoff on every requeue: < 1 step,
                        # so a requeued ticket is ready again next batch
                        retry_policy=RetryPolicy(base=0.25, cap=2.0,
                                                 jitter=0.1, seed=3))
    for name, w in WEIGHTS.items():
        queue.register(name, weight=w)
    vclock = {"now": 0.0}
    schedule = _schedule() if chaos else None
    ex = StreamExecutor(inner, _build_pipeline(), micro_batch=micro_batch,
                        carry_capacity=VOCAB, queue=queue,
                        clock=lambda: vclock["now"], chaos=schedule)

    with tempfile.TemporaryDirectory() as root:
        master, client, _ = make_sector(root, num_slaves=4, replication=2)
        det = FailureDetector(master, suspect_after=0.5, down_after=1.5,
                              clock=lambda: vclock["now"])
        daemon = ReplicationDaemon(master, clock=lambda: vclock["now"],
                                   detector=det)
        ex.attach_sector(master, client, daemon=daemon, detector=det,
                         retain=8)

        rng = np.random.default_rng(0)

        def make_request():
            return {"word": rng.integers(0, VOCAB,
                                         size=cost).astype(np.uint8)}

        delivered_count: collections.Counter = collections.Counter()
        delivered_payloads: Dict[int, np.ndarray] = {}
        dropped = 0

        def top_up():
            for name in WEIGHTS:
                for _ in range(DEPTH_TARGET + 2):
                    try:
                        ex.submit(make_request(), tenant=name)
                    except QueueFull:
                        break

        def record(batch):
            nonlocal dropped
            if batch is None:
                return
            dropped += batch.dropped
            for tk in batch.delivered:
                delivered_count[tk.req_id] += 1
                delivered_payloads[tk.req_id] = tk.payload["word"]

        for step in range(steps):
            vclock["now"] = float(step)
            top_up()
            record(ex.step())
        # drain without top-up so every admitted request is delivered
        while queue.pending():
            vclock["now"] += 1.0
            record(ex.step())

        stats = ex.stats()
        tstats = stats["tenants"]

        # stream/batch equivalence: final carry snapshot vs one-shot over
        # the concatenation of everything delivered — on a fresh full mesh
        snap = ex.carry_state()
        got = {int(k): int(v) for k, v in zip(snap["key"], snap["value"])}
        allwords = np.concatenate([delivered_payloads[i]
                                   for i in sorted(delivered_payloads)])
        oneshot = SPMDExecutor(mesh)
        with mesh:
            res = oneshot.run(_build_pipeline(),
                              {"word": jnp.asarray(allwords)})
        rec = res.valid_records()
        want = {int(k): int(v) for k, v in zip(rec["key"], rec["value"])}

        return {
            "ndev": ndev,
            "end_devices": ex.inner.axis_size,
            "micro_batch": micro_batch,
            "tenants": len(WEIGHTS),
            "steps": stats["steps"],
            "records_in": stats["records_in"],
            "records_per_s": stats["records_per_s"],
            "run_seconds": stats["run_seconds"],
            "batch_failures": stats["batch_failures"],
            "recoveries": stats["recoveries"],
            "cache": stats["cache"],
            "faults_fired": (schedule.fired_count if schedule else 0),
            "faults_total": (len(schedule.faults) if schedule else 0),
            "events": list(schedule.events) if schedule else [],
            "counts": dict(sorted(got.items())),
            "detector": dict(det.stats),
            "master": dict(master.stats),
            "requeues": sum(t["requeues"] for t in tstats.values()),
            "failed": sum(t["failed"] for t in tstats.values()),
            "max_deliveries_per_request": max(delivered_count.values()),
            "delivered_requests": len(delivered_count),
            "dropped": dropped,
            "stream_equals_batch": got == want,
        }


def check(res: Dict[str, object], replay: Dict[str, object],
          baseline: Dict[str, object]) -> List[str]:
    failures = []
    if res["tenants"] < 3 or res["steps"] < 30:
        failures.append(f"soak too small: {res['tenants']} tenants over "
                        f"{res['steps']} micro-batches (need >=3 over >=30)")
    if res["faults_fired"] != res["faults_total"] or res["faults_total"] < 3:
        failures.append(f"schedule incomplete: {res['faults_fired']}/"
                        f"{res['faults_total']} faults fired (need all, >=3)")
    if res["recoveries"] != 2:
        failures.append(f"recoveries={res['recoveries']} (want 2: one "
                        f"elastic mesh recovery + one Sector recovery)")
    if res["cache"]["misses"] != 2:
        failures.append(f"cache misses={res['cache']['misses']} (want 2: "
                        f"warm-up + exactly one post-shrink recompile)")
    if res["max_deliveries_per_request"] != 1:
        failures.append(f"duplicate delivery: a request completed "
                        f"{res['max_deliveries_per_request']} times")
    if res["failed"] or res["dropped"]:
        failures.append(f"lost work: {res['failed']} failed requests, "
                        f"{res['dropped']} dropped records")
    if not res["stream_equals_batch"]:
        failures.append("chaos-surviving stream snapshot != fault-free "
                        "one-shot batch run multiset")
    if (res["events"], res["counts"]) != (replay["events"],
                                          replay["counts"]):
        failures.append("same-seed replay diverged: (events, counts) not "
                        "byte-identical across two runs")
    overhead = res["run_seconds"] / max(baseline["run_seconds"], 1e-9)
    if overhead > 10.0:
        failures.append(f"recovery overhead {overhead:.1f}x the chaos-free "
                        f"soak (want <= 10x)")
    return failures


def _merge_json(json_path: str, res: Dict[str, object],
                baseline: Dict[str, object]) -> None:
    try:
        with open(json_path) as f:
            payload = json.load(f)
    except (OSError, ValueError):
        payload = {"schema": "repro.kernel_bench.v1", "results": {}}
    payload.setdefault("results", {})
    payload["results"]["stream_chaos_recovery_overhead"] = {
        "owner": "stream_chaos",
        "chaos_run_seconds": res["run_seconds"],
        "baseline_run_seconds": baseline["run_seconds"],
        "overhead_x": res["run_seconds"] / max(baseline["run_seconds"],
                                               1e-9),
        "recoveries": res["recoveries"],
        "cache_misses": res["cache"]["misses"],
        "ndev": res["ndev"], "end_devices": res["end_devices"],
    }
    payload["results"]["stream_chaos_exactly_once"] = {
        "owner": "stream_chaos",
        "delivered_requests": res["delivered_requests"],
        "max_deliveries_per_request": res["max_deliveries_per_request"],
        "requeues": res["requeues"], "failed": res["failed"],
        "stream_equals_batch": res["stream_equals_batch"],
    }
    payload["results"]["stream_chaos_soak"] = {
        "owner": "stream_chaos",
        "steps": res["steps"], "tenants": res["tenants"],
        "faults_fired": res["faults_fired"],
        "batch_failures": res["batch_failures"],
        "detector": res["detector"],
        "events": len(res["events"]),
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)


def run(csv: bool = True, json_path: Optional[str] = None,
        events_log: Optional[str] = None):
    res = soak(chaos=True)
    replay = soak(chaos=True)
    baseline = soak(chaos=False)
    overhead = res["run_seconds"] / max(baseline["run_seconds"], 1e-9)
    replayed = (res["events"], res["counts"]) == (replay["events"],
                                                  replay["counts"])
    lines = [
        f"stream_chaos_soak,0,{res['steps']} batches x {res['tenants']} "
        f"tenants; {res['faults_fired']}/{res['faults_total']} faults "
        f"fired; mesh {res['ndev']}->{res['end_devices']} devices",
        f"stream_chaos_recovery,0,recoveries={res['recoveries']} "
        f"cache_misses={res['cache']['misses']} overhead={overhead:.2f}x "
        f"vs chaos-free",
        f"stream_chaos_exactly_once,0,delivered={res['delivered_requests']} "
        f"max_per_req={res['max_deliveries_per_request']} "
        f"requeues={res['requeues']} failed={res['failed']} "
        f"equal_to_batch={res['stream_equals_batch']}",
        f"stream_chaos_replay,0,byte_identical={replayed} "
        f"({len(res['events'])} audit events)",
    ]
    if json_path:
        _merge_json(json_path, res, baseline)
        lines.append(f"stream_chaos_json,0,merged into {json_path}")
    if events_log:
        with open(events_log, "w") as f:
            f.write("\n".join(res["events"]) + "\n")
        lines.append(f"stream_chaos_events,0,audit log -> {events_log}")
    run.last_result = (res, replay, baseline)
    return lines


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--json", default=None,
                    help="merge results into this BENCH json")
    ap.add_argument("--events-log", default=None,
                    help="write the chaos audit log here (CI artifact)")
    args = ap.parse_args()
    if args.json is None and args.check:
        args.json = "BENCH_kernels.json"   # gated runs always leave a row
    for line in run(json_path=args.json, events_log=args.events_log):
        print(line)
    if args.check:
        failures = check(*run.last_result)
        if failures:
            for f in failures:
                print(f"CHECK FAIL: {f}")
            sys.exit(1)
        res = run.last_result[0]
        print(f"CHECK OK: {res['steps']} micro-batches survived "
              f"{res['faults_fired']} scheduled faults with "
              f"{res['recoveries']} recoveries, exactly-once delivery, "
              f"stream == batch, byte-identical same-seed replay")


if __name__ == "__main__":
    main()
