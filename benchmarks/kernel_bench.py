"""Pallas kernel microbenchmarks vs the jnp oracles — and vs the retired
argsort send path.

On this CPU container the Pallas kernels run in interpret mode, so the
``*_pallas_interp`` rows measure *oracle-equivalent semantics* plus
interpreter overhead, not TPU performance; the jnp rows (the fused O(n)
send path the shuffles run with ``use_pallas=False``, and the XLA oracles)
are real compiled-CPU numbers. On a real TPU set REPRO_PALLAS_INTERPRET=0.

Cases:

- ``bucket_hist``       — MXU one-hot histogram vs jnp one-hot oracle.
- ``partition_pack``    — the ISSUE-4 headline: the fused O(n) partition/
                          pack send path vs the stable-argsort + histogram
                          + gather layout it replaced, on the 2^16-record
                          shuffle send microbenchmark.
- ``bitonic_sort``      — multi-segment bitonic kernel vs XLA row sort.
- ``segmented_sort``    — stage-2 economics: sorting bpd bucket-major
                          segments of R/bpd vs one R-row segment
                          (O(R log² (R/bpd)) vs O(R log² R)).

``--json PATH`` additionally writes the machine-readable
``BENCH_kernels.json`` (the first point of the perf trajectory; CI runs
this as a smoke step and ``--check`` asserts the fused partition path beats
the argsort layout).
"""

from __future__ import annotations

import json
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters=5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / iters


def _argsort_send_layout(num_dest: int, capacity: int):
    """The pre-ISSUE-4 send path (stable argsort + bincount + gather),
    preserved here as the baseline the fused path must beat."""

    @jax.jit
    def layout(dest, col):
        n = dest.shape[0]
        order = jnp.argsort(dest, stable=True)
        counts = jnp.bincount(dest, length=num_dest)
        offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                   jnp.cumsum(counts)[:-1]])
        cap_iota = jnp.arange(capacity, dtype=jnp.int32)[None, :]
        src = jnp.clip(offsets[:, None] + cap_iota, 0, n - 1).reshape(-1)
        origin = jnp.take(order.astype(jnp.int32), src)
        tile = jnp.take(col, origin, axis=0).reshape(
            (num_dest, capacity) + col.shape[1:])
        return tile, cap_iota < counts[:, None]

    return layout


def run(csv: bool = True, json_path: str | None = None) -> List[str]:
    rng = np.random.default_rng(0)
    lines: List[str] = []
    results: Dict[str, Dict[str, float]] = {}

    def record(name: str, t: float, elems: int, extra: str = ""):
        results[name] = {"us_per_call": t * 1e6,
                         "melem_per_s": elems / t / 1e6}
        lines.append(f"kernel_{name},{t * 1e6:.1f},"
                     f"{elems / t / 1e6:.2f}Melem/s{extra}")

    # -- bucket histogram -----------------------------------------------------
    n, buckets = 1 << 16, 256
    ids = jnp.asarray(rng.integers(0, buckets, size=n).astype(np.int32))
    record("bucket_hist_pallas_interp",
           _time(lambda x: ops.bucket_histogram(x, buckets), ids), n)
    record("bucket_hist_oracle",
           _time(lambda x: ref.bucket_histogram_ref(x, buckets), ids), n)

    # -- fused partition/pack vs the argsort send path ------------------------
    n, num_dest = 1 << 16, 8
    capacity = 2 * n // num_dest
    dest = jnp.asarray(rng.integers(0, num_dest, size=n).astype(np.int32))
    data = jnp.asarray(rng.integers(0, 1 << 30, size=(n, 4)).astype(np.int32))
    baseline = _argsort_send_layout(num_dest, capacity)
    fused = jax.jit(lambda d, x: ops.partition_pack(
        [x], d, num_dest, capacity, use_pallas=False))
    fused_k = jax.jit(lambda d, x: ops.partition_pack(
        [x], d, num_dest, capacity, use_pallas=True))
    t_arg = _time(baseline, dest, data)
    t_fused = _time(fused, dest, data)
    t_fused_k = _time(fused_k, dest, data)
    record("partition_argsort_baseline", t_arg, n)
    record("partition_pack_fused", t_fused, n,
           extra=f" speedup_vs_argsort={t_arg / t_fused:.2f}x")
    record("partition_pack_pallas_interp", t_fused_k, n)
    results["partition_speedup_vs_argsort"] = {
        "ratio": t_arg / t_fused, "n": n, "num_dest": num_dest}

    # -- bitonic sort (multi-segment blocks) ----------------------------------
    rows, cols = 8, 4096
    keys = jnp.asarray(rng.integers(0, 1 << 30,
                                    size=(rows, cols)).astype(np.int32))
    vals = jnp.asarray(np.arange(rows * cols,
                                 dtype=np.int32).reshape(rows, cols))
    record("bitonic_sort_8x4096_pallas_interp",
           _time(ops.sort_kv_segments, keys, vals), rows * cols)
    record("bitonic_sort_8x4096_oracle",
           _time(ref.sort_kv_segments_ref, keys, vals), rows * cols)

    # -- segmented stage-2 sort: bpd segments of R/bpd vs one of R ------------
    r, bpd = 1 << 16, 16
    flat = jnp.asarray(rng.integers(0, 1 << 30, size=r).astype(np.int32))
    seg = flat.reshape(bpd, r // bpd)
    t_seg = _time(ops.sort_segments, seg)
    t_one = _time(ops.sort_segments, flat.reshape(1, r))
    record("segmented_sort_16x4096_pallas_interp", t_seg, r,
           extra=f" speedup_vs_single_segment={t_one / t_seg:.2f}x")
    record("segmented_sort_1x65536_pallas_interp", t_one, r)
    record("segmented_sort_16x4096_oracle",
           _time(lambda x: ref.sort_segments_ref(x), seg), r)
    results["segmented_speedup_vs_single"] = {
        "ratio": t_one / t_seg, "r": r, "bpd": bpd}

    if json_path:
        from repro.kernels.ops import _interpret_default
        payload = {
            "schema": "repro.kernel_bench.v1",
            "backend": jax.default_backend(),
            "pallas_interpret": _interpret_default(),
            "note": ("CPU container: Pallas rows run in interpret mode; "
                     "jnp/XLA rows are compiled. The trajectory point is "
                     "partition_speedup_vs_argsort (fused O(n) send path "
                     "vs the retired stable-argsort layout)."),
            "results": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        lines.append(f"kernel_bench_json,0,written {json_path}")
    return lines


def main() -> None:
    args = sys.argv[1:]
    json_path = None
    check = "--check" in args
    if "--json" in args:
        idx = args.index("--json") + 1
        if idx >= len(args):
            print("usage: kernel_bench.py [--json PATH] [--check]")
            sys.exit(2)
        json_path = args[idx]
    elif check:
        json_path = "BENCH_kernels.json"
    for line in run(json_path=json_path):
        print(line)
    if check:
        with open(json_path) as f:
            payload = json.load(f)
        ratio = payload["results"]["partition_speedup_vs_argsort"]["ratio"]
        if ratio <= 1.0:
            print(f"CHECK FAILED: fused partition path is not beating the "
                  f"argsort layout (speedup {ratio:.2f}x)")
            sys.exit(1)
        print(f"CHECK OK: fused partition path {ratio:.2f}x vs argsort")


if __name__ == "__main__":
    main()
