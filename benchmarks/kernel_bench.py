"""Pallas kernel microbenchmarks vs the jnp oracles — and vs the retired
argsort send path.

On this CPU container the Pallas kernels run in interpret mode, so the
``*_pallas_interp`` rows measure *oracle-equivalent semantics* plus
interpreter overhead, not TPU performance; the jnp rows (the fused O(n)
send path the shuffles run with ``use_pallas=False``, and the XLA oracles)
are real compiled-CPU numbers. On a real TPU set REPRO_PALLAS_INTERPRET=0.

Cases:

- ``bucket_hist``       — MXU one-hot histogram vs jnp one-hot oracle.
- ``partition_pack``    — the ISSUE-4 headline: the fused O(n) partition/
                          pack send path vs the stable-argsort + histogram
                          + gather layout it replaced, on the 2^16-record
                          shuffle send microbenchmark.
- ``bitonic_sort``      — multi-segment bitonic kernel vs XLA row sort.
- ``segmented_sort``    — stage-2 economics: sorting bpd bucket-major
                          segments of R/bpd vs one R-row segment
                          (O(R log² (R/bpd)) vs O(R log² R)).
- ``segmented_sort_table`` — the autotuner's evidence: a (R, bpd) grid of
                          KV segment sorts (the real stage-2 hot path)
                          timed per algorithm {bitonic, radix, oracle} and
                          through the autotuned entry point; per-cell
                          ``autotune_choice`` rows record what the
                          autotuner picked and why (measured Melem/s, or
                          the reason a candidate was skipped). The
                          resolved table is persisted as ``autotune_table``
                          (pre-loadable via REPRO_AUTOTUNE_TABLE; CI
                          uploads it as a workflow artifact).
- ``wire_bytes_per_hop``   — the ISSUE-5 headline: bytes one flat shuffle
                          hop ships for int32-pair records under the fused
                          one-wire-tensor frame (payload rows + one
                          count-header row per tile) vs the retired
                          4-tensor layout (data + valid + bucket + src,
                          each capacity-padded).
- ``collectives_per_hop``  — jaxpr-counted ``all_to_all`` per hop (flat /
                          hierarchical, shuffle / combine, chunked), traced
                          on 8 virtual devices in a subprocess; also checks
                          the chunked (W=4) hop delivers the identical
                          record multiset as W=1.

``--json PATH`` additionally writes the machine-readable
``BENCH_kernels.json`` (the perf trajectory; CI runs this as a smoke step
and ``--check`` asserts the fused partition path beats the argsort layout,
the fused frame halves int32-pair wire bytes, collectives-per-hop stays at
1 flat / 2 hierarchical per chunk, the segmented stage-2 speedup holds the
1.3x floor, and on every sweep cell the autotuned entry point reaches at
least 0.95x of the best measured candidate — in particular it is never
slower than the jnp oracle).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.records import WireFrame
from repro.kernels import autotune, ops, ref
from repro.obs.metrics import REGISTRY

#: the (R, bpd) grid of the autotune sweep — R total records per shard,
#: bpd buckets per device (so each cell sorts bpd segments of R/bpd).
SWEEP_R = (1 << 14, 1 << 16)
SWEEP_BPD = (1, 4, 16, 64)

#: every row this bench writes into BENCH_kernels.json is stamped with this
#: owner; the merge keeps prior rows stamped by OTHER owners (streaming,
#: chaos, obs benches) and rewrites only its own.
OWNER = "kernel"


def _time(fn, *args, iters=5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / iters


def _time_grid(fns: Dict[str, object], args, iters: int = 6) -> Dict[str, float]:
    """Best-of-N timing of several callables on the same inputs, rounds
    interleaved so slow drift (CPU frequency, background load) hits every
    candidate equally, and the order rotated each round so no candidate is
    permanently stuck running cache-cold behind a particular neighbour —
    used for the autotune table, where the per-cell gate compares
    candidates against each other and a systematic 5% skew between
    separate timing loops would be a false failure."""
    for fn in fns.values():           # compile outside the timed region
        jax.block_until_ready(fn(*args))
    names = list(fns)
    best = {name: float("inf") for name in names}
    for i in range(iters):
        for name in names[i % len(names):] + names[:i % len(names)]:
            t0 = time.perf_counter()
            jax.block_until_ready(fns[name](*args))
            best[name] = min(best[name], time.perf_counter() - t0)
    return best


def _argsort_send_layout(num_dest: int, capacity: int):
    """The pre-ISSUE-4 send path (stable argsort + bincount + gather),
    preserved here as the baseline the fused path must beat."""

    @jax.jit
    def layout(dest, col):
        n = dest.shape[0]
        order = jnp.argsort(dest, stable=True)
        counts = jnp.bincount(dest, length=num_dest)
        offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                   jnp.cumsum(counts)[:-1]])
        cap_iota = jnp.arange(capacity, dtype=jnp.int32)[None, :]
        src = jnp.clip(offsets[:, None] + cap_iota, 0, n - 1).reshape(-1)
        origin = jnp.take(order.astype(jnp.int32), src)
        tile = jnp.take(col, origin, axis=0).reshape(
            (num_dest, capacity) + col.shape[1:])
        return tile, cap_iota < counts[:, None]

    return layout


_COLLECTIVES_CODE = """
import jax, numpy as np, jax.numpy as jnp, dataclasses, json
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import shard_map
from repro.core.introspect import collective_counts
from repro.core.shuffle import ShufflePlan

mesh1 = jax.make_mesh((8,), ("data",))
mesh2 = jax.make_mesh((2, 4), ("dc", "node"))
N = 8 * 512
n_local = N // 8
flat = ShufflePlan.for_mesh(mesh1, 16, n_local, 2.5, ("data",))
hier = ShufflePlan.for_mesh(mesh2, 16, n_local, 2.5, ("dc", "node"))
d0 = jnp.zeros((N, 3), jnp.int32)
b0 = jnp.zeros((N,), jnp.int32)

def shuf(plan):
    def f(d, b):
        r = plan.shuffle(d, b.reshape(-1))
        return r.data, r.valid, r.dropped
    return f

def shuf_comb(plan):
    def f(d, b):
        r = plan.shuffle(d, b.reshape(-1))
        return plan.combine(r.data.astype(jnp.float32) * 2.0, r, n_local)
    return f

def count3(fn, mesh, spec):
    f = shard_map(fn, mesh=mesh, in_specs=(spec, spec),
                  out_specs=(spec, spec, P()), check_vma=False)
    return collective_counts(f, d0, b0)["all_to_all"]

def count2(fn, mesh, spec):
    f = shard_map(fn, mesh=mesh, in_specs=(spec, spec),
                  out_specs=(spec, spec), check_vma=False)
    return collective_counts(f, d0, b0)["all_to_all"]

s1, s2 = P("data"), P(("dc", "node"))
out = {
    "flat_shuffle": count3(shuf(flat), mesh1, s1),
    "hier_shuffle": count3(shuf(hier), mesh2, s2),
    "flat_shuffle_w4": count3(shuf(dataclasses.replace(flat, chunks=4)),
                              mesh1, s1),
    "hier_shuffle_w4": count3(shuf(dataclasses.replace(hier, chunks=4)),
                              mesh2, s2),
}
out["flat_with_combine"] = count2(shuf_comb(flat), mesh1, s1)
out["hier_with_combine"] = count2(shuf_comb(hier), mesh2, s2)

# chunked W=4 must deliver the identical record multiset as W=1
rng = np.random.default_rng(0)
data = rng.integers(0, 1 << 20, size=(N, 3)).astype(np.int32)
buckets = rng.integers(0, 16, size=N).astype(np.int32)
def run_plan(plan):
    dd = jax.device_put(jnp.asarray(data), NamedSharding(mesh1, s1))
    bd = jax.device_put(jnp.asarray(buckets), NamedSharding(mesh1, s1))
    def udf(d, b):
        r = plan.shuffle(d, b.reshape(-1))
        return r.data.reshape(-1, 3), r.valid.reshape(-1), r.dropped
    with mesh1:
        rd, rv, drop = shard_map(udf, mesh=mesh1, in_specs=(s1, s1),
                                 out_specs=(s1, s1, P()),
                                 check_vma=False)(dd, bd)
    assert int(drop) == 0
    return sorted(map(tuple, np.asarray(rd)[np.asarray(rv)]))
out["chunked_match"] = run_plan(flat) == run_plan(
    dataclasses.replace(flat, chunks=4))
print("RESULT " + json.dumps(out))
"""


def collectives_per_hop() -> Dict[str, object]:
    """jaxpr-count all_to_all per shuffle hop on 8 virtual devices (own
    subprocess: XLA_FLAGS must be set before jax initializes)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _COLLECTIVES_CODE], env=env,
                          capture_output=True, text=True, timeout=520)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT "):])


def wire_bytes_per_hop(n: int = 1 << 16, num_dest: int = 8) -> Dict[str, float]:
    """Static wire accounting of one flat shuffle hop over int32-pair
    records: the fused one-tensor frame vs the retired 4-tensor layout."""
    capacity = 2 * n // num_dest
    rec_bytes = 8                              # (key, value) int32 pair
    # retired layout: data + valid(bool byte) + bucket(i32) + src(i32),
    # each its own capacity-padded all_to_all tile
    legacy = num_dest * capacity * (rec_bytes + 1 + 4 + 4)
    fused_min = num_dest * WireFrame("int32", (2,)).tile_nbytes(capacity)
    fused_full = num_dest * WireFrame(
        "int32", (2,), meta=("bucket", "src")).tile_nbytes(capacity)
    return {
        "n": n, "num_dest": num_dest, "capacity": capacity,
        "rec_bytes": rec_bytes,
        "legacy_4tensor_bytes": legacy,
        "fused_frame_bytes_min": fused_min,    # wire_meta="min" (dataflow)
        "fused_frame_bytes_full": fused_full,  # wire_meta="full" (combine)
        "reduction_min": legacy / fused_min,
        "reduction_full": legacy / fused_full,
    }


def run(csv: bool = True, json_path: str | None = None) -> List[str]:
    rng = np.random.default_rng(0)
    lines: List[str] = []
    results: Dict[str, Dict[str, float]] = {}

    def record(name: str, t: float, elems: int, extra: str = ""):
        results[name] = {"us_per_call": t * 1e6,
                         "melem_per_s": elems / t / 1e6,
                         "owner": OWNER}
        lines.append(f"kernel_{name},{t * 1e6:.1f},"
                     f"{elems / t / 1e6:.2f}Melem/s{extra}")

    # -- bucket histogram -----------------------------------------------------
    n, buckets = 1 << 16, 256
    ids = jnp.asarray(rng.integers(0, buckets, size=n).astype(np.int32))
    record("bucket_hist_pallas_interp",
           _time(lambda x: ops.bucket_histogram(x, buckets), ids), n)
    record("bucket_hist_oracle",
           _time(lambda x: ref.bucket_histogram_ref(x, buckets), ids), n)

    # -- fused partition/pack vs the argsort send path ------------------------
    n, num_dest = 1 << 16, 8
    capacity = 2 * n // num_dest
    dest = jnp.asarray(rng.integers(0, num_dest, size=n).astype(np.int32))
    data = jnp.asarray(rng.integers(0, 1 << 30, size=(n, 4)).astype(np.int32))
    baseline = _argsort_send_layout(num_dest, capacity)
    fused = jax.jit(lambda d, x: ops.partition_pack(
        [x], d, num_dest, capacity, use_pallas=False))
    fused_k = jax.jit(lambda d, x: ops.partition_pack(
        [x], d, num_dest, capacity, use_pallas=True))
    t_arg = _time(baseline, dest, data)
    t_fused = _time(fused, dest, data)
    t_fused_k = _time(fused_k, dest, data)
    record("partition_argsort_baseline", t_arg, n)
    record("partition_pack_fused", t_fused, n,
           extra=f" speedup_vs_argsort={t_arg / t_fused:.2f}x")
    record("partition_pack_pallas_interp", t_fused_k, n)
    results["partition_speedup_vs_argsort"] = {
        "ratio": t_arg / t_fused, "n": n, "num_dest": num_dest,
        "owner": OWNER}

    # -- bitonic sort (multi-segment blocks) ----------------------------------
    rows, cols = 8, 4096
    keys = jnp.asarray(rng.integers(0, 1 << 30,
                                    size=(rows, cols)).astype(np.int32))
    vals = jnp.asarray(np.arange(rows * cols,
                                 dtype=np.int32).reshape(rows, cols))
    record("bitonic_sort_8x4096_pallas_interp",
           _time(lambda k, v: ops.sort_kv_segments(k, v, algo="bitonic"),
                 keys, vals), rows * cols)
    record("bitonic_sort_8x4096_oracle",
           _time(ref.sort_kv_segments_ref, keys, vals), rows * cols)

    # -- segmented stage-2 sort: bpd segments of R/bpd vs one of R ------------
    # pinned to the bitonic kernel so the trajectory metric keeps its
    # historical meaning (segment economics of ONE algorithm, not the
    # autotuner picking different winners at the two shapes)
    r, bpd = 1 << 16, 16
    flat = jnp.asarray(rng.integers(0, 1 << 30, size=r).astype(np.int32))
    seg = flat.reshape(bpd, r // bpd)
    seg_times = _time_grid(
        {"seg": lambda _: ops.sort_segments(seg, algo="bitonic"),
         "one": lambda _: ops.sort_segments(flat.reshape(1, r),
                                            algo="bitonic")},
        (None,))
    t_seg, t_one = seg_times["seg"], seg_times["one"]
    record("segmented_sort_16x4096_pallas_interp", t_seg, r,
           extra=f" speedup_vs_single_segment={t_one / t_seg:.2f}x")
    record("segmented_sort_1x65536_pallas_interp", t_one, r)
    record("segmented_sort_16x4096_oracle",
           _time(lambda x: ref.sort_segments_ref(x), seg), r)
    # published through the metrics registry too, so one snapshot carries
    # the perf trajectory alongside the runtime series
    REGISTRY.gauge("kernel.segmented_speedup_vs_single").set(t_one / t_seg)
    results["segmented_speedup_vs_single"] = {
        "ratio": t_one / t_seg, "r": r, "bpd": bpd, "owner": OWNER,
        "metric": "kernel.segmented_speedup_vs_single",
        "registry_value": REGISTRY.gauge(
            "kernel.segmented_speedup_vs_single").value}

    # -- autotune sweep: (R, bpd) × {bitonic, radix, oracle} KV cells ---------
    # Every cell is timed three ways on the same data: each candidate pinned
    # via algo=..., then the autotuned entry point (algo=None, which is what
    # the stage-2 hot path actually calls). autotune.choose() supplies the
    # decision record — its own synthetic-data measurements and the reason
    # any candidate was skipped (radix in interpret mode is only measured
    # inside its envelope; there are no silent caps).
    table: Dict[str, Dict[str, object]] = {}
    for r_tot in SWEEP_R:
        for bpd_c in SWEEP_BPD:
            s = r_tot // bpd_c
            k = jnp.asarray(rng.integers(
                0, np.iinfo(np.int32).max,
                size=(bpd_c, s)).astype(np.int32))
            v = jnp.asarray(np.arange(r_tot, dtype=np.int32)
                            .reshape(bpd_c, s))
            ch = autotune.choose(bpd_c, s, jnp.int32, kv=True)
            fns = {a: (lambda kk, vv, a=a:
                       ops.sort_kv_segments(kk, vv, algo=a))
                   for a in autotune.ALGOS if a not in ch.skipped}
            fns["autotuned"] = ops.sort_kv_segments
            times = _time_grid(fns, (k, v))
            # heavy-tail CPU timing: the autotuned entry dispatches to one
            # of the pinned candidates, so if its noise floor is >5% off
            # the best pinned one the estimate hasn't converged — pool more
            # interleaved rounds (elementwise min) before recording.
            for _ in range(2):
                t_best = min(t for a, t in times.items() if a != "autotuned")
                if times["autotuned"] <= t_best / 0.95:
                    break
                more = _time_grid(fns, (k, v))
                times = {a: min(times[a], more[a]) for a in times}
            per_algo = {a: r_tot / t / 1e6 for a, t in times.items()
                        if a != "autotuned"}
            t_auto = times["autotuned"]
            auto_melem = r_tot / t_auto / 1e6
            cell = f"{bpd_c}x{s}"
            table[cell] = {
                "r": r_tot, "bpd": bpd_c, "segment_len": s,
                "melem_per_s": per_algo,
                "autotuned_melem_per_s": auto_melem,
                "chosen": ch.algo, "source": ch.source,
                "skipped": dict(ch.skipped)}
            results[f"autotune_choice_{cell}"] = {
                "owner": OWNER, "algo": ch.algo, "source": ch.source,
                "melem_per_s": dict(ch.melem),
                "skipped": dict(ch.skipped)}
            lines.append(
                f"kernel_sort_kv_{cell},{t_auto * 1e6:.1f},"
                f"{auto_melem:.2f}Melem/s autotuned={ch.algo} " +
                " ".join(f"{a}={m:.2f}" for a, m in sorted(per_algo.items())))
    results["segmented_sort_table"] = {"owner": OWNER, "cells": table}
    results["autotune_table"] = {"owner": OWNER,
                                 "entries": autotune.export_table()}

    # -- one-wire-tensor shuffle: wire bytes + collective counts per hop ------
    wb = wire_bytes_per_hop()
    results["wire_bytes_per_hop"] = dict(wb, owner=OWNER)
    lines.append(
        f"kernel_wire_bytes_per_hop,0,"
        f"legacy={wb['legacy_4tensor_bytes']} "
        f"fused_min={wb['fused_frame_bytes_min']} "
        f"reduction={wb['reduction_min']:.2f}x "
        f"(int32-pair records, {wb['num_dest']} dests, "
        f"cap={wb['capacity']})")
    cc = collectives_per_hop()
    results["collectives_per_hop"] = dict(cc, owner=OWNER)
    lines.append(
        f"kernel_collectives_per_hop,0,"
        f"flat={cc['flat_shuffle']} hier={cc['hier_shuffle']} "
        f"flat_w4={cc['flat_shuffle_w4']} hier_w4={cc['hier_shuffle_w4']} "
        f"flat+combine={cc['flat_with_combine']} "
        f"hier+combine={cc['hier_with_combine']} "
        f"chunked_match={cc['chunked_match']} "
        f"(all_to_all per hop; was 4 flat / 9 hier / 7 / 15 with combine)")

    if json_path:
        from repro.kernels.ops import _interpret_default
        # other benches (streaming, chaos, obs) merge their trajectory
        # points into the same file, each stamped with an "owner" field —
        # keep every row another owner wrote, rewrite only our own.
        # Rows without an owner stamp are legacy kernel rows.
        try:
            with open(json_path) as f:
                prior = json.load(f).get("results", {})
            results.update({k: v for k, v in prior.items()
                            if isinstance(v, dict)
                            and v.get("owner", OWNER) != OWNER
                            and k not in results})
        except (OSError, ValueError):
            pass
        payload = {
            "schema": "repro.kernel_bench.v1",
            "backend": jax.default_backend(),
            "pallas_interpret": _interpret_default(),
            "note": ("CPU container: Pallas rows run in interpret mode; "
                     "jnp/XLA rows are compiled. The trajectory point is "
                     "partition_speedup_vs_argsort (fused O(n) send path "
                     "vs the retired stable-argsort layout)."),
            "results": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        lines.append(f"kernel_bench_json,0,written {json_path}")
    return lines


def main() -> None:
    args = sys.argv[1:]
    json_path = None
    check = "--check" in args
    if "--json" in args:
        idx = args.index("--json") + 1
        if idx >= len(args):
            print("usage: kernel_bench.py [--json PATH] [--check]")
            sys.exit(2)
        json_path = args[idx]
    elif check:
        json_path = "BENCH_kernels.json"
    for line in run(json_path=json_path):
        print(line)
    if check:
        with open(json_path) as f:
            payload = json.load(f)
        res = payload["results"]
        failures = []
        ratio = res["partition_speedup_vs_argsort"]["ratio"]
        if ratio <= 1.0:
            failures.append(f"fused partition path is not beating the "
                            f"argsort layout (speedup {ratio:.2f}x)")
        wb = res["wire_bytes_per_hop"]
        if wb["reduction_min"] < 2.0:
            failures.append(f"fused frame is not >=2x smaller than the "
                            f"4-tensor layout ({wb['reduction_min']:.2f}x)")
        cc = res["collectives_per_hop"]
        if cc["flat_shuffle"] > 1 or cc["flat_shuffle_w4"] > 4:
            failures.append(f"flat shuffle regressed above 1 all_to_all per "
                            f"hop per chunk ({cc['flat_shuffle']}, "
                            f"W4={cc['flat_shuffle_w4']})")
        if cc["hier_shuffle"] > 2 or cc["hier_shuffle_w4"] > 8:
            failures.append(f"hierarchical shuffle regressed above 2 "
                            f"all_to_all per hop per chunk "
                            f"({cc['hier_shuffle']}, "
                            f"W4={cc['hier_shuffle_w4']})")
        if cc["flat_with_combine"] > 2 or cc["hier_with_combine"] > 4:
            failures.append(f"combine collective count regressed "
                            f"(flat {cc['flat_with_combine']} > 2 or hier "
                            f"{cc['hier_with_combine']} > 4)")
        if not cc["chunked_match"]:
            failures.append("chunked (W=4) shuffle delivery differs from "
                            "W=1")
        seg = res["segmented_speedup_vs_single"]["ratio"]
        if seg < 1.3:
            failures.append(f"segmented stage-2 sort speedup vs single "
                            f"segment fell below the 1.3x floor "
                            f"({seg:.2f}x)")
        for cell, row in sorted(
                res["segmented_sort_table"]["cells"].items()):
            best_algo = max(row["melem_per_s"], key=row["melem_per_s"].get)
            best = row["melem_per_s"][best_algo]
            if row["autotuned_melem_per_s"] < 0.95 * best:
                failures.append(
                    f"autotuned sort_kv_segments at {cell} runs "
                    f"{row['autotuned_melem_per_s']:.2f} Melem/s, below "
                    f"0.95x the best candidate {best_algo} ({best:.2f}; "
                    f"autotuner chose {row['chosen']})")
            oracle = row["melem_per_s"].get("oracle")
            if oracle and row["autotuned_melem_per_s"] < 0.95 * oracle:
                failures.append(
                    f"autotuned sort_kv_segments at {cell} is slower than "
                    f"the jnp oracle ({row['autotuned_melem_per_s']:.2f} vs "
                    f"{oracle:.2f} Melem/s)")
        if failures:
            for msg in failures:
                print(f"CHECK FAILED: {msg}")
            sys.exit(1)
        ncells = len(res["segmented_sort_table"]["cells"])
        print(f"CHECK OK: fused partition {ratio:.2f}x vs argsort; wire "
              f"bytes {wb['reduction_min']:.2f}x smaller; collectives/hop "
              f"flat={cc['flat_shuffle']} hier={cc['hier_shuffle']}; "
              f"W=4 delivery matches W=1; segmented speedup {seg:.2f}x "
              f">= 1.3; autotuned sort within 0.95x of the best candidate "
              f"on all {ncells} sweep cells")


if __name__ == "__main__":
    main()
