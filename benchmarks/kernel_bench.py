"""Pallas kernel microbenchmarks vs the jnp oracles.

On this CPU container the Pallas kernels run in interpret mode, so absolute
times measure the *oracle-equivalent semantics*, not TPU performance; the
derived column reports elements/s and the oracle ratio. On a real TPU set
REPRO_PALLAS_INTERPRET=0.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters=5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / iters


def run(csv: bool = True) -> List[str]:
    rng = np.random.default_rng(0)
    lines = []

    n, buckets = 1 << 16, 256
    ids = jnp.asarray(rng.integers(0, buckets, size=n).astype(np.int32))
    t_k = _time(lambda x: ops.bucket_histogram(x, buckets), ids)
    t_r = _time(lambda x: ref.bucket_histogram_ref(x, buckets), ids)
    lines.append(f"kernel_bucket_hist_{n},{t_k * 1e6:.1f},"
                 f"{n / t_k / 1e6:.1f}Melem/s oracle={t_r * 1e6:.1f}us")

    rows, cols = 4, 4096
    keys = jnp.asarray(rng.integers(0, 1 << 30,
                                    size=(rows, cols)).astype(np.int32))
    vals = jnp.asarray(np.arange(rows * cols,
                                 dtype=np.int32).reshape(rows, cols))
    t_k = _time(ops.sort_kv_segments, keys, vals)
    t_r = _time(ref.sort_kv_segments_ref, keys, vals)
    lines.append(f"kernel_bitonic_sort_{rows}x{cols},{t_k * 1e6:.1f},"
                 f"{rows * cols / t_k / 1e6:.2f}Melem/s "
                 f"oracle={t_r * 1e6:.1f}us")
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
