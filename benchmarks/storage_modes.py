"""Storage design-decision benchmark (paper Table 2: files vs blocks).

Quantifies the paper's central storage argument on a live deployment:
- file mode (Sector): one slave contact per file read; replication created
  lazily by the daemon (writes are cheap);
- block mode (GFS/HDFS emulation): R-replicated at write time (write
  amplification) and a read touches ceil(size/block) slaves.
"""

from __future__ import annotations

import os
import tempfile
from typing import List

from repro.sector import (Master, NodeAddress, ReplicationDaemon,
                          SectorClient, SecurityServer, SlaveNode, Topology)


def _deploy(block_mode: bool, replication: int = 3):
    root = tempfile.mkdtemp(prefix="bench_modes_")
    sec = SecurityServer()
    sec.add_user("u", "pw")
    sec.allow_slaves("10.0.0.0/8")
    m = Master(sec, replication_factor=replication, block_mode=block_mode,
               block_size=8 << 10)
    topo = Topology(pods=2, racks=2, nodes_per_rack=4)
    for i, addr in enumerate(topo.all_addresses()):
        m.register_slave(SlaveNode(i, addr, os.path.join(root, f"s{i}"),
                                   ip=f"10.0.0.{i + 1}"))
    return m, SectorClient(m, "u", "pw", client_addr=NodeAddress(0, 0, 0))


def run(csv: bool = True) -> List[str]:
    lines = []
    payload = b"r" * (64 << 10)              # one 64 KiB "slice" (8 blocks)
    for mode in ("file", "block"):
        m, c = _deploy(block_mode=(mode == "block"))
        m.stats["transfers"] = 0
        for i in range(8):
            c.upload(f"/ds/f{i:02d}", payload)
        write_transfers = m.stats["transfers"]
        if mode == "file":
            ReplicationDaemon(m).run_until_stable()   # lazy replication
        m.stats["transfers"] = 0
        for i in range(8):
            assert c.download(f"/ds/f{i:02d}") == payload
        read_transfers = m.stats["transfers"]
        lines.append(
            f"storage_{mode}_mode,{read_transfers},"
            f"write_transfers={write_transfers} "
            f"read_transfers_per_file={read_transfers / 8:.0f} "
            f"(paper Table 2: files -> 1 slave/read, lazy replicas; "
            f"blocks -> replicate-at-write, many slaves/read)")
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
