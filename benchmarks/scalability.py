"""Scheduler scalability & fault-tolerance benchmarks (paper §3.5.2 claims).

- straggler sweep: makespan with/without speculative tail duplication as the
  slow-SPE fraction grows ("Sphere avoids waiting for the slow SPEs");
- crash sweep: completion and makespan as SPEs die mid-run;
- replication recovery: copies re-created per daemon tick after rack loss.
"""

from __future__ import annotations

import os
import tempfile
from typing import List

from repro.core.stream import SegmentInfo
from repro.sector import (Master, NodeAddress, ReplicationDaemon,
                          SectorClient, SecurityServer, SlaveNode, Topology)
from repro.sphere.scheduler import SegmentScheduler, SPEState


def straggler_sweep() -> List[str]:
    lines = []
    segs = [SegmentInfo(i, f"/d/f{i % 8:02d}", 0, 1000) for i in range(64)]
    locs = {f"/d/f{i:02d}": [NodeAddress(0, i % 2, i % 8)] for i in range(8)}
    for frac in (0.0, 0.125, 0.25, 0.5):
        for spec in (True, False):
            spes = []
            n = 16
            slow = int(n * frac)
            for i in range(n):
                speed = 100.0 if i >= slow else 10.0
                spes.append(SPEState(i, NodeAddress(0, i % 2, i % 8),
                                     speed=speed))
            s = SegmentScheduler(segs, spes, locs, speculate=spec)
            stats = s.run()
            assert stats["done"] == 64
            tag = "spec" if spec else "nospec"
            lines.append(f"straggler_{frac:.3f}_{tag},"
                         f"{stats['makespan'] * 1e6:.0f},"
                         f"attempts={stats['attempts']}")
    return lines


def crash_sweep() -> List[str]:
    lines = []
    segs = [SegmentInfo(i, f"/d/f{i % 8:02d}", 0, 1000) for i in range(64)]
    locs = {f"/d/f{i:02d}": [NodeAddress(0, i % 2, i % 8)] for i in range(8)}
    for crashes in (0, 2, 4, 8):
        spes = []
        for i in range(16):
            fail = 5.0 + i if i < crashes else None
            spes.append(SPEState(i, NodeAddress(0, i % 2, i % 8),
                                 speed=100.0, fail_at=fail))
        s = SegmentScheduler(segs, spes, locs, timeout=2.0)
        stats = s.run()
        assert stats["done"] == 64, stats
        lines.append(f"crash_{crashes}spe,{stats['makespan'] * 1e6:.0f},"
                     f"attempts={stats['attempts']}")
    return lines


def replication_recovery() -> List[str]:
    lines = []
    root = tempfile.mkdtemp(prefix="bench_sector_")
    sec = SecurityServer()
    sec.add_user("u", "pw")
    sec.allow_slaves("10.0.0.0/8")
    m = Master(sec, replication_factor=3)
    topo = Topology(pods=2, racks=2, nodes_per_rack=4)
    for i, addr in enumerate(topo.all_addresses()):
        m.register_slave(SlaveNode(i, addr, os.path.join(root, f"s{i}"),
                                   ip=f"10.0.0.{i + 1}"))
    c = SectorClient(m, "u", "pw")
    for i in range(32):
        c.upload(f"/ds/f{i:03d}", b"x" * 4096)
    d = ReplicationDaemon(m)
    initial = d.run_until_stable()
    # lose a whole rack (4 slaves)
    for s in list(m.slaves.values())[:4]:
        s.kill(wipe=True)
    ticks = 0
    copies = 0
    while True:
        made = d.tick(max_copies=8)   # bounded repair bandwidth per tick
        if made == 0:
            break
        ticks += 1
        copies += made
    assert all(len([x for x in meta.locations if m.slaves[x].alive]) >= 3
               for meta in m.index.values())
    lines.append(f"replication_rack_loss,{ticks},"
                 f"initial_copies={initial} repaired={copies} "
                 f"files=32 lost=0")
    return lines


def run(csv: bool = True) -> List[str]:
    return straggler_sweep() + crash_sweep() + replication_recovery()


if __name__ == "__main__":
    for line in run():
        print(line)
