"""Roofline report: aggregates the dry-run JSONs into the EXPERIMENTS.md
table (one row per arch x shape x mesh)."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_rows(results_dir: str = RESULTS_DIR) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def format_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | useful_flops | step_s | bound-MFU |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— | — | — | SKIP (full attn @500k) | — | — | — |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR: {r['error'][:60]} | | | | | | |")
            continue
        rf = r["roofline"]
        uf = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
            f"| {rf['collective_s']:.4f} | {rf['dominant'].replace('_s', '')} "
            f"| {uf:.2f} | {rf['step_time_s']:.4f} "
            f"| {rf['mfu_bound'] * 100:.1f}% |")
    return "\n".join(lines)


def run(csv: bool = True) -> List[str]:
    rows = load_rows()
    out = []
    for r in rows:
        if "roofline" not in r:
            continue
        rf = r["roofline"]
        out.append(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},"
                   f"{rf['step_time_s'] * 1e6:.0f},"
                   f"dominant={rf['dominant']} "
                   f"mfu_bound={rf['mfu_bound'] * 100:.1f}%")
    if not out:
        out.append("roofline_pending,0,run python -m repro.launch.dryrun --all")
    return out


if __name__ == "__main__":
    print(format_markdown(load_rows()))
