"""Streaming soak harness: one compiled pipeline, many tenants, sustained
micro-batch traffic (the ROADMAP's "millions of users" scenario in
miniature).

The soak runs a carried word-count ``Dataflow.stream_source()`` pipeline on
the :class:`repro.sphere.streaming.StreamExecutor` for >= 20 micro-batches
with 3 tenants at weights 1:3:4, all permanently backlogged (bounded queues,
rejections counted as backpressure), one request with a deliberately tiny
deadline (timeout -> head-requeue -> delivery, exactly once) and one injected
batch loss (dispatch failure -> requeue -> delivery, exactly once). The queue
runs on a virtual step clock so timeout behaviour is deterministic;
throughput is wall-clock over the compiled ``inner.run`` calls.

``--check`` asserts the ISSUE-6 acceptance criteria:

- zero recompiles after warm-up (``SPMDExecutor.cache_info().misses == 1``
  over the whole soak);
- weighted fair share within 10% of the 1:3:4 configured weights;
- the timed-out request was requeued and delivered exactly once (and so was
  every other request — no loss, no duplicates);
- the streamed output (final carry snapshot) is multiset-identical to the
  one-shot batch run over the concatenation of everything delivered;

and merges ``stream_records_per_s`` + ``stream_p99_latency`` into
``BENCH_kernels.json`` (without clobbering the kernel rows).

Run:  PYTHONPATH=src python benchmarks/streaming_bench.py [--check] [--json P]
"""

from __future__ import annotations

import os
import sys

if "jax" not in sys.modules:        # standalone: give the soak 8 devices
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import collections
import json
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

VOCAB = 64
NUM_BUCKETS = 8
# weights sum to 8 = requests per micro-batch, so one DRR round exactly
# fills a batch and the measured share converges to the weights quickly
WEIGHTS = {"free": 1.0, "pro": 3.0, "enterprise": 4.0}
DEPTH_TARGET = 12


def _build_pipeline():
    from repro.core.mapreduce import default_hash, reduce_by_key_sum
    from repro.sphere.dataflow import Dataflow

    def emit(rec):
        return {"key": rec["word"].astype(jnp.int32),
                "value": jnp.ones_like(rec["word"], jnp.int32)}

    def count(rec, valid):
        k, v, dropped = reduce_by_key_sum(rec["key"], rec["value"], valid)
        return {"key": k, "value": v}, k >= 0, dropped

    return (Dataflow.stream_source()
            .map(emit)
            .shuffle(by=lambda r: default_hash(r["key"], NUM_BUCKETS),
                     num_buckets=NUM_BUCKETS)
            .reduce(count))


def soak(steps: int = 28) -> Dict[str, object]:
    from repro.sphere.chaos import ChaosSchedule, FaultPlan
    from repro.sphere.dataflow import SPMDExecutor
    from repro.sphere.streaming import QueueFull, StreamExecutor, TenantQueue

    ndev = len(jax.devices())
    micro_batch = 64 * ndev
    cost = micro_batch // 8               # 8 requests fill one batch
    mesh = jax.make_mesh((ndev,), ("data",))
    inner = SPMDExecutor(mesh)
    queue = TenantQueue(quantum=float(cost), capacity=DEPTH_TARGET,
                        max_requeues=5)
    for name, w in WEIGHTS.items():
        queue.register(name, weight=w)
    # virtual step clock: deterministic deadlines; throughput stays wall-time
    vclock = {"now": 0.0}
    ex = StreamExecutor(inner, _build_pipeline(), micro_batch=micro_batch,
                        carry_capacity=VOCAB, queue=queue,
                        clock=lambda: vclock["now"],
                        # one scheduled batch loss mid-soak (dispatch
                        # failure -> requeue -> delivery, exactly once)
                        chaos=ChaosSchedule(
                            [FaultPlan(kind="lose_batch", at_batch=6)]))

    rng = np.random.default_rng(0)

    def make_request():
        return {"word": rng.integers(0, VOCAB, size=cost).astype(np.uint8)}

    delivered_count: collections.Counter = collections.Counter()
    delivered_payloads: Dict[int, np.ndarray] = {}
    rejections = 0
    special = None
    dropped = 0

    def top_up():
        nonlocal rejections
        for name in WEIGHTS:
            for _ in range(DEPTH_TARGET + 2):   # +2 overshoots: exercises
                try:                            # bounded-queue backpressure
                    ex.submit(make_request(), tenant=name)
                except QueueFull:
                    rejections += 1
                    break

    def record(batch):
        nonlocal dropped
        if batch is None:
            return
        dropped += batch.dropped
        for tk in batch.delivered:
            delivered_count[tk.req_id] += 1
            delivered_payloads[tk.req_id] = tk.payload["word"]

    for step in range(steps):
        vclock["now"] = float(step)
        if step == 3:
            # deadline shorter than one queue drain: times out while queued,
            # gets head-requeued, must still be delivered exactly once
            # (submitted before top_up so the bounded queue has room)
            special = ex.submit(make_request(), tenant="enterprise",
                                timeout=1.5)
        top_up()
        record(ex.step())       # the ChaosSchedule fires at batch 6
    fair = {n: s["records_served"]
            for n, s in queue.stats().items()}  # measured while backlogged
    # drain without top-up so every admitted request is delivered
    while queue.pending():
        vclock["now"] += 1.0
        record(ex.step())

    stats = ex.stats()
    tstats = stats["tenants"]
    total = sum(fair.values())
    wsum = sum(WEIGHTS.values())
    fair_rel = {n: (fair[n] / total) / (WEIGHTS[n] / wsum) for n in WEIGHTS}
    sec_per_step = stats["run_seconds"] / max(stats["steps"], 1)
    lat_steps = [tstats[n]["latency_p99"] for n in WEIGHTS]
    p99_steps = max(lat_steps)
    p50_steps = max(tstats[n]["latency_p50"] for n in WEIGHTS)

    # stream/batch equivalence: final carry snapshot vs a one-shot run over
    # the concatenation of every delivered request
    snap = ex.carry_state()
    got = {int(k): int(v) for k, v in zip(snap["key"], snap["value"])}
    allwords = np.concatenate([delivered_payloads[i]
                               for i in sorted(delivered_payloads)])
    oneshot = SPMDExecutor(mesh)
    with mesh:
        res = oneshot.run(_build_pipeline(), {"word": jnp.asarray(allwords)})
    rec = res.valid_records()
    want = {int(k): int(v) for k, v in zip(rec["key"], rec["value"])}

    info = inner.cache_info()
    return {
        "ndev": ndev,
        "micro_batch": micro_batch,
        "tenants": len(WEIGHTS),
        "steps": stats["steps"],
        "records_in": stats["records_in"],
        "records_per_s": stats["records_per_s"],
        "run_seconds": stats["run_seconds"],
        "p50_latency_ms": p50_steps * sec_per_step * 1e3,
        "p99_latency_ms": p99_steps * sec_per_step * 1e3,
        "latency_unit_note": "queue latencies measured in micro-batch steps,"
                             " converted at the mean batch wall time",
        "fair_share_rel": fair_rel,
        "cache": info._asdict(),
        "backpressure_rejections": rejections,
        "batch_failures": stats["batch_failures"],
        "timeouts": sum(t["timeouts"] for t in tstats.values()),
        "requeues": sum(t["requeues"] for t in tstats.values()),
        "failed": sum(t["failed"] for t in tstats.values()),
        "special_req_id": None if special is None else special.req_id,
        "special_deliveries": (0 if special is None
                               else delivered_count[special.req_id]),
        "special_requeues": 0 if special is None else special.requeues,
        "max_deliveries_per_request": max(delivered_count.values()),
        "delivered_requests": len(delivered_count),
        "dropped": dropped,
        "stream_equals_batch": got == want,
    }


def check(res: Dict[str, object]) -> List[str]:
    failures = []
    if res["tenants"] < 3 or res["steps"] < 20:
        failures.append(f"soak too small: {res['tenants']} tenants over "
                        f"{res['steps']} micro-batches (need >=3 over >=20)")
    if res["cache"]["misses"] != 1:
        failures.append(f"pipeline recompiled after warm-up: "
                        f"{res['cache']['misses']} cache misses (want 1)")
    for name, rel in res["fair_share_rel"].items():
        if not 0.9 <= rel <= 1.1:
            failures.append(f"fair share off for {name}: {rel:.3f}x of the "
                            f"configured weight (want within 10%)")
    if res["special_requeues"] < 1 or res["special_deliveries"] != 1:
        failures.append(f"timed-out request not requeued-then-delivered-once "
                        f"(requeues={res['special_requeues']}, "
                        f"deliveries={res['special_deliveries']})")
    if res["max_deliveries_per_request"] != 1:
        failures.append(f"duplicate delivery: a request completed "
                        f"{res['max_deliveries_per_request']} times")
    if res["failed"] or res["dropped"]:
        failures.append(f"lost work: {res['failed']} failed requests, "
                        f"{res['dropped']} dropped records")
    if not res["stream_equals_batch"]:
        failures.append("streamed snapshot != one-shot batch run multiset")
    return failures


def _merge_json(json_path: str, res: Dict[str, object]) -> None:
    try:
        with open(json_path) as f:
            payload = json.load(f)
    except (OSError, ValueError):
        payload = {"schema": "repro.kernel_bench.v1", "results": {}}
    payload.setdefault("results", {})
    payload["results"]["stream_records_per_s"] = {
        "owner": "stream",
        "value": res["records_per_s"], "micro_batch": res["micro_batch"],
        "tenants": res["tenants"], "steps": res["steps"],
        "ndev": res["ndev"],
    }
    payload["results"]["stream_p99_latency"] = {
        "owner": "stream",
        "ms": res["p99_latency_ms"], "p50_ms": res["p50_latency_ms"],
        "note": res["latency_unit_note"],
    }
    payload["results"]["stream_soak"] = {
        "owner": "stream",
        "fair_share_rel": res["fair_share_rel"],
        "cache_misses": res["cache"]["misses"],
        "timeouts": res["timeouts"], "requeues": res["requeues"],
        "backpressure_rejections": res["backpressure_rejections"],
        "stream_equals_batch": res["stream_equals_batch"],
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)


def run(csv: bool = True, json_path: str | None = None) -> List[str]:
    res = soak()
    us = res["run_seconds"] * 1e6 / res["steps"] if "run_seconds" in res \
        else 0.0
    fair = " ".join(f"{n}={v:.3f}" for n, v in res["fair_share_rel"].items())
    lines = [
        f"stream_records_per_s,{us:.0f},{res['records_per_s']:.0f}rec/s "
        f"({res['tenants']} tenants, {res['steps']} batches of "
        f"{res['micro_batch']}, {res['ndev']} devices)",
        f"stream_p99_latency,0,p50={res['p50_latency_ms']:.1f}ms "
        f"p99={res['p99_latency_ms']:.1f}ms (queue-wait, step-converted)",
        f"stream_fair_share,0,{fair} (rel to weights 1:3:4)",
        f"stream_soak,0,misses={res['cache']['misses']} "
        f"timeouts={res['timeouts']} requeues={res['requeues']} "
        f"backpressure={res['backpressure_rejections']} "
        f"equal_to_batch={res['stream_equals_batch']}",
    ]
    if json_path:
        _merge_json(json_path, res)
        lines.append(f"stream_bench_json,0,merged into {json_path}")
    run.last_result = res
    return lines


def main() -> None:
    args = sys.argv[1:]
    do_check = "--check" in args
    json_path = None
    if "--json" in args:
        idx = args.index("--json") + 1
        if idx >= len(args):
            print("usage: streaming_bench.py [--json PATH] [--check]")
            sys.exit(2)
        json_path = args[idx]
    elif do_check:
        json_path = "BENCH_kernels.json"
    for line in run(json_path=json_path):
        print(line)
    if do_check:
        res = run.last_result
        failures = check(res)
        if failures:
            for msg in failures:
                print(f"CHECK FAILED: {msg}")
            sys.exit(1)
        print(f"CHECK OK: {res['tenants']} tenants x {res['steps']} "
              f"micro-batches on one compiled pipeline "
              f"(misses={res['cache']['misses']}); fair share within 10%; "
              f"timed-out request requeued and delivered exactly once; "
              f"stream == batch multiset")


if __name__ == "__main__":
    main()
