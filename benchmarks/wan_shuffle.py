"""Wide-area shuffle benchmark: flat vs hierarchical bytes-over-WAN.

The paper's headline differentiator (§1, §2.2) is that Sector/Sphere manages
data *across* geographically distributed data centers. This benchmark prices
the bucket shuffle (§3.2) on the paper's Open Cloud Testbed model — 4
locations × 30 nodes, 1 GE in rack, a shared 10 GE uplink per site with
~30 ms one-way WAN latency — comparing

  flat  — one all_to_all over all 120 devices: every node ships a
          fixed-capacity tile to each of the 90 remote devices, i.e. 90
          sparse WAN flows per node per round;
  hier  — the two-level :class:`repro.core.shuffle.ShufflePlan`: stage A
          aggregates intra-DC, stage B ships ONE dense tile per remote DC
          (3 WAN flows per node), stage C is free.

Three byte accountings per round (one §3.5.1 segment of records in flight
per node), worst-case zero-drop capacities drawn from a multinomial model:

  useful    records that genuinely change DC — identical by construction
            (a record crosses the WAN exactly once either way);
  slot      what the capacity-padded all_to_all physically ships: tiles ×
            capacity slots. Hierarchical wins modestly — aggregated tiles
            concentrate around their mean, per-pair tiles pay the max-of-
            14400-pairs tail;
  wire      WAN-effective bytes with each flow rounded up to the transfer
            quantum a long fat pipe needs to sustain throughput (the
            bandwidth-delay product of the 10 GE / 30 ms link — the paper's
            UDT argument, §2.4; sub-BDP flows waste the pipe). Per-DC-pair
            payloads sit far below one quantum here, so the ratio collapses
            to the flow-count ratio: (dcs-1) / ((dcs-1) * nodes) =
            1/nodes_per_dc.

Also priced: the one-wire-tensor frame layout (``wan_frame_bytes`` — fused
payload rows + one count-header row per tile, ``wire_meta="min"``) against
the retired multi-collective layout (``wan_legacy_bytes`` — separate
capacity-padded data/valid/bucket/src tensors), for both paths.

Also reported: per-round WAN time (flow setup RTTs + payload over the shared
uplink, UDT vs TCP via :class:`repro.sector.transport.TransferSimulator`)
and a *measured* 8-virtual-device run checking the two paths deliver the
identical record multiset.
"""

from __future__ import annotations

import dataclasses
import os
import subprocess
import sys
from typing import Dict, List

import numpy as np

from repro.core.shuffle import ShufflePlan
from repro.sector.topology import NodeAddress, Topology
from repro.sector.transport import PAPER_LINKS, TransferSimulator

REC_BYTES = 100                 # paper terasort record: 10 B key + 90 B value
SEGMENT_RECORDS = 32768         # one §3.5.1 segment in flight per node


def zero_drop_capacities(dcs: int, nodes: int, n_local: int, seed: int = 0):
    """Worst-observed tile occupancies for one round of uniform bucket
    traffic (multinomial draw): the smallest capacities that drop nothing.

    Returns (c_flat, c_a, c_b): flat per-(src, dst-device) tile, stage-A
    per-(node, node) tile, stage-B per-(staging-node, dst-DC) tile.
    """
    rng = np.random.default_rng(seed)
    d = dcs * nodes
    counts = rng.multinomial(n_local, np.full(d, 1.0 / d), size=d)  # (src, dst)
    c_flat = int(counts.max())
    # stage A (intra-DC): tile (d1,n1)->(d1,n2) carries everything n1 holds
    # for node-row n2, any destination DC
    per_node_row = counts.reshape(d, dcs, nodes).sum(axis=1)        # (src, n2)
    c_a = int(per_node_row.max())
    # stage B: staged at (d1,n2), one tile per destination DC g
    staged = counts.reshape(dcs, nodes, dcs, nodes).sum(axis=1)     # (d1,g,n2)
    c_b = int(staged.max())
    return c_flat, c_a, c_b


def model_wan_round(
    dcs: int = 4,
    nodes: int = 30,
    n_local: int = SEGMENT_RECORDS,
    rec_bytes: int = REC_BYTES,
    wire_quantum_records: int | None = None,
    seed: int = 0,
) -> Dict[str, float]:
    """Per-device cross-DC traffic and per-round WAN time for one shuffle
    round on a ``dcs × nodes`` testbed (defaults: the paper's 4×30)."""
    if dcs < 2:
        raise ValueError("wide-area model needs >= 2 data centers "
                         "(a single-DC shuffle has no WAN traffic)")
    topo = Topology(pods=dcs, racks=1, nodes_per_rack=nodes)
    c_flat, c_a, c_b = zero_drop_capacities(dcs, nodes, n_local, seed)
    d = topo.num_nodes
    flat = ShufflePlan(num_buckets=d, axes=("wan",), shape=(d,),
                       capacities=(c_flat,))
    hier = dataclasses.replace(
        ShufflePlan.from_topology(topo, num_buckets=d, n_local=n_local),
        capacities=(c_a, c_b))

    wan = PAPER_LINKS[3]  # cross-pod: 10 GE, 30 ms one-way
    if wire_quantum_records is None:
        bdp = wan.bandwidth * 2 * wan.latency          # one RTT of the pipe
        wire_quantum_records = max(int(bdp / rec_bytes), 1)

    pf = flat.wan_profile(dcs, nodes, rec_bytes, wire_quantum_records)
    ph = hier.wan_profile(dcs, nodes, rec_bytes, wire_quantum_records)
    # fused one-tensor frame vs the retired multi-collective layout, at the
    # executor's wire_meta="min" (pure payload + per-tile count header)
    pf_min = flat.wan_profile(dcs, nodes, rec_bytes, wire_quantum_records,
                              wire_meta="min")
    ph_min = hier.wan_profile(dcs, nodes, rec_bytes, wire_quantum_records,
                              wire_meta="min")
    useful = int(n_local * (dcs - 1) / dcs * rec_bytes)  # either path

    def wan_time(profile, protocol: str) -> float:
        sim = TransferSimulator(links=PAPER_LINKS, protocol=protocol)
        bw = sim.effective_bandwidth(NodeAddress(0, 0, 0),
                                     NodeAddress(1, 0, 0)) / nodes
        setup = profile["wan_tiles"] * 2 * wan.latency   # rendezvous per flow
        return setup + profile["wan_slot_bytes"] / bw

    return {
        "dcs": dcs, "nodes": nodes, "n_local": n_local,
        "capacities": {"flat": c_flat, "stage_a": c_a, "stage_b": c_b},
        "wire_quantum_records": wire_quantum_records,
        "useful_bytes": useful,
        "flat": pf, "hier": ph,
        "flow_ratio": ph["wan_tiles"] / pf["wan_tiles"],
        "slot_ratio": ph["wan_slot_bytes"] / pf["wan_slot_bytes"],
        "wire_ratio": ph["wan_wire_bytes"] / pf["wan_wire_bytes"],
        # one-wire-tensor framing: bytes of the fused frame (wire_meta="min",
        # the dataflow executor's setting) over the retired 4/5-tensor layout
        "frame_ratio_flat": (pf_min["wan_frame_bytes"]
                             / pf["wan_legacy_bytes"]),
        "frame_ratio_hier": (ph_min["wan_frame_bytes"]
                             / ph["wan_legacy_bytes"]),
        "flat_min": pf_min, "hier_min": ph_min,
        "time_flat_udt": wan_time(pf, "udt"),
        "time_hier_udt": wan_time(ph, "udt"),
        "time_flat_tcp": wan_time(pf, "tcp"),
        "time_hier_tcp": wan_time(ph, "tcp"),
    }


_MEASURE_CODE = """
import time, numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import shard_map
from repro.core.shuffle import ShufflePlan, sphere_shuffle
mesh1 = jax.make_mesh((8,), ("data",))
mesh2 = jax.make_mesh((2, 4), ("dc", "node"))
rng = np.random.default_rng(0)
N = 8 * 8192
data = rng.integers(0, 1 << 20, size=(N, 3)).astype(np.int32)
buckets = rng.integers(0, 16, size=N).astype(np.int32)
n_local = N // 8

flat_plan = ShufflePlan.for_mesh(mesh1, 16, n_local, 2.5, ("data",))
hier_plan = ShufflePlan.for_mesh(mesh2, 16, n_local, 2.5, ("dc", "node"))

def run_one(mesh, spec, plan):
    dd = jax.device_put(jnp.asarray(data), NamedSharding(mesh, spec))
    bd = jax.device_put(jnp.asarray(buckets), NamedSharding(mesh, spec))
    def udf(d, b):
        r = plan.shuffle(d, b.reshape(-1))
        return (r.data.reshape(-1, 3), r.valid.reshape(-1),
                r.bucket.reshape(-1), r.dropped)
    f = shard_map(udf, mesh=mesh, in_specs=(spec, spec),
                  out_specs=(spec, spec, spec, P()), check_vma=False)
    with mesh:
        out = f(dd, bd)
        jax.block_until_ready(out[0])
        t0 = time.time(); iters = 5
        for _ in range(iters):
            out = f(dd, bd)
            jax.block_until_ready(out[0])
        dt = (time.time() - t0) / iters
    return out, dt

(fd, fv, fb, fdrop), t_flat = run_one(mesh1, P("data"), flat_plan)
(hd, hv, hb, hdrop), t_hier = run_one(mesh2, P(("dc", "node")), hier_plan)
assert int(fdrop) == 0 and int(hdrop) == 0
fd, fv, fb, hd, hv, hb = map(np.asarray, (fd, fv, fb, hd, hv, hb))
flat_set = sorted(map(tuple, np.concatenate([fb[fv][:, None], fd[fv]], 1)))
hier_set = sorted(map(tuple, np.concatenate([hb[hv][:, None], hd[hv]], 1)))
assert flat_set == hier_set, "delivery multisets differ"
rb = 3 * 4
pf = flat_plan.wan_profile(2, 4, rb)
ph = hier_plan.wan_profile(2, 4, rb)
print(f"RESULT flat {t_flat * 1e6:.1f} wan_tiles={pf['wan_tiles']} "
      f"wan_slot_bytes={pf['wan_slot_bytes']}")
print(f"RESULT hier {t_hier * 1e6:.1f} wan_tiles={ph['wan_tiles']} "
      f"wan_slot_bytes={ph['wan_slot_bytes']} equivalent=yes")
"""


def measured_8dev() -> List[str]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _MEASURE_CODE], env=env,
                          capture_output=True, text=True, timeout=520)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    return [l for l in proc.stdout.splitlines() if l.startswith("RESULT")]


def run(csv: bool = True) -> List[str]:
    lines = []
    m = model_wan_round()
    mb = 1.0 / 1e6
    lines.append(
        f"wan_shuffle_model_flat,{m['time_flat_udt'] * 1e6:.0f},"
        f"flows={m['flat']['wan_tiles']} "
        f"slotMB={m['flat']['wan_slot_bytes'] * mb:.2f} "
        f"wireMB={m['flat']['wan_wire_bytes'] * mb:.1f} "
        f"usefulMB={m['useful_bytes'] * mb:.2f} "
        f"udt={m['time_flat_udt']:.2f}s tcp={m['time_flat_tcp']:.2f}s")
    lines.append(
        f"wan_shuffle_model_hier,{m['time_hier_udt'] * 1e6:.0f},"
        f"flows={m['hier']['wan_tiles']} "
        f"slotMB={m['hier']['wan_slot_bytes'] * mb:.2f} "
        f"wireMB={m['hier']['wan_wire_bytes'] * mb:.1f} "
        f"usefulMB={m['useful_bytes'] * mb:.2f} "
        f"udt={m['time_hier_udt']:.2f}s tcp={m['time_hier_tcp']:.2f}s")
    lines.append(
        f"wan_shuffle_model_ratio,0,"
        f"wire={m['wire_ratio']:.4f} slot={m['slot_ratio']:.3f} "
        f"flows={m['flow_ratio']:.4f} "
        f"target<=1/{m['nodes']}={1.0 / m['nodes']:.4f} "
        f"({m['dcs']}x{m['nodes']} testbed, segment={m['n_local']} recs)")
    lines.append(
        f"wan_shuffle_model_frame,0,"
        f"fused_vs_legacy_flat={m['frame_ratio_flat']:.3f} "
        f"fused_vs_legacy_hier={m['frame_ratio_hier']:.3f} "
        f"frameMB_hier={m['hier_min']['wan_frame_bytes'] * mb:.2f} "
        f"legacyMB_hier={m['hier']['wan_legacy_bytes'] * mb:.2f} "
        f"(one wire tensor/hop, wire_meta=min, {REC_BYTES}B records)")
    for r in measured_8dev():
        parts = r.split()
        lines.append(f"wan_shuffle_measured_{parts[1]},{parts[2]},"
                     f"{' '.join(parts[3:])}")
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
