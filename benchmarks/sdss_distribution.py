"""SDSS data-distribution benchmark (paper Figs 4 & 5).

Fig 4: on-testbed downloads — the bottleneck is the *disk*, not the 10 Gbps
network; throughput scales with parallel downloads until the source disks
saturate.

Fig 5: end-user downloads over the commodity WAN — throughput is set by the
user's access link and distance; UDT sustains long-fat-pipe throughput where
TCP collapses (the paper's 8 Mb/s India .. 900 Mb/s Pasadena spread).

Both reproduced with the calibrated transport model + the Sector master's
replica selection (closest, least-busy slave).

``stream_demo`` additionally replays the SDSS serving scenario on the
Dataflow API: the catalog arrives as a :class:`repro.core.stream.SphereStream`
whose micro-batches feed a ``Dataflow.stream_source()`` pipeline that keeps a
running per-declination-stripe object count (carry state) — the "continuously
distribute new survey releases" workload of §4.1 rather than a one-shot scan.
"""

from __future__ import annotations

from typing import List

from repro.sector.topology import NodeAddress
from repro.sector.transport import (LinkSpec, PAPER_DISK_BW, PAPER_LINKS,
                                    TransferSimulator)

GB = 1e9


def fig4_testbed_downloads() -> List[str]:
    """Clients on the Teraflow testbed (10 GE): disk-bound."""
    lines = []
    file_bytes = 20 * GB  # catalog files: 20-25 GB (paper §4.1)
    for parallel in (1, 2, 4, 8):
        # each parallel stream is served by a different replica slave
        sim = TransferSimulator(links=PAPER_LINKS, protocol="udt",
                                disk_bw=PAPER_DISK_BW)
        per_stream = sim.effective_bandwidth(NodeAddress(0, 0, 0),
                                             NodeAddress(1, 0, 0))
        agg = per_stream * parallel
        net_cap = PAPER_LINKS[3].bandwidth
        agg = min(agg, net_cap)
        t = file_bytes * parallel / agg
        lines.append(
            f"sdss_fig4_parallel{parallel},{t * 1e6:.0f},"
            f"aggregate={agg * 8 / 1e9:.2f}Gbps disk_bound="
            f"{agg < net_cap}")
    return lines


def fig5_enduser_downloads() -> List[str]:
    """End users at increasing WAN distance; UDT vs TCP."""
    lines = []
    # (label, access link bw bytes/s, one-way latency s)
    users = [
        ("pasadena", 125e6, 0.03),     # ~900 Mb/s observed peak
        ("europe", 62.5e6, 0.06),
        ("asia", 31.25e6, 0.12),
        ("india", 1.25e6, 0.15),       # ~8 Mb/s observed floor
    ]
    for label, bw, lat in users:
        for proto in ("udt", "tcp"):
            links = dict(PAPER_LINKS)
            links[3] = LinkSpec(bandwidth=bw, latency=lat)
            sim = TransferSimulator(links=links, protocol=proto)
            eff = sim.effective_bandwidth(NodeAddress(0, 0, 0),
                                          NodeAddress(1, 0, 0))
            t = 20 * GB / eff
            lines.append(f"sdss_fig5_{label}_{proto},{t * 1e6:.0f},"
                         f"throughput={eff * 8 / 1e6:.1f}Mbps")
    return lines


def stream_demo() -> List[str]:
    """Stream the sky catalog through the Dataflow API: per-stripe object
    counts accumulated across micro-batches, checked against numpy."""
    import os
    import sys
    if "jax" not in sys.modules:
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.mapreduce import default_hash, reduce_by_key_sum
    from repro.core.stream import SphereStream
    from repro.sphere.dataflow import Dataflow, SPMDExecutor
    from repro.sphere.streaming import StreamExecutor

    num_stripes = 64                   # SDSS DR imaging stripes
    ndev = len(jax.devices())
    micro_batch = 32 * ndev
    n = micro_batch * 6

    rng = np.random.default_rng(2008)
    catalog = {"ra": rng.uniform(0, 360, n).astype(np.float32),
               "dec": rng.uniform(-90, 90, n).astype(np.float32)}

    def to_stripe(rec):
        stripe = jnp.clip(((rec["dec"] + 90.0) / 180.0 * num_stripes)
                          .astype(jnp.int32), 0, num_stripes - 1)
        return {"key": stripe, "value": jnp.ones_like(stripe)}

    def count(rec, valid):
        k, v, dropped = reduce_by_key_sum(rec["key"], rec["value"], valid)
        return {"key": k, "value": v}, k >= 0, dropped

    df = (Dataflow.stream_source()
          .map(to_stripe)
          .shuffle(by=lambda r: default_hash(r["key"], ndev),
                   num_buckets=ndev)
          .reduce(count))
    mesh = jax.make_mesh((ndev,), ("data",))
    ex = StreamExecutor(SPMDExecutor(mesh), df, micro_batch=micro_batch,
                        carry_capacity=num_stripes)
    stream = SphereStream(data=catalog)
    t0 = time.monotonic()
    for chunk in stream.micro_batches(micro_batch):
        ex.submit(chunk, tenant="sdss-release")
        ex.step()
    elapsed = time.monotonic() - t0

    snap = ex.carry_state()
    got = np.zeros(num_stripes, np.int64)
    got[np.asarray(snap["key"])] = np.asarray(snap["value"])
    stripes = np.clip(((catalog["dec"] + 90.0) / 180.0 * num_stripes)
                      .astype(np.int64), 0, num_stripes - 1)
    want = np.bincount(stripes, minlength=num_stripes)
    if not np.array_equal(got, want):
        raise AssertionError("streamed stripe histogram != numpy bincount")
    info = ex.inner.cache_info()
    return [f"sdss_stream_demo,{elapsed * 1e6 / max(ex.stats()['steps'], 1):.0f},"
            f"{n}objects/{ex.stats()['steps']}batches stripes_ok=True "
            f"compiles={info.misses}"]


def run(csv: bool = True) -> List[str]:
    return (fig4_testbed_downloads() + fig5_enduser_downloads()
            + stream_demo())


if __name__ == "__main__":
    for line in run():
        print(line)
