"""SDSS data-distribution benchmark (paper Figs 4 & 5).

Fig 4: on-testbed downloads — the bottleneck is the *disk*, not the 10 Gbps
network; throughput scales with parallel downloads until the source disks
saturate.

Fig 5: end-user downloads over the commodity WAN — throughput is set by the
user's access link and distance; UDT sustains long-fat-pipe throughput where
TCP collapses (the paper's 8 Mb/s India .. 900 Mb/s Pasadena spread).

Both reproduced with the calibrated transport model + the Sector master's
replica selection (closest, least-busy slave).
"""

from __future__ import annotations

from typing import List

from repro.sector.topology import NodeAddress
from repro.sector.transport import (LinkSpec, PAPER_DISK_BW, PAPER_LINKS,
                                    TransferSimulator)

GB = 1e9


def fig4_testbed_downloads() -> List[str]:
    """Clients on the Teraflow testbed (10 GE): disk-bound."""
    lines = []
    file_bytes = 20 * GB  # catalog files: 20-25 GB (paper §4.1)
    for parallel in (1, 2, 4, 8):
        # each parallel stream is served by a different replica slave
        sim = TransferSimulator(links=PAPER_LINKS, protocol="udt",
                                disk_bw=PAPER_DISK_BW)
        per_stream = sim.effective_bandwidth(NodeAddress(0, 0, 0),
                                             NodeAddress(1, 0, 0))
        agg = per_stream * parallel
        net_cap = PAPER_LINKS[3].bandwidth
        agg = min(agg, net_cap)
        t = file_bytes * parallel / agg
        lines.append(
            f"sdss_fig4_parallel{parallel},{t * 1e6:.0f},"
            f"aggregate={agg * 8 / 1e9:.2f}Gbps disk_bound="
            f"{agg < net_cap}")
    return lines


def fig5_enduser_downloads() -> List[str]:
    """End users at increasing WAN distance; UDT vs TCP."""
    lines = []
    # (label, access link bw bytes/s, one-way latency s)
    users = [
        ("pasadena", 125e6, 0.03),     # ~900 Mb/s observed peak
        ("europe", 62.5e6, 0.06),
        ("asia", 31.25e6, 0.12),
        ("india", 1.25e6, 0.15),       # ~8 Mb/s observed floor
    ]
    for label, bw, lat in users:
        for proto in ("udt", "tcp"):
            links = dict(PAPER_LINKS)
            links[3] = LinkSpec(bandwidth=bw, latency=lat)
            sim = TransferSimulator(links=links, protocol=proto)
            eff = sim.effective_bandwidth(NodeAddress(0, 0, 0),
                                          NodeAddress(1, 0, 0))
            t = 20 * GB / eff
            lines.append(f"sdss_fig5_{label}_{proto},{t * 1e6:.0f},"
                         f"throughput={eff * 8 / 1e6:.1f}Mbps")
    return lines


def run(csv: bool = True) -> List[str]:
    return fig4_testbed_downloads() + fig5_enduser_downloads()


if __name__ == "__main__":
    for line in run():
        print(line)
