"""MoE dispatch = Sphere bucket shuffle (paper §3.6 generalization claim).

Compares the sphere (all_to_all bucket shuffle) expert dispatch against the
dense einsum dispatch, measured on virtual devices, and reports the
collective bytes each one compiles to (the wide-area-traffic argument of the
paper, transplanted to ICI).
"""

from __future__ import annotations

import os
import subprocess
import sys
from typing import List

_CODE = """
import time, dataclasses, numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.models import moe as moe_mod
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = dataclasses.replace(get_smoke_config("qwen3_moe_30b_a3b"),
                          capacity_factor=2.0)
params, _ = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, tp=4)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 64, cfg.d_model), jnp.bfloat16)
xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))

def run_one(name, fn):
    with mesh:
        out = fn(); jax.block_until_ready(out[0])
        t0 = time.time(); iters = 5
        for _ in range(iters):
            out = fn(); jax.block_until_ready(out[0])
        dt = (time.time() - t0) / iters
    print(f"RESULT moe_{name} {dt*1e6:.1f}")
    return out

sphere = jax.jit(lambda p, xx: moe_mod.moe_apply_sphere(p, xx, cfg, mesh, ("data",)))
dense  = jax.jit(lambda p, xx: moe_mod.moe_apply_dense(p, xx, cfg))
o1 = run_one("sphere", lambda: sphere(params, xs))
o2 = run_one("dense",  lambda: dense(params, x))

# collective bytes of each compiled program
import re
from repro.launch.dryrun import collective_bytes
with mesh:
    h1 = sphere.lower(params, xs).compile().as_text()
    h2 = dense.lower(params, x).compile().as_text()
c1, c2 = collective_bytes(h1), collective_bytes(h2)
print(f"RESULT moe_sphere_coll_bytes {sum(c1.values())} {c1}")
print(f"RESULT moe_dense_coll_bytes {sum(c2.values())} {c2}")
"""


def run(csv: bool = True) -> List[str]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _CODE], env=env,
                          capture_output=True, text=True, timeout=560)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    lines = []
    for l in proc.stdout.splitlines():
        if l.startswith("RESULT"):
            parts = l.split(maxsplit=3)
            lines.append(f"{parts[1]},{parts[2]},"
                         f"{parts[3] if len(parts) > 3 else ''}")
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
