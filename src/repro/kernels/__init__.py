"""Pallas TPU kernels for the Terasort hot spots (paper §4.2, Fig 3).

The paper's compute-critical path is the two-stage distributed sort:
stage 1 hashes every record into a range bucket; stage 2 sorts each bucket
locally. On commodity CPUs those are a table-driven scatter and quicksort; on
TPU there is no efficient per-element scatter, so we adapt:

- ``bucket_hist``   — per-tile one-hot histogram, computed as an MXU matmul.
- ``bitonic_sort``  — in-VMEM bitonic network over (key, payload) pairs using
                      XOR-partner compare-exchange realized as reshapes/flips
                      (no gather/scatter), the TPU-native sort.

``ops`` exposes jit'd wrappers; ``ref`` holds the pure-jnp oracles used by the
tests' allclose sweeps.
"""

from repro.kernels.ops import (
    bucket_histogram,
    sort_segments,
    sort_kv_segments,
)

__all__ = ["bucket_histogram", "sort_segments", "sort_kv_segments"]
