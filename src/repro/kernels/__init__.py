"""Pallas TPU kernels for the Terasort hot spots (paper §4.2, Fig 3).

The paper's compute-critical path is the two-stage distributed sort:
stage 1 hashes every record into a range bucket; stage 2 sorts each bucket
locally. On commodity CPUs those are a table-driven scatter and quicksort; on
TPU there is no efficient per-element scatter, so we adapt:

- ``bucket_hist``   — per-tile one-hot histogram, computed as an MXU matmul
                      (int32 accumulation).
- ``partition``     — fused histogram + stable counting rank in one pass:
                      the O(n) shuffle send path (replaces the stable argsort
                      every send used to pay).
- ``bitonic_sort``  — in-VMEM bitonic network over (key, payload) pairs using
                      XOR-partner compare-exchange realized as reshapes/flips
                      (no gather/scatter), the TPU-native sort; one grid step
                      sorts a sublane-packed block of segments. Not stable.
- ``radix_sort``    — stable LSD counting-radix sort: per-byte one-hot
                      cumsum rank (the ``partition`` primitive, one pass per
                      key digit) with the permutation applied as chunked
                      one-hot MXU matmuls — no gather/scatter at all.
- ``autotune``      — backend-aware dispatch: measures bitonic vs radix vs
                      the XLA oracle once per segment-geometry cell, caches
                      the winner, persists the table into BENCH_kernels.json.

``ops`` exposes jit'd wrappers (including ``partition_pack``, the full
rank → slot-map → gather send-tile builder); the sort entry points dispatch
through the autotuner. ``ref`` holds the pure-jnp oracles used by the
tests' allclose sweeps.
"""

from repro.kernels.ops import (
    bucket_histogram,
    pad_sentinel,
    partition_pack,
    partition_rank,
    resolve_sort_algo,
    sort_segments,
    sort_kv_segments,
)

__all__ = ["bucket_histogram", "pad_sentinel", "partition_pack",
           "partition_rank", "resolve_sort_algo", "sort_segments",
           "sort_kv_segments"]
