"""Pallas kernel: in-VMEM bitonic sort of (key, payload) segments.

Terasort stage 2 (paper Fig 3) sorts each bucket locally; the paper's SPEs
call a CPU quicksort on the whole segment. Quicksort is branch/scatter bound
and has no TPU analogue, so we adapt the insight ("sort whole segments where
they live") to the TPU's vector units with a **bitonic sorting network**:

- compare-exchange partners at distance ``j`` (a power of two) are obtained
  by viewing each segment row as ``(S//(2j), 2, j)`` and splitting the
  middle axis — XOR-partner addressing with *no gather/scatter*;
- the ascending/descending direction of stage ``k`` depends only on the
  block index ``q``, so it is a broadcasted-iota predicate;
- the whole network is O(S log² S) fully-vectorized compare-exchanges on
  segments resident in VMEM.

One grid step sorts a **block of segments** at once: the operands stay 2-D
``(rows, S)`` — segments along the sublane axis, elements along the lane
axis — and every compare-exchange is a sublane×lane-shaped select over all
rows of the block simultaneously. (The original kernel flattened one row to
1-D per grid step and rebuilt it with ``stack``/``reshape`` relayouts each
stage; the 2-D form keeps the lane dimension intact for ``j >= lane`` and
amortizes one grid step over ``rows`` segments — the multi-segment layout
the segmented terasort stage 2 feeds it, where sorting ``bpd`` rows of
``R/bpd`` cuts the network from O(R log² R) to O(R log² (R/bpd)).)

The payload array is permuted alongside the keys (used to carry record
indices through the sort).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compare_exchange(keys, vals, k_exp: int, j_exp: int):
    """One bitonic stage over a (rows, S) block: partners at distance
    2^j_exp within blocks of 2^k_exp, for every row at once."""
    r, s = keys.shape
    j = 1 << j_exp
    half = s // (2 * j)
    ks = keys.reshape(r, half, 2, j)
    vs = vals.reshape(r, half, 2, j)
    lo_k, hi_k = ks[:, :, 0, :], ks[:, :, 1, :]
    lo_v, hi_v = vs[:, :, 0, :], vs[:, :, 1, :]
    # ascending iff bit k_exp of the element index is 0; that bit lives at
    # bit (k_exp - j_exp - 1) of the block index q.
    shift = k_exp - j_exp - 1
    q = jax.lax.broadcasted_iota(jnp.int32, (1, half, 1), 1)
    dir_up = ((q >> shift) & 1) == 0
    swap = jnp.where(dir_up, lo_k > hi_k, lo_k < hi_k)
    new_lo_k = jnp.where(swap, hi_k, lo_k)
    new_hi_k = jnp.where(swap, lo_k, hi_k)
    new_lo_v = jnp.where(swap, hi_v, lo_v)
    new_hi_v = jnp.where(swap, lo_v, hi_v)
    keys = jnp.concatenate([new_lo_k[:, :, None, :], new_hi_k[:, :, None, :]],
                           axis=2).reshape(r, s)
    vals = jnp.concatenate([new_lo_v[:, :, None, :], new_hi_v[:, :, None, :]],
                           axis=2).reshape(r, s)
    return keys, vals


def _bitonic_kernel(keys_ref, vals_ref, out_k_ref, out_v_ref):
    s = keys_ref.shape[-1]
    m = int(math.log2(s))
    keys = keys_ref[...]                    # (rows, S): one block of segments
    vals = vals_ref[...]
    for k_exp in range(1, m + 1):
        for j_exp in range(k_exp - 1, -1, -1):
            keys, vals = _compare_exchange(keys, vals, k_exp, j_exp)
    out_k_ref[...] = keys
    out_v_ref[...] = vals


def _next_pow2(x: int) -> int:
    return 1 << max(1, (x - 1).bit_length())


def _max_sentinel(dtype):
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


@functools.partial(jax.jit, static_argnames=("rows_per_step", "interpret"))
def sort_kv_segments_pallas(keys: jnp.ndarray, values: jnp.ndarray,
                            rows_per_step: int = 8,
                            interpret: bool = True):
    """Sort each row of ``keys`` ascending, permuting ``values`` alongside.

    keys/values: (num_segments, segment_len). Each grid step sorts
    ``rows_per_step`` segments at once (sublane-packed). Padding — segment
    length to the next power of two, segment count to a whole number of
    blocks — uses a max sentinel so padded slots sort to the end and are
    sliced off. Not stable — callers needing stability pack a unique
    tiebreak into keys.
    """
    n, s = keys.shape
    s_pad = _next_pow2(s)
    if s_pad != s:
        pad_k = jnp.full((n, s_pad - s), _max_sentinel(keys.dtype), keys.dtype)
        pad_v = jnp.zeros((n, s_pad - s), values.dtype)
        keys = jnp.concatenate([keys, pad_k], axis=1)
        values = jnp.concatenate([values, pad_v], axis=1)
    rb = max(1, min(rows_per_step, n))
    n_pad = -(-n // rb) * rb
    if n_pad != n:
        pad_k = jnp.full((n_pad - n, s_pad), _max_sentinel(keys.dtype),
                         keys.dtype)
        pad_v = jnp.zeros((n_pad - n, s_pad), values.dtype)
        keys = jnp.concatenate([keys, pad_k], axis=0)
        values = jnp.concatenate([values, pad_v], axis=0)
    out_k, out_v = pl.pallas_call(
        _bitonic_kernel,
        grid=(n_pad // rb,),
        in_specs=[pl.BlockSpec((rb, s_pad), lambda i: (i, 0)),
                  pl.BlockSpec((rb, s_pad), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rb, s_pad), lambda i: (i, 0)),
                   pl.BlockSpec((rb, s_pad), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n_pad, s_pad), keys.dtype),
                   jax.ShapeDtypeStruct((n_pad, s_pad), values.dtype)],
        interpret=interpret,
    )(keys, values)
    return out_k[:n, :s], out_v[:n, :s]


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_segments_pallas(keys: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Keys-only row sort (payload = dummy)."""
    dummy = jnp.zeros_like(keys, dtype=jnp.int32)
    out_k, _ = sort_kv_segments_pallas(keys, dummy, interpret=interpret)
    return out_k
