"""Pallas kernel: in-VMEM bitonic sort of (key, payload) segments.

Terasort stage 2 (paper Fig 3) sorts each bucket locally; the paper's SPEs
call a CPU quicksort on the whole segment. Quicksort is branch/scatter bound
and has no TPU analogue, so we adapt the insight ("sort whole segments where
they live") to the TPU's vector units with a **bitonic sorting network**:

- compare-exchange partners at distance ``j`` (a power of two) are obtained
  by ``reshape(S//(2j), 2, j)`` + a flip along the middle axis — XOR-partner
  addressing with *no gather/scatter*, pure relayout;
- the ascending/descending direction of stage ``k`` depends only on the outer
  index ``q``, so it is a broadcasted-iota predicate;
- the whole network is O(S log^2 S) fully-vectorized compare-exchanges on a
  segment resident in VMEM.

One grid step sorts one segment; the payload array is permuted alongside the
keys (used to carry record indices through the sort).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compare_exchange(keys, vals, k_exp: int, j_exp: int):
    """One bitonic stage: partners at distance 2^j_exp within blocks of
    2^k_exp. keys/vals are flat (S,)."""
    s = keys.shape[0]
    j = 1 << j_exp
    rows = s // (2 * j)
    ks = keys.reshape(rows, 2, j)
    vs = vals.reshape(rows, 2, j)
    lo_k, hi_k = ks[:, 0, :], ks[:, 1, :]
    lo_v, hi_v = vs[:, 0, :], vs[:, 1, :]
    # ascending iff bit k_exp of the element index is 0; that bit lives at
    # bit (k_exp - j_exp - 1) of the row index q.
    shift = k_exp - j_exp - 1
    q = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0)
    dir_up = ((q >> shift) & 1) == 0
    swap = jnp.where(dir_up, lo_k > hi_k, lo_k < hi_k)
    new_lo_k = jnp.where(swap, hi_k, lo_k)
    new_hi_k = jnp.where(swap, lo_k, hi_k)
    new_lo_v = jnp.where(swap, hi_v, lo_v)
    new_hi_v = jnp.where(swap, lo_v, hi_v)
    keys = jnp.stack([new_lo_k, new_hi_k], axis=1).reshape(s)
    vals = jnp.stack([new_lo_v, new_hi_v], axis=1).reshape(s)
    return keys, vals


def _bitonic_kernel(keys_ref, vals_ref, out_k_ref, out_v_ref):
    s = keys_ref.shape[-1]
    m = int(math.log2(s))
    keys = keys_ref[...].reshape(s)
    vals = vals_ref[...].reshape(s)
    for k_exp in range(1, m + 1):
        for j_exp in range(k_exp - 1, -1, -1):
            keys, vals = _compare_exchange(keys, vals, k_exp, j_exp)
    out_k_ref[...] = keys.reshape(out_k_ref.shape)
    out_v_ref[...] = vals.reshape(out_v_ref.shape)


def _next_pow2(x: int) -> int:
    return 1 << max(1, (x - 1).bit_length())


def _max_sentinel(dtype):
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_kv_segments_pallas(keys: jnp.ndarray, values: jnp.ndarray,
                            interpret: bool = True):
    """Sort each row of ``keys`` ascending, permuting ``values`` alongside.

    keys/values: (num_segments, segment_len). Padding to the next power of two
    uses a max sentinel so padded slots sort to the end and are sliced off.
    Not stable — callers needing stability pack a unique tiebreak into keys.
    """
    n, s = keys.shape
    s_pad = _next_pow2(s)
    if s_pad != s:
        pad_k = jnp.full((n, s_pad - s), _max_sentinel(keys.dtype), keys.dtype)
        pad_v = jnp.zeros((n, s_pad - s), values.dtype)
        keys = jnp.concatenate([keys, pad_k], axis=1)
        values = jnp.concatenate([values, pad_v], axis=1)
    out_k, out_v = pl.pallas_call(
        _bitonic_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, s_pad), lambda i: (i, 0)),
                  pl.BlockSpec((1, s_pad), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, s_pad), lambda i: (i, 0)),
                   pl.BlockSpec((1, s_pad), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, s_pad), keys.dtype),
                   jax.ShapeDtypeStruct((n, s_pad), values.dtype)],
        interpret=interpret,
    )(keys, values)
    return out_k[:, :s], out_v[:, :s]


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_segments_pallas(keys: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Keys-only row sort (payload = dummy)."""
    dummy = jnp.zeros_like(keys, dtype=jnp.int32)
    out_k, _ = sort_kv_segments_pallas(keys, dummy, interpret=interpret)
    return out_k
