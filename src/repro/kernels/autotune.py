"""Backend-aware sort-kernel autotuner: measure once, cache, replay.

Three interchangeable implementations back ``ops.sort_segments`` /
``ops.sort_kv_segments``:

- ``"bitonic"`` — the in-VMEM sorting network (O(S log² S), not stable),
- ``"radix"``   — the stable LSD counting-radix kernel (O(S · 32/bits)),
- ``"oracle"``  — XLA's stable sort (``jnp.sort`` / stable argsort+gather).

Which one wins depends on the backend and the segment geometry: on TPU the
Pallas kernels keep segments resident in VMEM; on the CPU container they run
in interpret mode, where XLA's native sort often wins and the O(S²/chunk)
matmul permutation makes radix a guaranteed loss. Rather than scatter
``use_pallas`` booleans through every call site, this module picks **per
shape**: the first call for a given ``(kv, dtype, num_segments, segment_len,
backend, mode)`` cell times every candidate on synthetic data and caches the
winner for the life of the process — every later call replays the cached
choice with zero measurement. The measured table can be exported (the kernel
benchmark persists it into ``BENCH_kernels.json`` and CI uploads it as an
artifact) and pre-loaded via ``REPRO_AUTOTUNE_TABLE=<path>`` so production
runs never measure at all.

Resolution order (first hit wins):

1. ``REPRO_KERNEL_FORCE=radix|bitonic|oracle`` — unconditional override,
2. the in-process cache (each cell is measured at most once — asserted in
   tests via :data:`MEASUREMENTS`),
3. a pre-loaded table entry for this backend/mode,
4. below :data:`MIN_MEASURE_ELEMS` (or with ``REPRO_AUTOTUNE=0``): the
   static default ``"oracle"`` — measurement noise beats kernel differences
   on tiny segments, and the stable oracle is always correct,
5. measure all eligible candidates, pick the fastest. Candidates outside
   their envelope (radix beyond its VMEM bound, or interpret-mode radix past
   the measurement budget) are skipped **with a recorded reason** — never
   silently.

Every choice is stable-aware: callers that need stability (the stage-2
segmented sort's suffix padding) ask :func:`is_stable` about the resolved
algorithm and only then enable the sentinel-collision guard.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import os
import time
from typing import Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.bitonic_sort import (sort_kv_segments_pallas,
                                        sort_segments_pallas)
from repro.kernels.radix_sort import (radix_supported, sort_kv_segments_radix,
                                      sort_segments_radix)

ALGOS = ("bitonic", "radix", "oracle")

#: algorithms that preserve the input order of equal keys.
STABLE_ALGOS = frozenset({"radix", "oracle"})

FORCE_ENV = "REPRO_KERNEL_FORCE"
TABLE_ENV = "REPRO_AUTOTUNE_TABLE"
MEASURE_ENV = "REPRO_AUTOTUNE"

#: cells smaller than this take the static default instead of measuring.
MIN_MEASURE_ELEMS = 1 << 14

#: interpret-mode radix measurement budget: the matmul permutation is
#: emulated, so measuring huge cells would stall the caller for seconds.
_RADIX_MEASURE_MAX_SEGLEN = 512
_RADIX_MEASURE_MAX_ELEMS = 1 << 15

_MEASURE_ITERS = 3


@dataclasses.dataclass(frozen=True)
class Choice:
    """Resolved algorithm for one cell.

    source: "forced" | "cached" | "table" | "static" | "measured".
    melem:  algo -> measured throughput (Melem/s); measurement cells only.
    skipped: algo -> reason it was not measured.
    """
    algo: str
    source: str
    melem: Mapping[str, float] = dataclasses.field(default_factory=dict)
    skipped: Mapping[str, str] = dataclasses.field(default_factory=dict)


#: cell key -> times that cell was actually measured (test introspection:
#: the replay test asserts every value stays at 1).
MEASUREMENTS: "collections.Counter[str]" = collections.Counter()

_cache: Dict[str, Choice] = {}
#: pre-built source="cached" views of _cache entries, so the hot replay
#: path (every sort call after the first) is one dict hit, not a
#: dataclasses.replace allocation.
_cached_view: Dict[str, Choice] = {}
_table: Dict[str, str] = {}
_table_loaded = False
_cell_key_memo: Dict[Tuple, str] = {}


def interpret_default() -> bool:
    """Pallas interpret mode: forced by ``REPRO_PALLAS_INTERPRET``, else on
    exactly when the backend is CPU (no Mosaic compiler)."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "cpu"


def cell_key(num_segments: int, segment_len: int, dtype, kv: bool,
             interpret: Optional[bool] = None) -> str:
    """Stable string id of an autotune cell — also the JSON table key.
    Memoized: this sits on the per-call sort dispatch path."""
    interp = interpret_default() if interpret is None else interpret
    memo_k = (num_segments, segment_len, dtype, kv, interp,
              jax.default_backend())
    key = _cell_key_memo.get(memo_k)
    if key is None:
        mode = "interp" if interp else "compiled"
        key = (f"{'kv' if kv else 'keys'}|{jnp.dtype(dtype).name}"
               f"|{num_segments}x{segment_len}|{memo_k[-1]}|{mode}")
        _cell_key_memo[memo_k] = key
    return key


def reset() -> None:
    """Drop every cached choice, loaded table and measurement count
    (tests; also lets a long-lived process re-tune after backend changes)."""
    _cache.clear()
    _cached_view.clear()
    _table.clear()
    _cell_key_memo.clear()
    MEASUREMENTS.clear()
    global _table_loaded
    _table_loaded = False


def is_stable(algo: str) -> bool:
    return algo in STABLE_ALGOS


def load_table(table: Mapping[str, str]) -> None:
    """Pre-load ``cell key -> algo`` choices (e.g. the ``autotune_table``
    entry of BENCH_kernels.json). Keys for other backends/modes are kept but
    never match, so one file can carry several backends' tables."""
    for k, v in table.items():
        algo = v["algo"] if isinstance(v, Mapping) else v
        if algo in ALGOS:
            _table[str(k)] = algo


def export_table() -> Dict[str, Dict]:
    """JSON-ready ``cell key -> {algo, source, melem, skipped}`` snapshot of
    every resolved cell (the benchmark persists this)."""
    return {k: {"algo": c.algo, "source": c.source,
                "melem": dict(c.melem), "skipped": dict(c.skipped)}
            for k, c in _cache.items()}


def _load_table_env() -> None:
    global _table_loaded
    if _table_loaded:
        return
    _table_loaded = True
    path = os.environ.get(TABLE_ENV)
    if not path or not os.path.exists(path):
        return
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        return
    results = doc.get("results", doc)
    table = results.get("autotune_table", {})
    load_table(table.get("entries", table) if isinstance(table, Mapping)
               else {})


def _synth(num_segments: int, segment_len: int, dtype, kv: bool):
    rng = np.random.default_rng(0)
    dtype = jnp.dtype(dtype)
    shape = (num_segments, segment_len)
    if dtype == jnp.float32:
        keys = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    elif dtype == jnp.uint32:
        keys = jnp.asarray(
            rng.integers(0, 1 << 32, size=shape, dtype=np.uint64)
            .astype(np.uint32))
    else:
        keys = jnp.asarray(
            rng.integers(0, (1 << 31) - 1, size=shape, dtype=np.int64)
            .astype(np.int32))
    if not kv:
        return (keys,)
    vals = jnp.arange(num_segments * segment_len,
                      dtype=jnp.int32).reshape(shape)
    return keys, vals


def _candidate(algo: str, kv: bool, interpret: bool) -> Callable:
    if algo == "oracle":
        fn = ref.sort_kv_segments_ref if kv else ref.sort_segments_ref
        return jax.jit(fn)
    if algo == "bitonic":
        if kv:
            return lambda k, v: sort_kv_segments_pallas(
                k, v, interpret=interpret)
        return lambda k: sort_segments_pallas(k, interpret=interpret)
    if kv:
        return lambda k, v: sort_kv_segments_radix(k, v, interpret=interpret)
    return lambda k: sort_segments_radix(k, interpret=interpret)


def _time(fn: Callable, args) -> float:
    """Best-of-N wall time (first call compiles and is discarded)."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(_MEASURE_ITERS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _measure(num_segments: int, segment_len: int, dtype, kv: bool,
             interpret: bool) -> Choice:
    args = _synth(num_segments, segment_len, dtype, kv)
    n_elem = num_segments * segment_len
    melem: Dict[str, float] = {}
    skipped: Dict[str, str] = {}
    for algo in ALGOS:
        if algo == "radix":
            reason = radix_supported(segment_len)
            if reason is None and jnp.dtype(dtype) not in (
                    jnp.int32, jnp.uint32, jnp.float32):
                reason = f"unsupported key dtype {jnp.dtype(dtype).name}"
            if reason is None and interpret and (
                    segment_len > _RADIX_MEASURE_MAX_SEGLEN
                    or n_elem > _RADIX_MEASURE_MAX_ELEMS):
                reason = (f"interpret-mode measurement budget: "
                          f"{num_segments}x{segment_len} exceeds "
                          f"{_RADIX_MEASURE_MAX_ELEMS} elems / "
                          f"{_RADIX_MEASURE_MAX_SEGLEN} seg-len "
                          f"(matmul permutation is emulated on CPU)")
            if reason is not None:
                skipped[algo] = reason
                continue
        try:
            dt = _time(_candidate(algo, kv, interpret), args)
        except Exception as e:  # candidate failed outright: disqualify
            skipped[algo] = f"{type(e).__name__}: {e}"
            continue
        melem[algo] = n_elem / dt / 1e6
    if not melem:
        return Choice("oracle", "static", melem={}, skipped=skipped)
    best = max(melem, key=lambda a: melem[a])
    return Choice(best, "measured", melem=melem, skipped=skipped)


def choose(num_segments: int, segment_len: int, dtype, *, kv: bool = True,
           interpret: Optional[bool] = None) -> Choice:
    """Resolve the sort algorithm for one cell (see module docstring for
    the resolution order). Safe to call during tracing: measurement runs
    jitted candidates on synthetic concrete inputs."""
    forced = os.environ.get(FORCE_ENV)
    if forced:
        if forced not in ALGOS:
            raise ValueError(f"{FORCE_ENV}={forced!r}: expected one of "
                             f"{ALGOS}")
        return Choice(forced, "forced")
    interp = interpret_default() if interpret is None else interpret
    key = cell_key(num_segments, segment_len, dtype, kv, interp)
    hit = _cached_view.get(key)
    if hit is not None:
        return hit
    _load_table_env()
    if key in _table:
        choice = Choice(_table[key], "table")
    elif (num_segments * segment_len < MIN_MEASURE_ELEMS
          or os.environ.get(MEASURE_ENV) == "0"):
        choice = Choice("oracle", "static")
    else:
        choice = _measure(num_segments, segment_len, dtype, kv, interp)
        if choice.source == "measured":
            MEASUREMENTS[key] += 1
    _cache[key] = choice
    _cached_view[key] = dataclasses.replace(choice, source="cached")
    return choice
