"""Public jit'd wrappers for the Pallas kernels.

On the CPU container the kernels execute in Pallas ``interpret`` mode (the
kernel body runs as traced JAX ops); on a real TPU set
``REPRO_PALLAS_INTERPRET=0`` to run the compiled kernels. ``use_pallas=False``
falls back to the jnp oracles in :mod:`repro.kernels.ref` — the terasort
benchmark uses that switch to measure kernel-vs-oracle parity.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bucket_hist import bucket_histogram_pallas
from repro.kernels.bitonic_sort import sort_kv_segments_pallas, sort_segments_pallas


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "cpu"


def bucket_histogram(bucket_ids: jnp.ndarray, num_buckets: int,
                     use_pallas: bool = True) -> jnp.ndarray:
    """int32 (num_buckets,) histogram; ids outside range are ignored."""
    if not use_pallas:
        return ref.bucket_histogram_ref(bucket_ids, num_buckets)
    return bucket_histogram_pallas(bucket_ids, num_buckets,
                                   interpret=_interpret_default())


def sort_segments(keys: jnp.ndarray, use_pallas: bool = True) -> jnp.ndarray:
    """Sort each row ascending."""
    if not use_pallas:
        return ref.sort_segments_ref(keys)
    return sort_segments_pallas(keys, interpret=_interpret_default())


def sort_kv_segments(keys: jnp.ndarray, values: jnp.ndarray,
                     use_pallas: bool = True):
    """Sort each row of (keys, values) by key."""
    if not use_pallas:
        return ref.sort_kv_segments_ref(keys, values)
    return sort_kv_segments_pallas(keys, values, interpret=_interpret_default())
