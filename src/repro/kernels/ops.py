"""Public jit'd wrappers for the Pallas kernels.

On the CPU container the kernels execute in Pallas ``interpret`` mode (the
kernel body runs as traced JAX ops); on a real TPU set
``REPRO_PALLAS_INTERPRET=0`` to run the compiled kernels.

The segment-sort entry points (:func:`sort_segments`,
:func:`sort_kv_segments`) dispatch through the backend-aware autotuner
(:mod:`repro.kernels.autotune`): ``algo=None`` measures bitonic vs radix vs
the XLA oracle once per shape cell and replays the cached winner; ``algo``
may pin ``"bitonic"`` / ``"radix"`` / ``"oracle"`` explicitly, and
``REPRO_KERNEL_FORCE`` overrides everything. The historical ``use_pallas``
boolean is deprecated (it predates the radix kernel): ``True`` maps to
``"bitonic"``, ``False`` to ``"oracle"``.
"""

from __future__ import annotations

import os
import warnings
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import autotune, ref
from repro.kernels.bucket_hist import bucket_histogram_pallas
from repro.kernels.bitonic_sort import (sort_kv_segments_pallas,
                                        sort_segments_pallas)
from repro.kernels.partition import partition_rank_pallas
from repro.kernels.radix_sort import (sort_kv_segments_radix,
                                      sort_segments_radix)

_UNSET = object()  # sentinel: "use_pallas not passed" (deprecation shim)

_interpret_default = autotune.interpret_default


def pad_sentinel(dtype):
    """Greatest value of ``dtype`` — the padding key that sorts to the end
    of a segment (+inf for floats, the integer max otherwise), as a numpy
    scalar so it stays concrete inside traced code. Stable sorts keep real
    keys equal to the sentinel ahead of suffix padding; only the unstable
    bitonic network needs the collision guard."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return dtype.type(float("inf"))
    return dtype.type(jnp.iinfo(dtype).max)


def _legacy_algo(use_pallas, algo: Optional[str], where: str) -> Optional[str]:
    """Fold the deprecated ``use_pallas`` boolean into ``algo``."""
    if use_pallas is _UNSET:
        return algo
    warnings.warn(
        f"{where}(use_pallas=...) is deprecated: the kernel choice is now "
        f"autotuned per shape/backend; pass algo='bitonic'/'radix'/'oracle' "
        f"to pin one (use_pallas={bool(use_pallas)} maps to "
        f"algo={'bitonic' if use_pallas else 'oracle'!r}).",
        DeprecationWarning, stacklevel=3)
    if algo is not None:
        return algo
    return "bitonic" if use_pallas else "oracle"


def resolve_sort_algo(num_segments: int, segment_len: int, dtype,
                      algo: Optional[str] = None, kv: bool = True) -> str:
    """The algorithm :func:`sort_segments` / :func:`sort_kv_segments` will
    run for this cell: the forced/pinned/autotuned choice as a plain string,
    resolvable at trace time (callers use it to decide stability-dependent
    guards before the sort runs). ``REPRO_KERNEL_FORCE`` beats a pinned
    ``algo``."""
    if not os.environ.get(autotune.FORCE_ENV) and algo is not None:
        if algo not in autotune.ALGOS:
            raise ValueError(f"algo={algo!r}: expected one of "
                             f"{autotune.ALGOS} (or None to autotune)")
        return algo
    return autotune.choose(num_segments, segment_len, dtype, kv=kv).algo


def bucket_histogram(bucket_ids: jnp.ndarray, num_buckets: int,
                     use_pallas: bool = True) -> jnp.ndarray:
    """int32 (num_buckets,) histogram; ids outside range are ignored."""
    if not use_pallas:
        return ref.bucket_histogram_ref(bucket_ids, num_buckets)
    return bucket_histogram_pallas(bucket_ids, num_buckets,
                                   interpret=_interpret_default())


def partition_rank(dest: jnp.ndarray, num_dest: int,
                   use_pallas: bool = True):
    """Fused one-pass (stable rank, histogram) of a destination vector —
    see :func:`repro.kernels.partition.partition_rank_pallas`."""
    if not use_pallas:
        return ref.partition_rank_ref(dest, num_dest)
    return partition_rank_pallas(dest, num_dest,
                                 interpret=_interpret_default())


def partition_pack(
    columns: Sequence[jnp.ndarray],
    dest: jnp.ndarray,
    num_dest: int,
    capacity: int,
    use_pallas: bool = True,
) -> Tuple[List[jnp.ndarray], jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """O(n) fused partition/pack: lay records out contiguously per
    destination and pack fixed-size ``(num_dest, capacity, ...)`` tiles.

    This is the shuffle send-path primitive (every ``sphere_shuffle`` /
    ``hierarchical_shuffle`` stage, the MoE expert regroup, and the
    segmented stage-2 sort all go through it). It replaces the historical
    stable-argsort + histogram + gather with one fused rank pass (Pallas
    kernel or jnp oracle), an O(n) slot-map scatter, and one gather per
    column — reproducing the stable-argsort layout exactly: destination
    d's records occupy slots [0, counts[d]) of row d in arrival order, and
    records past ``capacity`` are dropped *from the tail* (later arrivals
    lose, exactly as the argsort layout dropped them).

    Args:
      columns: arrays sharing leading dim n; each is packed into its own
        tile stack (dtypes are preserved — records are moved, not summed).
      dest: (n,) int32; ids outside [0, num_dest) are never packed (callers
        use ``num_dest`` as the virtual overflow destination).
      capacity: slots per destination.
    Returns (tiles, in_range, origin, dropped_local):
      tiles[i]:  (num_dest, capacity, *columns[i].shape[1:])
      in_range:  (num_dest, capacity) bool — slot holds a real record
      origin:    (num_dest, capacity) int32 source row (-1 on empty slots;
                 meaningful only where ``in_range``)
      dropped_local: () int32 — records beyond capacity, this shard only.
    """
    dest = jnp.asarray(dest, jnp.int32).reshape(-1)
    n = dest.shape[0]
    if n == 0:
        tiles = [jnp.zeros((num_dest, capacity) + c.shape[1:], c.dtype)
                 for c in columns]
        return (tiles, jnp.zeros((num_dest, capacity), bool),
                jnp.full((num_dest, capacity), -1, jnp.int32),
                jnp.zeros((), jnp.int32))
    rank, counts = partition_rank(dest, num_dest, use_pallas=use_pallas)
    ok = (dest >= 0) & (dest < num_dest) & (rank < capacity)
    slot = jnp.where(ok, dest * capacity + rank, num_dest * capacity)
    origin = (jnp.full((num_dest * capacity + 1,), -1, jnp.int32)
              .at[slot].set(jnp.arange(n, dtype=jnp.int32))
              [:num_dest * capacity].reshape(num_dest, capacity))
    cap_iota = jnp.arange(capacity, dtype=counts.dtype)[None, :]
    in_range = cap_iota < counts[:, None]
    gidx = jnp.clip(origin, 0, n - 1).reshape(-1)
    tiles = [jnp.take(col, gidx, axis=0)
             .reshape((num_dest, capacity) + col.shape[1:])
             for col in columns]
    dropped_local = jnp.sum(jnp.maximum(counts - capacity, 0)).astype(jnp.int32)
    return tiles, in_range, origin, dropped_local


def sort_segments(keys: jnp.ndarray, use_pallas=_UNSET, *,
                  algo: Optional[str] = None) -> jnp.ndarray:
    """Sort each row ascending.

    ``algo=None`` → autotuned per shape/backend (see module docstring);
    ``"bitonic"``/``"radix"``/``"oracle"`` pin an implementation.
    ``use_pallas`` is the deprecated boolean predecessor.
    """
    algo = _legacy_algo(use_pallas, algo, "sort_segments")
    n, s = keys.shape
    resolved = resolve_sort_algo(n, s, keys.dtype, algo, kv=False)
    if resolved == "oracle":
        return ref.sort_segments_ref(keys)
    if resolved == "radix":
        return sort_segments_radix(keys, interpret=_interpret_default())
    return sort_segments_pallas(keys, interpret=_interpret_default())


def sort_kv_segments(keys: jnp.ndarray, values: jnp.ndarray,
                     use_pallas=_UNSET, *, algo: Optional[str] = None):
    """Sort each row of (keys, values) by key.

    ``algo=None`` → autotuned per shape/backend; ``"radix"`` and
    ``"oracle"`` are stable, ``"bitonic"`` is not (callers needing
    stability check :func:`repro.kernels.autotune.is_stable` on the
    :func:`resolve_sort_algo` result). ``use_pallas`` is deprecated.
    """
    algo = _legacy_algo(use_pallas, algo, "sort_kv_segments")
    n, s = keys.shape
    resolved = resolve_sort_algo(n, s, keys.dtype, algo, kv=True)
    if resolved == "oracle":
        return ref.sort_kv_segments_ref(keys, values)
    if resolved == "radix":
        return sort_kv_segments_radix(keys, values,
                                      interpret=_interpret_default())
    return sort_kv_segments_pallas(keys, values,
                                   interpret=_interpret_default())
