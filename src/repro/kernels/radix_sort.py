"""Pallas kernel: stable LSD counting-radix sort of (key, payload) segments.

The bitonic network (:mod:`repro.kernels.bitonic_sort`) is O(S log² S)
compare-exchanges and **not stable** — ties (including the padding sentinel)
can swap. This kernel is the complementary design point: a least-significant
-digit counting radix sort, O(S · 32/bits) work, **stable by construction**
(each pass preserves the arrival order of equal digits), built from exactly
three TPU-native primitives:

- ``broadcasted_iota`` + compare to build one-hot digit planes,
- ``cumsum`` over the one-hot plane — the same stable counting-rank
  primitive :mod:`repro.kernels.partition` uses for the shuffle send path
  (rank of record i within its digit bucket = # earlier records with the
  same digit; bucket base = exclusive cumsum of the histogram), giving each
  row its destination ``pos = base[digit] + rank`` in one pass,
- an **MXU matmul permutation**: Mosaic has no per-element gather/scatter,
  so applying the permutation is expressed as ``out = Xᵀ · P`` where
  ``P[i, j] = (pos[i] == j)`` is built blockwise (``chunk`` output columns
  at a time) from ``pos`` with iota compares. Each output column has exactly
  one nonzero term, so the f32 accumulate is exact once operands are split
  into 16-bit limbs (every limb < 2¹⁶ is exactly representable in f32).

Keys are first mapped through an order-preserving bijection onto uint32
("sortable bits": int32 flips the sign bit, float32 flips sign-magnitude to
two's-complement-like order), sorted as unsigned bytes, and mapped back —
one kernel body serves int32/uint32/float32. NaN keys are unsupported (as
with the bitonic kernel's ±inf sentinel); -0.0 orders before +0.0 (bit
order refines the numeric order at the one tie the bijection splits).

Padding (segment length to a lane multiple, segment count to whole blocks)
uses the transformed-domain maximum ``0xFFFFFFFF``: stability keeps real
rows ahead of padding even when a real key equals the sentinel, so — unlike
the bitonic kernel — no key value is reserved.

On the CPU container the kernel runs in interpret mode where the O(S²/chunk)
matmul permutation is emulated scalar work — the autotuner
(:mod:`repro.kernels.autotune`) measures this and falls back to the bitonic
kernel or the XLA oracle; radix is the TPU design point, selected only where
measurement says it wins.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: supported digit widths (bits per pass); 32 must divide evenly.
SUPPORTED_BITS = (1, 2, 4, 8)

#: output-column block width of the matmul permutation (MXU lane width).
_PERMUTE_CHUNK = 128

#: soft VMEM budget (bytes) for the per-block one-hot plane; bounds
#: rows_per_step and the bits=8 segment-length envelope.
_VMEM_BUDGET = 4 << 20


# -- order-preserving key <-> uint32 bijections ------------------------------


def key_to_sortable_bits(keys: jnp.ndarray) -> jnp.ndarray:
    """Map int32/uint32/float32 keys onto uint32 so that unsigned byte order
    equals the key order (monotone bijection)."""
    dt = keys.dtype
    if dt == jnp.uint32:
        return keys
    if dt == jnp.int32:
        return (keys ^ jnp.int32(-2147483648)).astype(jnp.uint32)
    if dt == jnp.float32:
        bits = jax.lax.bitcast_convert_type(keys, jnp.uint32)
        sign = (bits >> jnp.uint32(31)) == jnp.uint32(1)
        return jnp.where(sign, ~bits, bits | jnp.uint32(0x80000000))
    raise TypeError(f"radix sort supports int32/uint32/float32 keys, "
                    f"got {dt}")


def sortable_bits_to_key(bits: jnp.ndarray, dtype) -> jnp.ndarray:
    """Inverse of :func:`key_to_sortable_bits`."""
    dtype = jnp.dtype(dtype)
    if dtype == jnp.uint32:
        return bits
    if dtype == jnp.int32:
        return bits.astype(jnp.int32) ^ jnp.int32(-2147483648)
    if dtype == jnp.float32:
        sign = (bits & jnp.uint32(0x80000000)) == jnp.uint32(0)
        raw = jnp.where(sign, ~bits, bits & jnp.uint32(0x7FFFFFFF))
        return jax.lax.bitcast_convert_type(raw, jnp.float32)
    raise TypeError(f"radix sort supports int32/uint32/float32 keys, "
                    f"got {dtype}")


# -- kernel ------------------------------------------------------------------


def _permute_matmul(pos, planes, chunk: int):
    """Apply ``out[:, pos[i]] = plane[:, i]`` to every u32 plane at once.

    pos: (r, s) int32 destination of each element (a permutation per row).
    planes: sequence of (r, s) uint32 arrays permuted together.
    Implemented as chunked one-hot matmuls (see module docstring): each
    plane is split into two 16-bit limbs so the f32 MXU accumulate is exact.
    """
    r, s = pos.shape
    lhs = []
    for a in planes:
        lhs.append((a & jnp.uint32(0xFFFF)).astype(jnp.float32))
        lhs.append((a >> jnp.uint32(16)).astype(jnp.float32))
    x = jnp.stack(lhs, axis=1)                          # (r, 2·P, s)
    outs = []
    for jc in range(0, s, chunk):
        width = min(chunk, s - jc)
        cols = jc + jax.lax.broadcasted_iota(jnp.int32, (r, s, width), 2)
        p = (pos[:, :, None] == cols).astype(jnp.float32)
        outs.append(jax.lax.dot_general(
            x, p, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32))        # (r, 2·P, width)
    y = jnp.concatenate(outs, axis=-1) if len(outs) > 1 else outs[0]
    res = []
    for i in range(len(planes)):
        lo = y[:, 2 * i, :].astype(jnp.uint32)
        hi = y[:, 2 * i + 1, :].astype(jnp.uint32)
        res.append((hi << jnp.uint32(16)) | lo)
    return res


def _make_radix_kernel(bits: int, num_planes: int, chunk: int):
    nb = 1 << bits

    def kernel(*refs):
        in_refs, out_refs = refs[:num_planes], refs[num_planes:]
        planes = [ref[...] for ref in in_refs]
        keys = planes[0]
        r, s = keys.shape
        cols = jax.lax.broadcasted_iota(jnp.int32, (r, s, nb), 2)
        for shift in range(0, 32, bits):
            digit = ((keys >> jnp.uint32(shift))
                     & jnp.uint32(nb - 1)).astype(jnp.int32)
            oh = digit[:, :, None] == cols
            cum = jnp.cumsum(oh.astype(jnp.int32), axis=1)
            counts = cum[:, -1, :]                       # (r, nb) histogram
            offs = jnp.cumsum(counts, axis=1) - counts   # exclusive bases
            pos = jnp.sum(jnp.where(oh, cum - 1 + offs[:, None, :], 0),
                          axis=2)
            planes = _permute_matmul(pos, planes, chunk)
            keys = planes[0]
        for ref, plane in zip(out_refs, planes):
            ref[...] = plane

    return kernel


def default_bits(segment_len: int) -> int:
    """Digit width by segment length: 8 halves the pass count but needs an
    (S, 256) one-hot plane per row; drop to 4 once that exceeds the VMEM
    budget."""
    return 8 if segment_len * 256 * 4 <= _VMEM_BUDGET else 4


def radix_supported(segment_len: int, bits: Optional[int] = None
                    ) -> Optional[str]:
    """Return None when the kernel envelope covers ``segment_len``, else a
    human-readable reason (callers log it — never a silent skip)."""
    b = bits if bits is not None else default_bits(segment_len)
    if b not in SUPPORTED_BITS:
        return f"bits={b} not in {SUPPORTED_BITS}"
    if segment_len * (1 << b) * 4 > _VMEM_BUDGET:
        return (f"one-hot plane S·2^bits·4 = {segment_len * (1 << b) * 4} "
                f"bytes exceeds the {_VMEM_BUDGET}-byte VMEM budget "
                f"(S={segment_len}, bits={b})")
    return None


def _pad_axis1(arr, width, fill):
    return jnp.concatenate(
        [arr, jnp.full((arr.shape[0], width), fill, arr.dtype)], axis=1)


@functools.partial(jax.jit, static_argnames=("bits", "rows_per_step",
                                             "interpret"))
def sort_kv_segments_radix(keys: jnp.ndarray, values: jnp.ndarray,
                           bits: Optional[int] = None,
                           rows_per_step: Optional[int] = None,
                           interpret: bool = True
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stable-sort each row of ``keys`` ascending, permuting ``values``
    alongside.

    keys/values: (num_segments, segment_len); keys int32/uint32/float32
    (NaN unsupported), values any 32-bit dtype (moved bit-exactly). Rows of
    equal keys keep their input order — the property the stage-2 segmented
    sort relies on to keep suffix padding behind real max-value keys.
    """
    n, s = keys.shape
    key_dtype = keys.dtype
    val_dtype = values.dtype
    if val_dtype.itemsize != 4:
        raise TypeError(f"radix payload must be a 32-bit dtype, "
                        f"got {val_dtype}")
    b = bits if bits is not None else default_bits(s)
    reason = radix_supported(s, b)
    if reason is not None:
        raise ValueError(f"radix kernel unsupported here: {reason}")
    kbits = key_to_sortable_bits(keys)
    vbits = (values if val_dtype == jnp.uint32
             else jax.lax.bitcast_convert_type(values, jnp.uint32))
    # lane-align the segment axis; transformed-domain max pads sort to the
    # suffix and stability keeps them behind real 0xFFFFFFFF keys.
    s_pad = -(-s // _PERMUTE_CHUNK) * _PERMUTE_CHUNK if s > 1 else s
    if s_pad != s:
        kbits = _pad_axis1(kbits, s_pad - s, jnp.uint32(0xFFFFFFFF))
        vbits = _pad_axis1(vbits, s_pad - s, jnp.uint32(0))
    # block rows so the (rb, S, 2^bits) one-hot plane stays within budget
    cap = max(1, _VMEM_BUDGET // max(s_pad * (1 << b) * 4, 1))
    rb = max(1, min(rows_per_step if rows_per_step is not None else 8,
                    cap, n))
    n_pad = -(-n // rb) * rb
    if n_pad != n:
        kbits = jnp.concatenate(
            [kbits, jnp.zeros((n_pad - n, s_pad), jnp.uint32)], axis=0)
        vbits = jnp.concatenate(
            [vbits, jnp.zeros((n_pad - n, s_pad), jnp.uint32)], axis=0)
    spec = pl.BlockSpec((rb, s_pad), lambda i: (i, 0))
    out_k, out_v = pl.pallas_call(
        _make_radix_kernel(b, num_planes=2, chunk=min(_PERMUTE_CHUNK, s_pad)),
        grid=(n_pad // rb,),
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n_pad, s_pad), jnp.uint32),
                   jax.ShapeDtypeStruct((n_pad, s_pad), jnp.uint32)],
        interpret=interpret,
    )(kbits, vbits)
    out_k = sortable_bits_to_key(out_k[:n, :s], key_dtype)
    out_v = out_v[:n, :s]
    if val_dtype != jnp.uint32:
        out_v = jax.lax.bitcast_convert_type(out_v, val_dtype)
    return out_k, out_v


@functools.partial(jax.jit, static_argnames=("bits", "rows_per_step",
                                             "interpret"))
def sort_segments_radix(keys: jnp.ndarray,
                        bits: Optional[int] = None,
                        rows_per_step: Optional[int] = None,
                        interpret: bool = True) -> jnp.ndarray:
    """Keys-only row sort (single-plane kernel — no payload matmuls)."""
    n, s = keys.shape
    key_dtype = keys.dtype
    b = bits if bits is not None else default_bits(s)
    reason = radix_supported(s, b)
    if reason is not None:
        raise ValueError(f"radix kernel unsupported here: {reason}")
    kbits = key_to_sortable_bits(keys)
    s_pad = -(-s // _PERMUTE_CHUNK) * _PERMUTE_CHUNK if s > 1 else s
    if s_pad != s:
        kbits = _pad_axis1(kbits, s_pad - s, jnp.uint32(0xFFFFFFFF))
    cap = max(1, _VMEM_BUDGET // max(s_pad * (1 << b) * 4, 1))
    rb = max(1, min(rows_per_step if rows_per_step is not None else 8,
                    cap, n))
    n_pad = -(-n // rb) * rb
    if n_pad != n:
        kbits = jnp.concatenate(
            [kbits, jnp.zeros((n_pad - n, s_pad), jnp.uint32)], axis=0)
    spec = pl.BlockSpec((rb, s_pad), lambda i: (i, 0))
    (out_k,) = pl.pallas_call(
        _make_radix_kernel(b, num_planes=1, chunk=min(_PERMUTE_CHUNK, s_pad)),
        grid=(n_pad // rb,),
        in_specs=[spec],
        out_specs=[spec],
        out_shape=[jax.ShapeDtypeStruct((n_pad, s_pad), jnp.uint32)],
        interpret=interpret,
    )(kbits)
    return sortable_bits_to_key(out_k[:n, :s], key_dtype)
