"""Pallas kernel: fused per-destination histogram + stable counting rank.

The shuffle send path (paper §3.2 "the output can be sent to multiple
locations") has to lay local records out contiguously per destination before
the capacity-bounded ``all_to_all``. The historical implementation paid a
full stable ``argsort`` over every local record on every send — O(n log² n)
compare-exchanges on TPU — even though the layout only needs, per record,

  ``rank[i]`` = how many earlier records share record i's destination,

and, per destination, the total count. Both fall out of ONE pass over the
destination vector (the paper's one-pass "hashing" stage of Fig 3):

- the destination one-hot of a tile (the same trick ``bucket_hist`` feeds
  the MXU) is cumulative-summed along the record axis, giving each record
  its *intra-tile* rank in its destination column and the tile's histogram
  in the final row;
- a running per-destination base (the histogram of all earlier tiles) is
  kept resident in the revisited output block and added to the intra-tile
  rank, making ranks global and **stable by construction** — records keep
  their arrival order within a destination, exactly like the stable argsort
  they replace.

Counts accumulate in **int32** (the float32 one-hot matmul of the original
histogram kernel silently lost increments past 2^24 records; a cumsum in
int32 is exact to 2^31).

Downstream (:func:`repro.kernels.ops.partition_pack`) converts
``(rank, counts)`` into the packed ``(num_dest, capacity, ...)`` send tiles
with one O(n) slot-map scatter + one gather per column — no sort anywhere
on the send path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rank_kernel(ids_ref, rank_ref, counts_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    ids = ids_ref[...]                       # (1, tile) int32
    tile = ids.shape[-1]
    d_pad = counts_ref.shape[-1]
    # destination one-hot (bucket_hist's MXU trick, reused for the rank)
    cols = jax.lax.broadcasted_iota(jnp.int32, (tile, d_pad), 1)
    oh = ids.reshape(tile, 1) == cols        # (tile, d_pad)
    base = counts_ref[...]                   # counts of all earlier tiles
    cum = jnp.cumsum(oh.astype(jnp.int32), axis=0)
    # each record's global stable rank within its destination column
    rank = jnp.sum(jnp.where(oh, cum - 1 + base, 0), axis=1)
    rank_ref[...] = rank.reshape(1, tile)
    counts_ref[...] = base + cum[-1:, :]     # int32: exact to 2^31


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("num_dest", "tile", "interpret"))
def partition_rank_pallas(
    dest: jnp.ndarray,
    num_dest: int,
    tile: int = 1024,
    interpret: bool = True,
):
    """One-pass fused (stable rank, histogram) of ``dest`` (int32 (n,)).

    Returns ``(rank (n,) int32, counts (num_dest,) int32)``. ``rank[i]`` is
    meaningful only where ``dest[i]`` is in [0, num_dest); out-of-range ids
    (negative padding, the ``num_dest`` overflow destination) contribute to
    no count and get an unspecified rank.
    """
    n = dest.shape[0]
    n_pad = max(_round_up(max(n, 1), tile), tile)
    # pad with -1: matches no destination column, counts nothing
    ids = jnp.full((n_pad,), -1, dtype=jnp.int32).at[:n].set(
        dest.astype(jnp.int32))
    d_pad = _round_up(max(num_dest, 1), 128)  # lane-aligned destination axis
    grid = (n_pad // tile,)
    rank, counts = pl.pallas_call(
        _rank_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, tile), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((1, tile), lambda i: (0, i)),
                   pl.BlockSpec((1, d_pad), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, n_pad), jnp.int32),
                   jax.ShapeDtypeStruct((1, d_pad), jnp.int32)],
        interpret=interpret,
    )(ids.reshape(1, n_pad))
    return rank[0, :n], counts[0, :num_dest]
