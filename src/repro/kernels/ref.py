"""Pure-jnp oracles for the Pallas kernels (used by the allclose test sweeps).

These are the semantics the kernels must match exactly; they are also the
fallback implementation path when Pallas is unavailable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def bucket_histogram_ref(bucket_ids: jnp.ndarray, num_buckets: int) -> jnp.ndarray:
    """Count of records per bucket. ids outside [0, num_buckets) are ignored.

    Args:
      bucket_ids: int32 (n,)
      num_buckets: static python int
    Returns:
      int32 (num_buckets,)
    """
    ids = bucket_ids.astype(jnp.int32)
    onehot = (ids[:, None] == jnp.arange(num_buckets, dtype=jnp.int32)[None, :])
    return jnp.sum(onehot.astype(jnp.int32), axis=0)


def partition_rank_ref(dest: jnp.ndarray, num_dest: int, tile: int = 4096):
    """Fused (stable rank, histogram) of the destination vector.

    ``rank[i]`` counts earlier records with the same destination — the slot
    record i occupies within destination ``dest[i]``'s contiguous run, in
    arrival order (the exact layout a stable argsort by destination would
    produce). Out-of-range ids (< 0 or >= num_dest) count nothing and get
    an unspecified rank. O(n · num_dest) vectorized work, no sort — the
    one-hot cumsum runs as a scan over ``tile``-row chunks carrying the
    per-destination base (mirroring the Pallas kernel's grid), so transient
    memory is O(tile · num_dest) rather than O(n · num_dest).

    Args:
      dest: int32 (n,)
      num_dest: static python int
    Returns:
      (rank int32 (n,), counts int32 (num_dest,))
    """
    ids = dest.astype(jnp.int32).reshape(-1)
    n = ids.shape[0]
    if n == 0:
        return ids, jnp.zeros((num_dest,), jnp.int32)
    tile = min(tile, n)
    n_pad = (n + tile - 1) // tile * tile
    padded = jnp.full((n_pad,), -1, jnp.int32).at[:n].set(ids)
    cols = jnp.arange(num_dest, dtype=jnp.int32)[None, :]

    def step(base, chunk):
        oh = chunk[:, None] == cols
        cum = jnp.cumsum(oh.astype(jnp.int32), axis=0)
        rank = jnp.sum(jnp.where(oh, cum - 1 + base[None, :], 0), axis=1)
        return base + cum[-1], rank

    counts, ranks = jax.lax.scan(step, jnp.zeros((num_dest,), jnp.int32),
                                 padded.reshape(-1, tile))
    return ranks.reshape(-1)[:n], counts


def sort_segments_ref(keys: jnp.ndarray) -> jnp.ndarray:
    """Ascending sort of each segment (row) independently.

    Args:
      keys: (num_segments, segment_len) int32/uint32/float32
    Returns:
      sorted keys, same shape/dtype
    """
    return jnp.sort(keys, axis=-1)


def sort_kv_segments_ref(keys: jnp.ndarray, values: jnp.ndarray):
    """Sort each segment of (key, value) rows by key (stable).

    Args:
      keys:   (num_segments, segment_len)
      values: (num_segments, segment_len) payload (e.g. record index)
    Returns:
      (sorted_keys, permuted_values)
    """
    order = jnp.argsort(keys, axis=-1, stable=True)
    skeys = jnp.take_along_axis(keys, order, axis=-1)
    svals = jnp.take_along_axis(values, order, axis=-1)
    return skeys, svals
