"""Pure-jnp oracles for the Pallas kernels (used by the allclose test sweeps).

These are the semantics the kernels must match exactly; they are also the
fallback implementation path when Pallas is unavailable.
"""

from __future__ import annotations

import jax.numpy as jnp


def bucket_histogram_ref(bucket_ids: jnp.ndarray, num_buckets: int) -> jnp.ndarray:
    """Count of records per bucket. ids outside [0, num_buckets) are ignored.

    Args:
      bucket_ids: int32 (n,)
      num_buckets: static python int
    Returns:
      int32 (num_buckets,)
    """
    ids = bucket_ids.astype(jnp.int32)
    onehot = (ids[:, None] == jnp.arange(num_buckets, dtype=jnp.int32)[None, :])
    return jnp.sum(onehot.astype(jnp.int32), axis=0)


def sort_segments_ref(keys: jnp.ndarray) -> jnp.ndarray:
    """Ascending sort of each segment (row) independently.

    Args:
      keys: (num_segments, segment_len) int32/uint32/float32
    Returns:
      sorted keys, same shape/dtype
    """
    return jnp.sort(keys, axis=-1)


def sort_kv_segments_ref(keys: jnp.ndarray, values: jnp.ndarray):
    """Sort each segment of (key, value) rows by key (stable).

    Args:
      keys:   (num_segments, segment_len)
      values: (num_segments, segment_len) payload (e.g. record index)
    Returns:
      (sorted_keys, permuted_values)
    """
    order = jnp.argsort(keys, axis=-1, stable=True)
    skeys = jnp.take_along_axis(keys, order, axis=-1)
    svals = jnp.take_along_axis(values, order, axis=-1)
    return skeys, svals
