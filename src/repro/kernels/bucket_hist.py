"""Pallas kernel: per-tile bucket histogram via an MXU one-hot matmul.

Terasort stage 1 (paper Fig 3) needs, per shard, the number of records
destined for every range bucket so the shuffle can lay records out
contiguously per destination. The CPU version is a table increment per
record; on TPU the idiomatic form is::

    counts = ones(1, T) @ one_hot(ids, B)      # an MXU matmul per tile

Grid iterates over tiles of the id vector; all grid steps map to the *same*
output block, which Pallas keeps resident in VMEM and we accumulate into
(initialized at step 0). Bucket ids outside [0, B) contribute nothing — the
wrapper uses that to pad inputs to a whole number of tiles.

The per-tile matmul runs in float32 (exact: a tile holds at most ``tile`` <
2^24 records), but the running accumulator is **int32** — a float32
accumulator silently loses +1 increments once a bucket's count passes 2^24
(≈16.7M records), which is well inside a production shard.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(ids_ref, out_ref, *, num_buckets: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]  # (1, tile) int32
    tile = ids.shape[-1]
    # one-hot over the (padded) bucket axis; 2D iota is TPU-safe.
    buckets = jax.lax.broadcasted_iota(jnp.int32, (tile, num_buckets), 1)
    onehot = (ids.reshape(tile, 1) == buckets).astype(jnp.float32)
    ones = jnp.ones((1, tile), dtype=jnp.float32)
    # MXU matmul: (1, tile) @ (tile, B) -> (1, B); per-tile counts <= tile
    # < 2^24 so the f32 matmul is exact — accumulate in int32 (exact to 2^31)
    counts = jnp.dot(ones, onehot, preferred_element_type=jnp.float32)
    out_ref[...] += counts.astype(jnp.int32)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("num_buckets", "tile", "interpret"))
def bucket_histogram_pallas(
    bucket_ids: jnp.ndarray,
    num_buckets: int,
    tile: int = 2048,
    interpret: bool = True,
) -> jnp.ndarray:
    """int32 (num_buckets,) histogram of ``bucket_ids`` (int32 (n,))."""
    n = bucket_ids.shape[0]
    n_pad = max(_round_up(n, tile), tile)
    # pad with an id guaranteed out of range -> lands in no bucket column
    ids = jnp.full((n_pad,), num_buckets, dtype=jnp.int32).at[:n].set(
        bucket_ids.astype(jnp.int32))
    b_pad = _round_up(max(num_buckets, 1), 128)  # lane-aligned bucket axis
    grid = (n_pad // tile,)
    out = pl.pallas_call(
        functools.partial(_hist_kernel, num_buckets=b_pad),
        grid=grid,
        in_specs=[pl.BlockSpec((1, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, b_pad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, b_pad), jnp.int32),
        interpret=interpret,
    )(ids.reshape(1, n_pad))
    return out[0, :num_buckets]
