"""sphere_shuffle: the bucket shuffle (paper §3.2 "Shuffling input streams").

"the output can be sent to multiple locations ... a user-defined function can
specify a bucket ID (that refers to a destination file on either a local or
on a remote node) for each record in the output, and Sphere will send this
record to the specified destination."

TPU adaptation: a per-element network send does not exist; the SPMD-native
form is a **capacity-bounded all_to_all**. Buckets are assigned contiguously
to devices along a mesh axis; each device

1. computes its per-destination histogram (the Pallas ``bucket_hist`` kernel
   or its jnp oracle),
2. stable-sorts records by destination — after which each destination's
   records are *contiguous*, so the send buffer is built with a **gather**
   (TPU-friendly) instead of a scatter,
3. exchanges fixed-size (devices, capacity, ...) tiles with
   ``jax.lax.all_to_all``.

Capacity bounding is the paper's segment-size clamp (S_min/S_max, §3.5.1)
reborn: bounded skew in exchange for a static, compilable communication
pattern. Records beyond capacity are dropped and *counted* (``dropped``), the
same contract MoE capacity-factor dispatch uses — and indeed
:mod:`repro.models.moe` calls this exact function for expert dispatch.

All functions here run **inside** ``shard_map`` and communicate via
``axis_name`` collectives.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class ShuffleResult:
    """Per-device local view of a completed shuffle.

    data:    (num_src, capacity, *rec) records received, grouped by source
             device (row s = records sent by source s).
    valid:   (num_src, capacity) bool — real record vs padding.
    bucket:  (num_src, capacity) int32 global bucket id of each record.
    src_pos: (num_src, capacity) int32 original local row index at the source
             (needed by :func:`sphere_combine` to route results back).
    dropped: () int32 — records dropped across the whole axis this step
             (capacity overflow), psum'd.
    """

    data: jax.Array
    valid: jax.Array
    bucket: jax.Array
    src_pos: jax.Array
    dropped: jax.Array


def _per_dest_layout(dest: jax.Array, num_dest: int):
    """Stable-sort local records by destination; return (order, counts,
    offsets) so that destination d's records sit at
    order[offsets[d] : offsets[d] + counts[d]]."""
    n = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    counts = jnp.bincount(dest, length=num_dest)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    return order, counts, offsets


def sphere_shuffle(
    data: jax.Array,
    bucket_ids: jax.Array,
    num_buckets: int,
    capacity: int,
    axis_name: str,
    valid: Optional[jax.Array] = None,
) -> ShuffleResult:
    """Send each local record to the device owning its bucket.

    Must be called inside ``shard_map``. ``num_buckets`` must be a multiple of
    the axis size; bucket b lives on device ``b // (num_buckets // D)``.

    Args:
      data: (n, *rec) local records.
      bucket_ids: (n,) int32 in [0, num_buckets); records with out-of-range
        ids (e.g. -1 for padding) are not sent.
      capacity: max records any source sends to any one destination.
      valid: optional (n,) bool marking real input records.
    """
    axis_size = jax.lax.axis_size(axis_name)
    if num_buckets % axis_size != 0:
        raise ValueError(f"num_buckets={num_buckets} not divisible by "
                         f"axis size {axis_size}")
    bpd = num_buckets // axis_size
    n = data.shape[0]

    ids = bucket_ids.astype(jnp.int32)
    ok = (ids >= 0) & (ids < num_buckets)
    if valid is not None:
        ok = ok & valid
    # invalid records get dest = axis_size (a virtual overflow destination)
    dest = jnp.where(ok, ids // bpd, axis_size)

    order, counts, offsets = _per_dest_layout(dest, axis_size + 1)
    sorted_data = jnp.take(data, order, axis=0)
    sorted_ids = jnp.take(ids, order, axis=0)

    # gather-based send-buffer build: slot (d, c) <- sorted row offsets[d]+c
    cap_iota = jnp.arange(capacity, dtype=jnp.int32)[None, :]           # (1, C)
    src_rows = offsets[:axis_size, None] + cap_iota                     # (D, C)
    in_range = cap_iota < counts[:axis_size, None]                      # (D, C)
    src_rows = jnp.clip(src_rows, 0, n - 1)
    send_data = jnp.take(sorted_data, src_rows.reshape(-1), axis=0)
    send_data = send_data.reshape((axis_size, capacity) + data.shape[1:])
    send_bucket = jnp.where(in_range, jnp.take(sorted_ids, src_rows), -1)
    send_src = jnp.where(in_range, jnp.take(order.astype(jnp.int32), src_rows), -1)
    send_valid = in_range

    dropped_local = jnp.sum(jnp.maximum(counts[:axis_size] - capacity, 0))
    dropped = jax.lax.psum(dropped_local, axis_name)

    recv_data = jax.lax.all_to_all(send_data, axis_name, split_axis=0,
                                   concat_axis=0, tiled=True)
    recv_bucket = jax.lax.all_to_all(send_bucket, axis_name, split_axis=0,
                                     concat_axis=0, tiled=True)
    recv_src = jax.lax.all_to_all(send_src, axis_name, split_axis=0,
                                  concat_axis=0, tiled=True)
    recv_valid = jax.lax.all_to_all(send_valid, axis_name, split_axis=0,
                                    concat_axis=0, tiled=True)
    return ShuffleResult(data=recv_data, valid=recv_valid, bucket=recv_bucket,
                         src_pos=recv_src, dropped=dropped)


def sphere_combine(
    processed: jax.Array,
    shuffle: ShuffleResult,
    axis_name: str,
    num_local_out: int,
) -> Tuple[jax.Array, jax.Array]:
    """Route per-record results back to their source devices and original rows
    (the inverse shuffle). ``processed`` must be (num_src, capacity, *out)
    aligned with ``shuffle.data``. Results for the same source row are summed
    (this is exactly the MoE top-k combine contract).

    Returns (combined (num_local_out, *out), hit_count (num_local_out,)).
    """
    back = jax.lax.all_to_all(processed, axis_name, split_axis=0,
                              concat_axis=0, tiled=True)
    back_valid = jax.lax.all_to_all(shuffle.valid, axis_name, split_axis=0,
                                    concat_axis=0, tiled=True)
    back_src = jax.lax.all_to_all(shuffle.src_pos, axis_name, split_axis=0,
                                  concat_axis=0, tiled=True)
    flat = back.reshape((-1,) + back.shape[2:])
    fvalid = back_valid.reshape(-1)
    fsrc = jnp.where(fvalid, back_src.reshape(-1), num_local_out)  # OOB drop
    out_shape = (num_local_out,) + back.shape[2:]
    zeros = jnp.zeros(out_shape, dtype=processed.dtype)
    masked = flat * fvalid.reshape((-1,) + (1,) * (flat.ndim - 1)).astype(flat.dtype)
    combined = zeros.at[fsrc].add(masked, mode="drop")
    hits = jnp.zeros((num_local_out,), jnp.int32).at[fsrc].add(
        fvalid.astype(jnp.int32), mode="drop")
    return combined, hits
