"""sphere_shuffle: the bucket shuffle (paper §3.2 "Shuffling input streams").

"the output can be sent to multiple locations ... a user-defined function can
specify a bucket ID (that refers to a destination file on either a local or
on a remote node) for each record in the output, and Sphere will send this
record to the specified destination."

TPU adaptation: a per-element network send does not exist; the SPMD-native
form is a **capacity-bounded all_to_all**. Buckets are assigned contiguously
to devices along a mesh axis; each device

1. runs the fused O(n) partition pass
   (:func:`repro.kernels.ops.partition_pack`): per-destination histogram +
   stable counting rank in ONE sweep over the destination vector (the
   Pallas ``partition`` kernel or its jnp oracle) — no sort anywhere on the
   send path (the historical implementation paid a full stable sort over
   every local record per send),
2. packs each destination's records into its send tile with the resulting
   slot map — a **gather** (TPU-friendly) driven by the ranks,
3. exchanges fixed-size (devices, capacity, ...) tiles with
   ``jax.lax.all_to_all``.

Capacity bounding is the paper's segment-size clamp (S_min/S_max, §3.5.1)
reborn: bounded skew in exchange for a static, compilable communication
pattern. Records beyond capacity are dropped and *counted* (``dropped``), the
same contract MoE capacity-factor dispatch uses — and indeed
:mod:`repro.models.moe` calls this exact function for expert dispatch.

Wide-area (two-level) form — paper §2.2: Sector "can manage data not only
within a data center, but also across geographically distributed data
centers". Over a 2-D ``(dc, node)`` mesh the flat all_to_all is wasteful on
the WAN: every device ships a fixed-capacity tile to each of the
``(dcs-1)*nodes`` remote devices, so each cross-DC link carries ``nodes``×
sparse tiles per destination DC. :func:`hierarchical_shuffle` instead runs

  Stage A  intra-DC all_to_all along the ``node`` axis that aggregates
           records by destination DC and pre-places them on the node-row of
           their final owner — after this, everything bound for DC ``g``
           sits densely packed on the staging nodes;
  Stage B  inter-DC all_to_all along the ``dc`` axis: one dense tile per
           remote DC per device crosses the WAN (1/nodes of the flat tile
           count);
  Stage C  fan-out to the final bucket owner inside the destination DC —
           free by construction, because stage A already staged each record
           on its owner's node-row, so arrival *is* delivery (consumers do
           the same local regroup-by-bucket they do after a flat shuffle).

Both paths share the fused partition/pack/capacity machinery
(:func:`repro.kernels.ops.partition_pack`) and are selected via
:class:`ShufflePlan`, which is built from a mesh or a
:class:`repro.sector.topology.Topology`.

All shuffle functions here run **inside** ``shard_map`` and communicate via
``axis_name`` collectives.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.kernels import ops as kops


@dataclasses.dataclass
class ShuffleResult:
    """Per-device local view of a completed shuffle.

    data:    (num_src, capacity, *rec) records received, grouped by source
             device (row s = records sent by source s).
    valid:   (num_src, capacity) bool — real record vs padding.
    bucket:  (num_src, capacity) int32 global bucket id of each record.
    src_pos: (num_src, capacity) int32 original local row index at the source
             (needed by :func:`sphere_combine` to route results back).
    dropped: () int32 — records dropped across the whole axis this step
             (capacity overflow), psum'd.
    """

    data: jax.Array
    valid: jax.Array
    bucket: jax.Array
    src_pos: jax.Array
    dropped: jax.Array


@dataclasses.dataclass
class HierShuffleResult(ShuffleResult):
    """Result of :func:`hierarchical_shuffle`.

    The public fields keep the :class:`ShuffleResult` contract with
    ``num_src = dcs``: row g holds the records relayed through DC g's staging
    node on this device's node-row; ``src_pos`` is still the record's
    original row at its *origin* node. The private fields thread the
    two-stage route back for :func:`hierarchical_combine`.
    """

    a_valid: jax.Array = None   # (nodes, cap_a) stage-A receive validity
    a_src: jax.Array = None     # (nodes, cap_a) stage-A origin rows
    b_pos: jax.Array = None     # (dcs, cap_b) row into stage-A recv layout


def _a2a(x: jax.Array, axis_name: str) -> jax.Array:
    return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0,
                              tiled=True)


def sphere_shuffle(
    data: jax.Array,
    bucket_ids: jax.Array,
    num_buckets: int,
    capacity: int,
    axis_name: str,
    valid: Optional[jax.Array] = None,
    use_pallas: bool = False,
) -> ShuffleResult:
    """Send each local record to the device owning its bucket (flat path).

    Must be called inside ``shard_map``. ``num_buckets`` must be a multiple of
    the axis size; bucket b lives on device ``b // (num_buckets // D)``.

    Args:
      data: (n, *rec) local records.
      bucket_ids: (n,) int32 in [0, num_buckets); records with out-of-range
        ids (e.g. -1 for padding) are not sent.
      capacity: max records any source sends to any one destination.
      valid: optional (n,) bool marking real input records.
      use_pallas: compute the per-destination histogram with the Pallas
        ``bucket_hist`` kernel instead of its jnp oracle.
    """
    axis_size = compat.axis_size(axis_name)
    if num_buckets % axis_size != 0:
        raise ValueError(f"num_buckets={num_buckets} not divisible by "
                         f"axis size {axis_size}")
    bpd = num_buckets // axis_size

    ids = bucket_ids.astype(jnp.int32)
    ok = (ids >= 0) & (ids < num_buckets)
    if valid is not None:
        ok = ok & valid
    # invalid records get dest = axis_size (a virtual overflow destination)
    dest = jnp.where(ok, ids // bpd, axis_size)

    (send_data, send_ids), in_range, origin, dropped_local = \
        kops.partition_pack([data, ids], dest, axis_size, capacity,
                            use_pallas=use_pallas)
    send_bucket = jnp.where(in_range, send_ids, -1)
    send_src = jnp.where(in_range, origin, -1)

    dropped = jax.lax.psum(dropped_local, axis_name)
    return ShuffleResult(
        data=_a2a(send_data, axis_name),
        valid=_a2a(in_range, axis_name),
        bucket=_a2a(send_bucket, axis_name),
        src_pos=_a2a(send_src, axis_name),
        dropped=dropped,
    )


def hierarchical_shuffle(
    data: jax.Array,
    bucket_ids: jax.Array,
    num_buckets: int,
    capacity_a: int,
    capacity_b: int,
    dc_axis: str,
    node_axis: str,
    valid: Optional[jax.Array] = None,
    use_pallas: bool = False,
) -> HierShuffleResult:
    """Two-level wide-area shuffle over a ``(dc, node)`` mesh (see module
    docstring). Must be called inside ``shard_map`` over both axes.

    Bucket ownership matches the flat layout on the row-major flattened
    device order: bucket b lives on global device ``b // bpd`` =
    ``(dc, node) = (b // bpd // nodes, b // bpd % nodes)``.

    Args:
      capacity_a: stage-A tile size — max records one node sends to one
        sibling node inside its DC (≈ n_local / nodes × capacity_factor).
      capacity_b: stage-B (WAN) tile size — max staged records one node
        sends to one remote DC (≈ n_local / dcs × capacity_factor).
    """
    dcs = compat.axis_size(dc_axis)
    nodes = compat.axis_size(node_axis)
    num_devices = dcs * nodes
    if num_buckets % num_devices != 0:
        raise ValueError(f"num_buckets={num_buckets} not divisible by "
                         f"mesh size {dcs}x{nodes}")
    bpd = num_buckets // num_devices

    ids = bucket_ids.astype(jnp.int32)
    ok = (ids >= 0) & (ids < num_buckets)
    if valid is not None:
        ok = ok & valid
    owner = jnp.where(ok, ids // bpd, 0)

    # Stage A: intra-DC exchange, keyed by the owner's node-row. This both
    # aggregates by destination DC (all records for DC g end up contiguous on
    # the staging nodes) and pre-places records so stage C is a no-op.
    dest_a = jnp.where(ok, owner % nodes, nodes)
    (ta_data, ta_ids), in_a, origin_a, drop_a = kops.partition_pack(
        [data, ids], dest_a, nodes, capacity_a, use_pallas=use_pallas)
    a_data = _a2a(ta_data, node_axis)
    a_ids = _a2a(jnp.where(in_a, ta_ids, -1), node_axis)
    a_src = _a2a(jnp.where(in_a, origin_a, -1), node_axis)
    a_valid = _a2a(in_a, node_axis)

    # Stage B: inter-DC exchange along the dc axis — the only WAN traffic.
    # One dense (capacity_b, *rec) tile per remote DC per device.
    n_staged = nodes * capacity_a
    f_data = a_data.reshape((n_staged,) + data.shape[1:])
    f_ids = a_ids.reshape(n_staged)
    f_src = a_src.reshape(n_staged)
    f_valid = a_valid.reshape(n_staged)
    pos_a = jnp.arange(n_staged, dtype=jnp.int32)
    owner_b = jnp.where(f_valid, f_ids, 0) // bpd
    dest_b = jnp.where(f_valid, owner_b // nodes, dcs)
    (tb_data, tb_ids, tb_src, tb_pos), in_b, _, drop_b = kops.partition_pack(
        [f_data, f_ids, f_src, pos_a], dest_b, dcs, capacity_b,
        use_pallas=use_pallas)

    recv_data = _a2a(tb_data, dc_axis)
    recv_bucket = _a2a(jnp.where(in_b, tb_ids, -1), dc_axis)
    recv_src = _a2a(jnp.where(in_b, tb_src, -1), dc_axis)
    recv_pos = _a2a(jnp.where(in_b, tb_pos, -1), dc_axis)
    recv_valid = _a2a(in_b, dc_axis)

    # Stage C (fan-out inside the destination DC) is free: stage A staged
    # every record on its final owner's node-row, so stage B delivered it.
    dropped = jax.lax.psum(jax.lax.psum(drop_a + drop_b, dc_axis), node_axis)
    return HierShuffleResult(
        data=recv_data, valid=recv_valid, bucket=recv_bucket,
        src_pos=recv_src, dropped=dropped,
        a_valid=a_valid, a_src=a_src, b_pos=recv_pos,
    )


def sphere_combine(
    processed: jax.Array,
    shuffle: ShuffleResult,
    axis_name: str,
    num_local_out: int,
) -> Tuple[jax.Array, jax.Array]:
    """Route per-record results back to their source devices and original rows
    (the inverse shuffle). ``processed`` must be (num_src, capacity, *out)
    aligned with ``shuffle.data``. Results for the same source row are summed
    (this is exactly the MoE top-k combine contract).

    Returns (combined (num_local_out, *out), hit_count (num_local_out,)).
    """
    back = _a2a(processed, axis_name)
    back_valid = _a2a(shuffle.valid, axis_name)
    back_src = _a2a(shuffle.src_pos, axis_name)
    flat = back.reshape((-1,) + back.shape[2:])
    fvalid = back_valid.reshape(-1)
    fsrc = jnp.where(fvalid, back_src.reshape(-1), num_local_out)  # OOB drop
    out_shape = (num_local_out,) + back.shape[2:]
    zeros = jnp.zeros(out_shape, dtype=processed.dtype)
    masked = flat * fvalid.reshape((-1,) + (1,) * (flat.ndim - 1)).astype(flat.dtype)
    combined = zeros.at[fsrc].add(masked, mode="drop")
    hits = jnp.zeros((num_local_out,), jnp.int32).at[fsrc].add(
        fvalid.astype(jnp.int32), mode="drop")
    return combined, hits


def hierarchical_combine(
    processed: jax.Array,
    shuffle: HierShuffleResult,
    dc_axis: str,
    node_axis: str,
    num_local_out: int,
) -> Tuple[jax.Array, jax.Array]:
    """Inverse of :func:`hierarchical_shuffle`: results ride the WAN back to
    their staging node (reverse stage B), are scattered into the stage-A
    receive layout, then :func:`sphere_combine` reverses stage A back to the
    origin rows. ``processed`` must be (dcs, capacity_b, *out) aligned with
    ``shuffle.data``."""
    back = _a2a(processed, dc_axis)
    back_valid = _a2a(shuffle.valid, dc_axis)
    back_pos = _a2a(shuffle.b_pos, dc_axis)
    out_tail = back.shape[2:]
    flat = back.reshape((-1,) + out_tail)
    fvalid = back_valid.reshape(-1)
    n_staged = shuffle.a_valid.size
    fpos = jnp.where(fvalid, back_pos.reshape(-1), n_staged)       # OOB drop
    masked = flat * fvalid.reshape((-1,) + (1,) * (flat.ndim - 1)).astype(flat.dtype)
    buf = jnp.zeros((n_staged + 1,) + out_tail, processed.dtype)
    buf = buf.at[fpos].add(masked, mode="drop")[:n_staged]
    buf = buf.reshape(shuffle.a_valid.shape + out_tail)
    # records that survived stage A but were dropped at stage B got no result
    # back — mask them out so hit_count keeps the flat-path contract
    # (hits == 0 for undelivered records)
    delivered = jnp.zeros((n_staged + 1,), bool).at[fpos].set(
        True, mode="drop")[:n_staged]
    a_valid = shuffle.a_valid & delivered.reshape(shuffle.a_valid.shape)
    synth = ShuffleResult(data=buf, valid=a_valid, bucket=None,
                          src_pos=shuffle.a_src, dropped=None)
    return sphere_combine(buf, synth, node_axis, num_local_out)


# -- topology-parameterized plan ---------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShufflePlan:
    """A compiled-shape shuffle strategy: which mesh axes to exchange over,
    with what per-tile capacities. One axis → flat all_to_all; two axes
    (dc, node) → the two-level hierarchical path.

    Built host-side (shapes must be static), used inside ``shard_map``.
    """

    num_buckets: int
    axes: Tuple[str, ...]        # ("data",) flat, or (dc_axis, node_axis)
    shape: Tuple[int, ...]       # mesh extent of each axis
    capacities: Tuple[int, ...]  # (capacity,) or (capacity_a, capacity_b)
    use_pallas: bool = False

    def __post_init__(self):
        if len(self.axes) not in (1, 2) or len(self.axes) != len(self.shape):
            raise ValueError(f"bad plan axes={self.axes} shape={self.shape}")
        if len(self.capacities) != len(self.axes):
            raise ValueError("need one capacity per shuffle stage")
        if self.num_buckets % self.num_devices != 0:
            raise ValueError(f"num_buckets={self.num_buckets} not divisible "
                             f"by {self.num_devices} devices")

    # -- static geometry ----------------------------------------------------
    @property
    def hierarchical(self) -> bool:
        return len(self.axes) == 2

    @property
    def num_devices(self) -> int:
        return math.prod(self.shape)

    @property
    def buckets_per_device(self) -> int:
        return self.num_buckets // self.num_devices

    @property
    def recv_slots(self) -> int:
        """Rows of the local receive buffer (= num_src * capacity)."""
        if self.hierarchical:
            return self.shape[0] * self.capacities[1]
        return self.shape[0] * self.capacities[0]

    # -- constructors -------------------------------------------------------
    @classmethod
    def for_mesh(cls, mesh, num_buckets: int, n_local: int,
                 capacity_factor: float = 2.0,
                 axes: Sequence[str] = ("data",),
                 use_pallas: bool = False) -> "ShufflePlan":
        """Capacities sized for ``n_local`` records/device at uniform load,
        padded by ``capacity_factor`` (the §3.5.1 segment clamp)."""
        axes = tuple(axes)
        shape = tuple(mesh.shape[a] for a in axes)
        if len(axes) == 1:
            caps = (int(n_local / shape[0] * capacity_factor) + 1,)
        else:
            dcs, nodes = shape
            caps = (int(n_local / nodes * capacity_factor) + 1,
                    int(n_local / dcs * capacity_factor) + 1)
        return cls(num_buckets, axes, shape, caps, use_pallas)

    @classmethod
    def from_topology(cls, topo, num_buckets: int, n_local: int,
                      capacity_factor: float = 2.0,
                      dc_axis: str = "dc", node_axis: str = "node",
                      use_pallas: bool = False) -> "ShufflePlan":
        """Map a :class:`repro.sector.topology.Topology` onto a plan: pods
        become the WAN axis, racks × nodes_per_rack the intra-DC axis. A
        single-pod topology degenerates to the flat path."""
        nodes = topo.racks * topo.nodes_per_rack
        if topo.pods == 1:
            caps = (int(n_local / nodes * capacity_factor) + 1,)
            return cls(num_buckets, (node_axis,), (nodes,), caps, use_pallas)
        caps = (int(n_local / nodes * capacity_factor) + 1,
                int(n_local / topo.pods * capacity_factor) + 1)
        return cls(num_buckets, (dc_axis, node_axis), (topo.pods, nodes),
                   caps, use_pallas)

    # -- shard_map-side ops -------------------------------------------------
    def device_index(self) -> jax.Array:
        """Global device index in bucket-ownership order (inside shard_map)."""
        if self.hierarchical:
            return (jax.lax.axis_index(self.axes[0]) * self.shape[1]
                    + jax.lax.axis_index(self.axes[1]))
        return jax.lax.axis_index(self.axes[0])

    def pmean_axes(self) -> Tuple[str, ...]:
        return self.axes

    def shuffle(self, data: jax.Array, bucket_ids: jax.Array,
                valid: Optional[jax.Array] = None) -> ShuffleResult:
        if self.hierarchical:
            return hierarchical_shuffle(
                data, bucket_ids, self.num_buckets,
                self.capacities[0], self.capacities[1],
                self.axes[0], self.axes[1], valid=valid,
                use_pallas=self.use_pallas)
        return sphere_shuffle(data, bucket_ids, self.num_buckets,
                              self.capacities[0], self.axes[0], valid=valid,
                              use_pallas=self.use_pallas)

    def combine(self, processed: jax.Array, result: ShuffleResult,
                num_local_out: int) -> Tuple[jax.Array, jax.Array]:
        if self.hierarchical:
            return hierarchical_combine(processed, result, self.axes[0],
                                        self.axes[1], num_local_out)
        return sphere_combine(processed, result, self.axes[0], num_local_out)

    # -- WAN cost model (host-side, used by benchmarks/wan_shuffle.py) ------
    def wan_profile(self, dcs: int, nodes: int, rec_bytes: int,
                    wire_segment_records: Optional[int] = None) -> dict:
        """Per-device, per-round cross-DC traffic of this plan mapped onto a
        ``dcs × nodes`` wide-area layout (flat plans flatten it row-major).

        wan_tiles: fixed-capacity tiles shipped across a DC boundary —
          flat: one per remote *device*; hierarchical: one per remote *DC*.
        wan_slot_bytes: bytes the all_to_all actually ships over the WAN
          (tiles × capacity slots, full even when half-empty).
        wan_wire_bytes: with transfers quantized to ``wire_segment_records``
          (the §3.5.1 S_min clamp — UDT needs big transfers to fill a long
          fat pipe), each tile rounds up to whole wire segments.
        """
        if self.num_devices != dcs * nodes:
            raise ValueError(f"plan covers {self.num_devices} devices, "
                             f"topology has {dcs * nodes}")
        if self.hierarchical:
            tiles, cap = dcs - 1, self.capacities[1]
        else:
            tiles, cap = (dcs - 1) * nodes, self.capacities[0]
        out = {"wan_tiles": tiles, "wan_slot_bytes": tiles * cap * rec_bytes}
        if wire_segment_records:
            q = wire_segment_records
            out["wan_wire_bytes"] = tiles * (-(-cap // q) * q) * rec_bytes
        return out
