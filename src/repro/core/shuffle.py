"""sphere_shuffle: the bucket shuffle (paper §3.2 "Shuffling input streams").

"the output can be sent to multiple locations ... a user-defined function can
specify a bucket ID (that refers to a destination file on either a local or
on a remote node) for each record in the output, and Sphere will send this
record to the specified destination."

TPU adaptation: a per-element network send does not exist; the SPMD-native
form is a **capacity-bounded all_to_all**. Buckets are assigned contiguously
to devices along a mesh axis; each device

1. frames every local record into one byte row (payload + the metadata this
   hop needs — :class:`repro.core.records.WireFrame`),
2. runs the fused O(n) partition pass
   (:func:`repro.kernels.ops.partition_pack`) over the framed rows —
   per-destination histogram + stable counting rank in ONE sweep, no sort
   anywhere on the send path,
3. exchanges exactly **one** fixed-size ``(devices, capacity+1, row_bytes)``
   uint8 tensor with ``jax.lax.all_to_all`` per hop — the Sector/UDT lesson
   (§2.3): one large framed transfer instead of several small ones. The
   historical implementation shipped four collectives per hop (``data``,
   ``valid``, ``bucket``, ``src_pos``); per-slot validity now travels as one
   int32 count per tile (real records occupy prefix slots by the partition's
   construction) and the remaining metadata rides in the same byte row as
   the payload.

With ``chunks > 1`` the local record stream splits into W chunks whose
per-chunk partition/pack interleaves with the previous chunk's
``all_to_all`` in one unrolled loop — XLA's latency-hiding scheduler can
overlap send-side compute with the exchange (the paper's overlap of SPE
processing with UDT data transfer, §2.3/§5), and peak send-buffer memory
drops by ~W×. Capacity splits across chunks (``ceil(capacity / W)`` slots
per destination per chunk), so each chunk's bins see W× the relative
traffic variance — size ``capacity_factor`` for the per-chunk clamp, not
the aggregate one, when running chunked under skew.

Capacity bounding is the paper's segment-size clamp (S_min/S_max, §3.5.1)
reborn: bounded skew in exchange for a static, compilable communication
pattern. Records beyond capacity are dropped and *counted* (``dropped``), the
same contract MoE capacity-factor dispatch uses — and indeed
:mod:`repro.models.moe` calls this exact function for expert dispatch.

Wide-area (two-level) form — paper §2.2: Sector "can manage data not only
within a data center, but also across geographically distributed data
centers". Over a 2-D ``(dc, node)`` mesh the flat all_to_all is wasteful on
the WAN: every device ships a fixed-capacity tile to each of the
``(dcs-1)*nodes`` remote devices, so each cross-DC link carries ``nodes``×
sparse tiles per destination DC. :func:`hierarchical_shuffle` instead runs

  Stage A  intra-DC all_to_all along the ``node`` axis that aggregates
           records by destination DC and pre-places them on the node-row of
           their final owner — after this, everything bound for DC ``g``
           sits densely packed on the staging nodes;
  Stage B  inter-DC all_to_all along the ``dc`` axis: one dense tile per
           remote DC per device crosses the WAN (1/nodes of the flat tile
           count);
  Stage C  fan-out to the final bucket owner inside the destination DC —
           free by construction, because stage A already staged each record
           on its owner's node-row, so arrival *is* delivery (consumers do
           the same local regroup-by-bucket they do after a flat shuffle).

Both paths share the fused partition/pack/capacity machinery
(:func:`repro.kernels.ops.partition_pack`) and are selected via
:class:`ShufflePlan`, which is built from a mesh or a
:class:`repro.sector.topology.Topology`.

Collective counts per call (``all_to_all``, at ``chunks=1``): flat shuffle
1 (was 4), hierarchical shuffle 2 (was 9), flat combine 1 (was 3),
hierarchical combine 2 (was 6). ``chunks=W`` multiplies the shuffle counts
by W, each collective carrying ~1/W of the bytes.

All shuffle functions here run **inside** ``shard_map`` and communicate via
``axis_name`` collectives.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.records import WireFrame
from repro.kernels import ops as kops

#: wire_meta modes: which per-record metadata rides in the frame rows.
#: "full"   — bucket + src (+ stage-A pos on the hierarchical path): the
#:            complete ShuffleResult contract incl. combine support.
#: "bucket" — bucket only: delivery grouping without a return trip.
#: "min"    — nothing beyond routing necessities (the hierarchical stage A
#:            still carries the bucket to route stage B): for consumers
#:            that recompute grouping from the records themselves (the
#:            dataflow executor does), the wire carries pure payload.
WIRE_META_MODES = ("full", "bucket", "min")

#: wire_meta mode -> int32 meta fields in the frame row, per hop kind. The
#: hierarchical stage B additionally carries the stage-A position so
#: :func:`hierarchical_combine` can invert the route. ``wan_profile`` prices
#: frames from these same tables, so the cost model cannot drift from the
#: bytes the hop actually ships.
_WIRE_META_FLAT = {"full": ("bucket", "src"), "bucket": ("bucket",),
                   "min": ()}
_WIRE_META_HIER = {"full": ("bucket", "src", "pos"), "bucket": ("bucket",),
                   "min": ()}


@dataclasses.dataclass
class ShuffleResult:
    """Per-device local view of a completed shuffle.

    data:    (num_src, slots, *rec) records received, grouped by source
             device (row s = records sent by source s). With ``chunks=W``,
             ``slots = W * ceil(capacity / W)`` (chunk receive tiles
             concatenated).
    valid:   (num_src, slots) bool — real record vs padding.
    bucket:  (num_src, slots) int32 global bucket id of each record, or
             ``None`` when the hop ran with ``wire_meta="min"``.
    src_pos: (num_src, slots) int32 original local row index at the source
             (needed by :func:`sphere_combine` to route results back), or
             ``None`` unless ``wire_meta="full"``.
    dropped: () int32 — records dropped across the whole axis this step
             (capacity overflow), psum'd.
    """

    data: jax.Array
    valid: jax.Array
    bucket: Optional[jax.Array]
    src_pos: Optional[jax.Array]
    dropped: jax.Array


@dataclasses.dataclass
class HierShuffleResult(ShuffleResult):
    """Result of :func:`hierarchical_shuffle`.

    The public fields keep the :class:`ShuffleResult` contract with
    ``num_src = dcs``: row g holds the records relayed through DC g's staging
    node on this device's node-row; ``src_pos`` is still the record's
    original row at its *origin* node. The private fields thread the
    two-stage route back for :func:`hierarchical_combine` (``None`` unless
    ``wire_meta="full"``).
    """

    a_valid: jax.Array = None   # (nodes, slots_a) stage-A receive validity
    a_src: jax.Array = None     # (nodes, slots_a) stage-A origin rows
    b_pos: jax.Array = None     # (dcs, slots_b) row into stage-A recv layout


def _a2a(x: jax.Array, axis_name: str) -> jax.Array:
    return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0,
                              tiled=True)


#: host-side hop-geometry sink. Shuffle hops run inside traced code, so a
#: per-run byte counter is impossible without shipping extra scalars; the
#: geometry, however, is static. Wrapping *lowering* in :func:`record_hops`
#: captures, exactly once per compile, every hop the program will execute
#: (wire bytes per device, chunk rounds, destinations) — the SPMD executor
#: stores the list with the compiled program and replays it per run.
_HOP_SINK: Optional[List[dict]] = None


@contextlib.contextmanager
def record_hops(sink: List[dict]):
    """Collect one dict per shuffle hop traced inside the ``with`` block."""
    global _HOP_SINK
    prev = _HOP_SINK
    _HOP_SINK = sink
    try:
        yield sink
    finally:
        _HOP_SINK = prev


def _wire_exchange(
    frame: WireFrame,
    payload: jax.Array,
    meta: Dict[str, jax.Array],
    dest: jax.Array,
    num_dest: int,
    capacity: int,
    chunks: int,
    axis_name: str,
    use_pallas: bool,
):
    """One shuffle hop: frame -> chunked partition/pack -> ONE all_to_all
    per chunk -> open. Returns (payload, valid, metas, dropped_local) with
    receive leading shape ``(num_dest, chunks * ceil(capacity / chunks))``.

    The chunk loop is intentionally unrolled (no ``lax.scan``): chunk k+1's
    partition/pack has no data dependency on chunk k's ``all_to_all``, so
    the XLA latency-hiding scheduler can overlap them.
    """
    framed = frame.frame_rows(payload, **meta)
    n = framed.shape[0]
    w = max(int(chunks), 1)
    cap_c = -(-capacity // w)
    if _HOP_SINK is not None:
        _HOP_SINK.append({
            "axis": axis_name, "num_dest": num_dest, "capacity": capacity,
            "chunks": w, "row_nbytes": frame.row_nbytes,
            "tile_nbytes": frame.tile_nbytes(cap_c),
            "wire_bytes_per_device": w * num_dest * frame.tile_nbytes(cap_c),
            "meta": list(frame.meta),
        })
    nc = -(-n // w) if n else 0
    if w * nc != n:  # pad the stream so chunks are equal-shaped; padding
        pad = w * nc - n  # rows route to the virtual overflow destination
        framed = jnp.concatenate(
            [framed, jnp.zeros((pad, frame.row_nbytes), jnp.uint8)])
        dest = jnp.concatenate(
            [dest, jnp.full((pad,), num_dest, jnp.int32)])
    parts = []
    dropped = jnp.zeros((), jnp.int32)
    for k in range(w):
        rows = jax.lax.slice_in_dim(framed, k * nc, (k + 1) * nc, axis=0)
        dk = jax.lax.slice_in_dim(dest, k * nc, (k + 1) * nc, axis=0)
        (tile,), in_rng, _, drop_k = kops.partition_pack(
            [rows], dk, num_dest, cap_c, use_pallas=use_pallas)
        # empty slots hold a duplicated row-0 gather — zero them so the wire
        # is deterministic and no local bytes leak across devices
        tile = tile * in_rng[..., None].astype(jnp.uint8)
        counts = jnp.sum(in_rng, axis=1, dtype=jnp.int32)
        parts.append(frame.open(_a2a(frame.seal(tile, counts), axis_name)))
        dropped = dropped + drop_k
    if w == 1:
        pay, val, metas = parts[0]
    else:
        pay = jnp.concatenate([p[0] for p in parts], axis=1)
        val = jnp.concatenate([p[1] for p in parts], axis=1)
        metas = {name: jnp.concatenate([p[2][name] for p in parts], axis=1)
                 for name in frame.meta}
    return pay, val, metas, dropped


def _masked(metas: Dict[str, jax.Array], name: str,
            valid: jax.Array) -> Optional[jax.Array]:
    if name not in metas:
        return None
    return jnp.where(valid, metas[name], -1)


def sphere_shuffle(
    data: jax.Array,
    bucket_ids: jax.Array,
    num_buckets: int,
    capacity: int,
    axis_name: str,
    valid: Optional[jax.Array] = None,
    use_pallas: bool = False,
    chunks: int = 1,
    wire_meta: str = "full",
) -> ShuffleResult:
    """Send each local record to the device owning its bucket (flat path).

    Must be called inside ``shard_map``. ``num_buckets`` must be a multiple of
    the axis size; bucket b lives on device ``b // (num_buckets // D)``.

    Args:
      data: (n, *rec) local records.
      bucket_ids: (n,) int32 in [0, num_buckets); records with out-of-range
        ids (e.g. -1 for padding) are not sent.
      capacity: max records any source sends to any one destination
        (split ~evenly across ``chunks``).
      valid: optional (n,) bool marking real input records.
      use_pallas: compute the per-destination partition with the Pallas
        kernel instead of its jnp oracle.
      chunks: pipeline depth W — the hop runs as W interleaved
        pack/exchange rounds of capacity ``ceil(capacity / W)`` each.
      wire_meta: which metadata to ship per record (see WIRE_META_MODES).
    """
    axis_size = compat.axis_size(axis_name)
    if num_buckets % axis_size != 0:
        raise ValueError(f"num_buckets={num_buckets} not divisible by "
                         f"axis size {axis_size}")
    if wire_meta not in WIRE_META_MODES:
        raise ValueError(f"wire_meta={wire_meta!r} not in {WIRE_META_MODES}")
    bpd = num_buckets // axis_size

    ids = bucket_ids.astype(jnp.int32)
    ok = (ids >= 0) & (ids < num_buckets)
    if valid is not None:
        ok = ok & valid
    # invalid records get dest = axis_size (a virtual overflow destination)
    dest = jnp.where(ok, ids // bpd, axis_size)

    names = _WIRE_META_FLAT[wire_meta]
    frame = WireFrame.for_payload(data, meta=names)
    meta = {}
    if "bucket" in names:
        meta["bucket"] = ids
    if "src" in names:
        meta["src"] = jnp.arange(data.shape[0], dtype=jnp.int32)
    pay, val, metas, drop_local = _wire_exchange(
        frame, data, meta, dest, axis_size, capacity, chunks, axis_name,
        use_pallas)
    return ShuffleResult(
        data=pay, valid=val,
        bucket=_masked(metas, "bucket", val),
        src_pos=_masked(metas, "src", val),
        dropped=jax.lax.psum(drop_local, axis_name),
    )


def hierarchical_shuffle(
    data: jax.Array,
    bucket_ids: jax.Array,
    num_buckets: int,
    capacity_a: int,
    capacity_b: int,
    dc_axis: str,
    node_axis: str,
    valid: Optional[jax.Array] = None,
    use_pallas: bool = False,
    chunks: int = 1,
    wire_meta: str = "full",
) -> HierShuffleResult:
    """Two-level wide-area shuffle over a ``(dc, node)`` mesh (see module
    docstring). Must be called inside ``shard_map`` over both axes.

    Bucket ownership matches the flat layout on the row-major flattened
    device order: bucket b lives on global device ``b // bpd`` =
    ``(dc, node) = (b // bpd // nodes, b // bpd % nodes)``.

    Args:
      capacity_a: stage-A tile size — max records one node sends to one
        sibling node inside its DC (≈ n_local / nodes × capacity_factor).
      capacity_b: stage-B (WAN) tile size — max staged records one node
        sends to one remote DC (≈ n_local / dcs × capacity_factor).
      chunks / wire_meta: as for :func:`sphere_shuffle` (both stages chunk;
        stage A always carries the bucket — stage B routes by it).
    """
    dcs = compat.axis_size(dc_axis)
    nodes = compat.axis_size(node_axis)
    num_devices = dcs * nodes
    if num_buckets % num_devices != 0:
        raise ValueError(f"num_buckets={num_buckets} not divisible by "
                         f"mesh size {dcs}x{nodes}")
    if wire_meta not in WIRE_META_MODES:
        raise ValueError(f"wire_meta={wire_meta!r} not in {WIRE_META_MODES}")
    bpd = num_buckets // num_devices

    ids = bucket_ids.astype(jnp.int32)
    ok = (ids >= 0) & (ids < num_buckets)
    if valid is not None:
        ok = ok & valid
    owner = jnp.where(ok, ids // bpd, 0)

    # Stage A: intra-DC exchange, keyed by the owner's node-row. This both
    # aggregates by destination DC (all records for DC g end up contiguous on
    # the staging nodes) and pre-places records so stage C is a no-op. The
    # bucket always rides along — stage B routes by it.
    names_b = _WIRE_META_HIER[wire_meta]
    names_a = ("bucket",) + (("src",) if "src" in names_b else ())
    frame_a = WireFrame.for_payload(data, meta=names_a)
    meta_a = {"bucket": ids}
    if "src" in names_a:
        meta_a["src"] = jnp.arange(data.shape[0], dtype=jnp.int32)
    dest_a = jnp.where(ok, owner % nodes, nodes)
    pay_a, val_a, metas_a, drop_a = _wire_exchange(
        frame_a, data, meta_a, dest_a, nodes, capacity_a, chunks, node_axis,
        use_pallas)

    # Stage B: inter-DC exchange along the dc axis — the only WAN traffic.
    # One dense (slots_b, row_bytes) tile per remote DC per device.
    n_staged = val_a.size
    f_pay = pay_a.reshape((n_staged,) + data.shape[1:])
    f_valid = val_a.reshape(-1)
    f_bucket = metas_a["bucket"].reshape(-1)
    owner_b = jnp.where(f_valid, f_bucket, 0) // bpd
    dest_b = jnp.where(f_valid, owner_b // nodes, dcs)
    frame_b = WireFrame.for_payload(data, meta=names_b)
    meta_b = {}
    if "bucket" in names_b:
        meta_b["bucket"] = f_bucket
    if "src" in names_b:
        meta_b["src"] = metas_a["src"].reshape(-1)
    if "pos" in names_b:
        meta_b["pos"] = jnp.arange(n_staged, dtype=jnp.int32)
    pay_b, val_b, metas_b, drop_b = _wire_exchange(
        frame_b, f_pay, meta_b, dest_b, dcs, capacity_b, chunks, dc_axis,
        use_pallas)

    # Stage C (fan-out inside the destination DC) is free: stage A staged
    # every record on its final owner's node-row, so stage B delivered it.
    dropped = jax.lax.psum(jax.lax.psum(drop_a + drop_b, dc_axis), node_axis)
    return HierShuffleResult(
        data=pay_b, valid=val_b,
        bucket=_masked(metas_b, "bucket", val_b),
        src_pos=_masked(metas_b, "src", val_b),
        dropped=dropped,
        a_valid=val_a, a_src=_masked(metas_a, "src", val_a),
        b_pos=_masked(metas_b, "pos", val_b),
    )


def sphere_combine(
    processed: jax.Array,
    shuffle: ShuffleResult,
    axis_name: str,
    num_local_out: int,
) -> Tuple[jax.Array, jax.Array]:
    """Route per-record results back to their source devices and original rows
    (the inverse shuffle) — ONE all_to_all: results, validity, and return
    rows travel in one explicit-valid wire frame (return-tile valid slots
    are not a prefix after drops, so validity is a per-row byte here).
    ``processed`` must be (num_src, slots, *out) aligned with
    ``shuffle.data``, and the shuffle must have run with
    ``wire_meta="full"``. Results for the same source row are summed (this
    is exactly the MoE top-k combine contract).

    Returns (combined (num_local_out, *out), hit_count (num_local_out,)).
    """
    if shuffle.src_pos is None:
        raise ValueError("combine needs a shuffle run with wire_meta='full' "
                         "(src_pos was not shipped)")
    num_src, cap = processed.shape[:2]
    out_tail = processed.shape[2:]
    flat_p = processed.reshape((num_src * cap,) + out_tail)
    frame = WireFrame.for_payload(flat_p, meta=("src",), explicit_valid=True)
    rows = frame.frame_rows(flat_p, valid=shuffle.valid.reshape(-1),
                            src=shuffle.src_pos.reshape(-1))
    back = _a2a(rows.reshape(num_src, cap, frame.row_nbytes), axis_name)
    pay, bvalid, metas = frame.open_rows(back)

    flat = pay.reshape((-1,) + out_tail)
    fvalid = bvalid.reshape(-1)
    fsrc = jnp.where(fvalid, metas["src"].reshape(-1), num_local_out)  # OOB
    out_shape = (num_local_out,) + out_tail
    zeros = jnp.zeros(out_shape, dtype=processed.dtype)
    masked = flat * fvalid.reshape((-1,) + (1,) * (flat.ndim - 1)).astype(flat.dtype)
    combined = zeros.at[fsrc].add(masked, mode="drop")
    hits = jnp.zeros((num_local_out,), jnp.int32).at[fsrc].add(
        fvalid.astype(jnp.int32), mode="drop")
    return combined, hits


def hierarchical_combine(
    processed: jax.Array,
    shuffle: HierShuffleResult,
    dc_axis: str,
    node_axis: str,
    num_local_out: int,
) -> Tuple[jax.Array, jax.Array]:
    """Inverse of :func:`hierarchical_shuffle`: results ride the WAN back to
    their staging node (reverse stage B, ONE all_to_all), are scattered into
    the stage-A receive layout, then :func:`sphere_combine` reverses stage A
    back to the origin rows (one more). ``processed`` must be
    (dcs, slots_b, *out) aligned with ``shuffle.data``."""
    if shuffle.b_pos is None:
        raise ValueError("combine needs a shuffle run with wire_meta='full' "
                         "(b_pos was not shipped)")
    num_src, cap = processed.shape[:2]
    out_tail = processed.shape[2:]
    flat_p = processed.reshape((num_src * cap,) + out_tail)
    frame = WireFrame.for_payload(flat_p, meta=("pos",), explicit_valid=True)
    rows = frame.frame_rows(flat_p, valid=shuffle.valid.reshape(-1),
                            pos=shuffle.b_pos.reshape(-1))
    back = _a2a(rows.reshape(num_src, cap, frame.row_nbytes), dc_axis)
    pay, bvalid, metas = frame.open_rows(back)

    flat = pay.reshape((-1,) + out_tail)
    fvalid = bvalid.reshape(-1)
    n_staged = shuffle.a_valid.size
    fpos = jnp.where(fvalid, metas["pos"].reshape(-1), n_staged)   # OOB drop
    masked = flat * fvalid.reshape((-1,) + (1,) * (flat.ndim - 1)).astype(flat.dtype)
    buf = jnp.zeros((n_staged + 1,) + out_tail, processed.dtype)
    buf = buf.at[fpos].add(masked, mode="drop")[:n_staged]
    buf = buf.reshape(shuffle.a_valid.shape + out_tail)
    # records that survived stage A but were dropped at stage B got no result
    # back — mask them out so hit_count keeps the flat-path contract
    # (hits == 0 for undelivered records)
    delivered = jnp.zeros((n_staged + 1,), bool).at[fpos].set(
        True, mode="drop")[:n_staged]
    a_valid = shuffle.a_valid & delivered.reshape(shuffle.a_valid.shape)
    synth = ShuffleResult(data=buf, valid=a_valid, bucket=None,
                          src_pos=shuffle.a_src,
                          dropped=jnp.zeros((), jnp.int32))
    return sphere_combine(buf, synth, node_axis, num_local_out)


# -- topology-parameterized plan ---------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShufflePlan:
    """A compiled-shape shuffle strategy: which mesh axes to exchange over,
    with what per-tile capacities. One axis → flat all_to_all; two axes
    (dc, node) → the two-level hierarchical path. ``chunks`` sets the
    pipeline depth W of every hop (see :func:`sphere_shuffle`).

    Built host-side (shapes must be static), used inside ``shard_map``.
    """

    num_buckets: int
    axes: Tuple[str, ...]        # ("data",) flat, or (dc_axis, node_axis)
    shape: Tuple[int, ...]       # mesh extent of each axis
    capacities: Tuple[int, ...]  # (capacity,) or (capacity_a, capacity_b)
    use_pallas: bool = False
    chunks: int = 1

    def __post_init__(self):
        if len(self.axes) not in (1, 2) or len(self.axes) != len(self.shape):
            raise ValueError(f"bad plan axes={self.axes} shape={self.shape}")
        if len(self.capacities) != len(self.axes):
            raise ValueError("need one capacity per shuffle stage")
        if self.num_buckets % self.num_devices != 0:
            raise ValueError(f"num_buckets={self.num_buckets} not divisible "
                             f"by {self.num_devices} devices")
        if self.chunks < 1:
            raise ValueError(f"chunks={self.chunks} must be >= 1")

    # -- static geometry ----------------------------------------------------
    @property
    def hierarchical(self) -> bool:
        return len(self.axes) == 2

    @property
    def num_devices(self) -> int:
        return math.prod(self.shape)

    @property
    def buckets_per_device(self) -> int:
        return self.num_buckets // self.num_devices

    def stage_slots(self, stage: int) -> int:
        """Receive slots per source for shuffle stage ``stage``:
        ``chunks * ceil(capacity / chunks)``."""
        cap = self.capacities[stage]
        return self.chunks * (-(-cap // self.chunks))

    @property
    def recv_slots(self) -> int:
        """Rows of the local receive buffer (= num_src * slots of the
        delivering stage)."""
        if self.hierarchical:
            return self.shape[0] * self.stage_slots(1)
        return self.shape[0] * self.stage_slots(0)

    # -- constructors -------------------------------------------------------
    @classmethod
    def for_mesh(cls, mesh, num_buckets: int, n_local: int,
                 capacity_factor: float = 2.0,
                 axes: Sequence[str] = ("data",),
                 use_pallas: bool = False,
                 chunks: int = 1) -> "ShufflePlan":
        """Capacities sized for ``n_local`` records/device at uniform load,
        padded by ``capacity_factor`` (the §3.5.1 segment clamp)."""
        axes = tuple(axes)
        shape = tuple(mesh.shape[a] for a in axes)
        if len(axes) == 1:
            caps = (int(n_local / shape[0] * capacity_factor) + 1,)
        else:
            dcs, nodes = shape
            caps = (int(n_local / nodes * capacity_factor) + 1,
                    int(n_local / dcs * capacity_factor) + 1)
        return cls(num_buckets, axes, shape, caps, use_pallas, chunks)

    @classmethod
    def from_topology(cls, topo, num_buckets: int, n_local: int,
                      capacity_factor: float = 2.0,
                      dc_axis: str = "dc", node_axis: str = "node",
                      use_pallas: bool = False,
                      chunks: int = 1) -> "ShufflePlan":
        """Map a :class:`repro.sector.topology.Topology` onto a plan: pods
        become the WAN axis, racks × nodes_per_rack the intra-DC axis. A
        single-pod topology degenerates to the flat path."""
        nodes = topo.racks * topo.nodes_per_rack
        if topo.pods == 1:
            caps = (int(n_local / nodes * capacity_factor) + 1,)
            return cls(num_buckets, (node_axis,), (nodes,), caps, use_pallas,
                       chunks)
        caps = (int(n_local / nodes * capacity_factor) + 1,
                int(n_local / topo.pods * capacity_factor) + 1)
        return cls(num_buckets, (dc_axis, node_axis), (topo.pods, nodes),
                   caps, use_pallas, chunks)

    # -- shard_map-side ops -------------------------------------------------
    def device_index(self) -> jax.Array:
        """Global device index in bucket-ownership order (inside shard_map)."""
        if self.hierarchical:
            return (jax.lax.axis_index(self.axes[0]) * self.shape[1]
                    + jax.lax.axis_index(self.axes[1]))
        return jax.lax.axis_index(self.axes[0])

    def pmean_axes(self) -> Tuple[str, ...]:
        return self.axes

    def shuffle(self, data: jax.Array, bucket_ids: jax.Array,
                valid: Optional[jax.Array] = None,
                wire_meta: str = "full") -> ShuffleResult:
        if self.hierarchical:
            return hierarchical_shuffle(
                data, bucket_ids, self.num_buckets,
                self.capacities[0], self.capacities[1],
                self.axes[0], self.axes[1], valid=valid,
                use_pallas=self.use_pallas, chunks=self.chunks,
                wire_meta=wire_meta)
        return sphere_shuffle(data, bucket_ids, self.num_buckets,
                              self.capacities[0], self.axes[0], valid=valid,
                              use_pallas=self.use_pallas, chunks=self.chunks,
                              wire_meta=wire_meta)

    def combine(self, processed: jax.Array, result: ShuffleResult,
                num_local_out: int) -> Tuple[jax.Array, jax.Array]:
        if self.hierarchical:
            return hierarchical_combine(processed, result, self.axes[0],
                                        self.axes[1], num_local_out)
        return sphere_combine(processed, result, self.axes[0], num_local_out)

    # -- WAN cost model (host-side, used by benchmarks/wan_shuffle.py) ------
    def wan_profile(self, dcs: int, nodes: int, rec_bytes: int,
                    wire_segment_records: Optional[int] = None,
                    wire_meta: str = "full") -> dict:
        """Per-device, per-round cross-DC traffic of this plan mapped onto a
        ``dcs × nodes`` wide-area layout (flat plans flatten it row-major).

        wan_tiles: fixed-capacity tiles shipped across a DC boundary —
          flat: one per remote *device*; hierarchical: one per remote *DC*.
        wan_rounds: chunked exchange rounds (= ``chunks``); each WAN tile is
          shipped once per round at 1/rounds capacity.
        wan_slot_bytes: payload bytes the all_to_all ships over the WAN
          (tiles × capacity slots × rec_bytes, full even when half-empty).
        wan_frame_bytes: bytes of the fused one-tensor wire layout actually
          shipped — framed rows (payload + the ``wire_meta`` metadata ints)
          plus one count-header row per tile per round.
        wan_legacy_bytes: the retired multi-collective layout — separate
          capacity-padded data/valid/bucket/src((+pos)) tensors per hop.
        wan_wire_bytes: with transfers quantized to ``wire_segment_records``
          (the §3.5.1 S_min clamp — UDT needs big transfers to fill a long
          fat pipe), each tile's payload rounds up to whole wire segments.
        """
        if self.num_devices != dcs * nodes:
            raise ValueError(f"plan covers {self.num_devices} devices, "
                             f"topology has {dcs * nodes}")
        if wire_meta not in WIRE_META_MODES:
            raise ValueError(f"wire_meta={wire_meta!r} not in "
                             f"{WIRE_META_MODES}")
        if self.hierarchical:
            tiles, cap = dcs - 1, self.capacities[1]
            meta = _WIRE_META_HIER[wire_meta]
            legacy_tensors = rec_bytes + 1 + 4 + 4 + 4  # +valid,bucket,src,pos
        else:
            tiles, cap = (dcs - 1) * nodes, self.capacities[0]
            meta = _WIRE_META_FLAT[wire_meta]
            legacy_tensors = rec_bytes + 1 + 4 + 4      # +valid,bucket,src
        # the exact frame the WAN hop ships (rec_bytes-wide payload rows)
        frame = WireFrame("uint8", (rec_bytes,), meta=meta)
        w = self.chunks
        cap_c = -(-cap // w)
        frame_rows = cap_c + 1                          # + count header row
        out = {
            "wan_tiles": tiles,
            "wan_rounds": w,
            "wan_slot_bytes": tiles * cap * rec_bytes,
            "wan_frame_bytes": tiles * w * frame.tile_nbytes(cap_c),
            "wan_legacy_bytes": tiles * cap * legacy_tensors,
        }
        if wire_segment_records:
            q = wire_segment_records
            out["wan_wire_bytes"] = tiles * (-(-cap // q) * q) * rec_bytes
            out["wan_frame_wire_bytes"] = (
                tiles * w * (-(-frame_rows // q) * q) * frame.row_nbytes)
        return out
