"""MapReduce as a special case of Sphere (paper §3.6).

"A MapReduce map process can be expressed directly by a Sphere process that
writes the output stream to local storage. A MapReduce reduce process can be
simulated by the hashing/bucket process of Sphere."

``map_reduce`` composes exactly that: a Map UDF applied per segment
(:func:`sphere_map` semantics, inlined), a hash bucket shuffle
(:func:`sphere_shuffle`), and a Reduce UDF applied per received bucket. The
inverted-index example from the paper lives in ``examples/inverted_index.py``
on top of this.
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map

from repro.core.shuffle import sphere_shuffle


def default_hash(keys: jax.Array, num_buckets: int) -> jax.Array:
    """Multiplicative hash -> bucket id (the paper's simple first-letter
    bucketing generalized)."""
    h = (keys.astype(jnp.uint32) * jnp.uint32(2654435761)) >> jnp.uint32(16)
    return (h % jnp.uint32(num_buckets)).astype(jnp.int32)


def map_reduce(
    map_udf: Callable[[jax.Array], Tuple[jax.Array, jax.Array]],
    reduce_udf: Callable[[jax.Array, jax.Array, jax.Array], Tuple[jax.Array, jax.Array]],
    data: jax.Array,
    mesh: Mesh,
    axis: str = "data",
    num_buckets: Optional[int] = None,
    capacity_factor: float = 4.0,
    hash_fn: Callable = default_hash,
):
    """Run Map -> bucket shuffle -> Reduce over ``data`` sharded on ``axis``.

    map_udf:    local_segment -> (keys (m,), values (m,)) emitted pairs
                (m static; emit-nothing is encoded by key = -1).
    reduce_udf: (keys, values, valid) for one device's received bucket
                contents -> (out_keys, out_values) local reduced pairs.
    Returns (keys, values, valid) sharded over ``axis``.
    """
    axis_size = mesh.shape[axis]
    nb = num_buckets or axis_size

    def udf(seg):
        seg = seg.reshape((-1,) + seg.shape[2:]) if seg.ndim > 1 else seg
        keys, values = map_udf(seg)
        bucket = hash_fn(keys, nb)
        bucket = jnp.where(keys < 0, -1, bucket)  # -1 = emit nothing
        rec = jnp.stack([keys.astype(jnp.int32), values.astype(jnp.int32)], 1)
        m = keys.shape[0]
        capacity = int(m / axis_size * capacity_factor) + 1
        res = sphere_shuffle(rec, bucket, nb, capacity, axis)
        rk = res.data[..., 0].reshape(-1)
        rv = res.data[..., 1].reshape(-1)
        valid = res.valid.reshape(-1)
        out_k, out_v = reduce_udf(rk, rv, valid)
        out_valid = out_k >= 0
        return out_k, out_v, out_valid, res.dropped

    out_k, out_v, out_valid, dropped = shard_map(
        udf, mesh=mesh, in_specs=(P(axis),),
        out_specs=(P(axis), P(axis), P(axis), P()),
        check_vma=False,
    )(data)
    return out_k, out_v, out_valid, dropped


def reduce_by_key_sum(keys: jax.Array, values: jax.Array, valid: jax.Array,
                      max_unique: Optional[int] = None):
    """Built-in Reduce UDF: sum values per key (wordcount/inverted-index
    aggregation). Sorts by key, then segment-sums runs of equal keys.

    Returns (unique_keys, sums) padded with key=-1 rows up to the input size
    (or ``max_unique``)."""
    n = keys.shape[0]
    cap = max_unique or n
    sentinel = jnp.iinfo(jnp.int32).max
    skey = jnp.where(valid, keys, sentinel)
    order = jnp.argsort(skey, stable=True)
    sk = jnp.take(skey, order)
    sv = jnp.take(jnp.where(valid, values, 0), order)
    is_head = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    seg_id = jnp.cumsum(is_head.astype(jnp.int32)) - 1        # run index per row
    run_sum = jnp.zeros((n,), sv.dtype).at[seg_id].add(sv)    # total per run
    # scatter each run's head (key, total) to slot = run index
    slot = jnp.where(is_head & (sk != sentinel), seg_id, cap)  # OOB -> dropped
    out_k = jnp.full((cap,), -1, jnp.int32).at[slot].set(sk, mode="drop")
    out_v = jnp.zeros((cap,), sv.dtype).at[slot].set(
        jnp.take(run_sum, seg_id), mode="drop")
    return out_k, out_v
