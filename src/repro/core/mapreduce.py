"""MapReduce as a special case of Sphere (paper §3.6).

"A MapReduce map process can be expressed directly by a Sphere process that
writes the output stream to local storage. A MapReduce reduce process can be
simulated by the hashing/bucket process of Sphere."

``map_reduce`` is now a **deprecated thin shim** over the unified dataflow
API (:mod:`repro.sphere.dataflow`) — prefer building the pipeline directly::

    df = (Dataflow.source()
          .map(lambda r: {"key": ..., "value": ...})
          .shuffle(by=lambda r: default_hash(r["key"], nb), num_buckets=nb)
          .reduce(...))
    SPMDExecutor(mesh).run(df, data)

Unlike the historical entry point, the dataflow path carries records through
the shuffle via :class:`repro.core.records.RecordCodec`, so keys and values
keep their dtypes (the old code silently cast both to int32; float64 values
now round-trip losslessly). The inverted-index example from the paper lives
in ``examples/inverted_index.py`` on top of the dataflow API, runnable on
both the SPMD and the host Sector/SPE executor.
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.kernels import ops as kops


def default_hash(keys: jax.Array, num_buckets: int) -> jax.Array:
    """Multiplicative hash -> bucket id (the paper's simple first-letter
    bucketing generalized)."""
    h = (keys.astype(jnp.uint32) * jnp.uint32(2654435761)) >> jnp.uint32(16)
    return (h % jnp.uint32(num_buckets)).astype(jnp.int32)


def map_reduce(
    map_udf: Callable[[jax.Array], Tuple[jax.Array, jax.Array]],
    reduce_udf: Callable[[jax.Array, jax.Array, jax.Array], Tuple[jax.Array, jax.Array]],
    data: jax.Array,
    mesh: Mesh,
    axis: str = "data",
    num_buckets: Optional[int] = None,
    capacity_factor: float = 4.0,
    hash_fn: Callable = default_hash,
):
    """Run Map -> bucket shuffle -> Reduce over ``data`` sharded on ``axis``.

    .. deprecated:: use :class:`repro.sphere.dataflow.Dataflow` directly.

    map_udf:    local_segment -> (keys (m,), values (m,)) emitted pairs
                (m static; emit-nothing is encoded by key = -1).
    reduce_udf: (keys, values, valid) for one device's received bucket
                contents -> (out_keys, out_values) or
                (out_keys, out_values, dropped) local reduced pairs.
    Returns (keys, values, valid, dropped) sharded over ``axis``; ``dropped``
    counts shuffle capacity overflow plus any drops the reduce UDF reports
    (e.g. :func:`reduce_by_key_sum` truncation).
    """
    from repro.sphere.dataflow import Dataflow, SPMDExecutor

    nb = num_buckets or mesh.shape[axis]

    def emit(seg):
        seg = seg.reshape((-1,) + seg.shape[2:]) if seg.ndim > 1 else seg
        keys, values = map_udf(seg)
        return {"key": keys, "value": values}

    def bucket_of(rec):
        # key < 0 = emit nothing (never sent, never counted as dropped)
        return jnp.where(rec["key"] < 0, -1, hash_fn(rec["key"], nb))

    def reduce_stage(rec, valid):
        out = reduce_udf(rec["key"], rec["value"], valid)
        out_k, out_v = out[0], out[1]
        red_dropped = out[2] if len(out) > 2 else None
        if red_dropped is None:
            return {"key": out_k, "value": out_v}, out_k >= 0
        return {"key": out_k, "value": out_v}, out_k >= 0, red_dropped

    df = (Dataflow.source()
          .map(emit)
          .shuffle(by=bucket_of, num_buckets=nb,
                   capacity_factor=capacity_factor)
          .reduce(reduce_stage))
    res = SPMDExecutor(mesh, axes=(axis,)).run(df, data)
    return res.records["key"], res.records["value"], res.valid, res.dropped


def reduce_by_key_sum(keys: jax.Array, values: jax.Array, valid: jax.Array,
                      max_unique: Optional[int] = None,
                      use_pallas=kops._UNSET,
                      algo: Optional[str] = None):
    """Built-in Reduce UDF: sum values per key (wordcount/inverted-index
    aggregation). Groups by key — a single-segment run of the same
    sort machinery the stage-2 segmented sort uses
    (:func:`repro.kernels.ops.sort_kv_segments`, dispatched through the
    backend-aware autotuner; ``algo`` pins ``"bitonic"``/``"radix"``/
    ``"oracle"``) — then segment-sums runs of equal keys. Summation is
    order-insensitive, so even the unstable bitonic network's tie order
    does not change results. ``use_pallas`` is deprecated (``True`` →
    ``algo="bitonic"``, ``False`` → ``algo="oracle"``).

    Returns (unique_keys, sums, dropped) with key=-1 padding rows up to the
    input size (or ``max_unique``). ``dropped`` counts the distinct keys that
    did not fit in ``max_unique`` — truncation is no longer silent; it is
    reported the same way ``sphere_shuffle.dropped`` reports capacity
    overflow, and :func:`map_reduce` folds it into its ``dropped`` total.
    Values keep their dtype (sums of float64 values are float64)."""
    algo = kops._legacy_algo(use_pallas, algo, "reduce_by_key_sum")
    n = keys.shape[0]
    cap = max_unique or n
    sentinel = int(kops.pad_sentinel(jnp.int32))
    skey = jnp.where(valid, keys, sentinel)
    pos = jnp.arange(n, dtype=jnp.int32)
    sk_row, order_row = kops.sort_kv_segments(skey[None, :], pos[None, :],
                                              algo=algo)
    sk, order = sk_row[0], order_row[0]
    sv = jnp.take(jnp.where(valid, values, jnp.zeros_like(values)), order)
    is_head = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    seg_id = jnp.cumsum(is_head.astype(jnp.int32)) - 1        # run index per row
    run_sum = jnp.zeros((n,), sv.dtype).at[seg_id].add(sv)    # total per run
    # scatter each run's head (key, total) to slot = run index
    real_head = is_head & (sk != sentinel)
    slot = jnp.where(real_head, seg_id, cap)                  # OOB -> dropped
    out_k = jnp.full((cap,), -1, jnp.int32).at[slot].set(sk, mode="drop")
    out_v = jnp.zeros((cap,), sv.dtype).at[slot].set(
        jnp.take(run_sum, seg_id), mode="drop")
    dropped = jnp.sum((real_head & (seg_id >= cap)).astype(jnp.int32))
    return out_k, out_v, dropped
