"""sphere_map: apply a User-Defined Function to every segment (paper §3.2-3.3).

"each element in the input data array is processed independently by the same
processing function using multiple computing units" — the stream-processing
paradigm. A device plays the SPE role; ``shard_map`` gives the UDF its local
segment; the traced jaxpr plays the role of the ``.so`` UDF library the paper
ships to each SPE.

This is the low-level SPMD primitive **under** the unified dataflow layer:
multi-stage programs (map -> shuffle -> reduce/sort) should be written as a
:class:`repro.sphere.dataflow.Dataflow`, which runs unmodified on either the
compiled SPMD executor or the host Sector/SPE executor. ``sphere_map``
remains the direct escape hatch for one-shot segment UDFs with arbitrary
(non-record) outputs.

Supports the paper's extensions:
- multiple input streams (``sphere_map(f, [a, b], ...)`` == ``f(A[], B[])``);
- record-wise, group-wise or whole-segment UDFs (the UDF sees the entire
  local segment and may reduce/expand it);
- bucket output via :func:`repro.core.shuffle.sphere_shuffle` composed inside
  the UDF (see :mod:`repro.sphere.dataflow` for the canonical use).
"""

from __future__ import annotations

from typing import Callable, Sequence, Union

import jax
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map

from repro.core.stream import SphereStream

Arrays = Union[jax.Array, Sequence[jax.Array]]


def sphere_map(
    udf: Callable,
    streams: Union[SphereStream, Sequence[SphereStream]],
    mesh: Mesh,
    axis: str = "data",
    out_axis: str | None = "data",
    check_vma: bool = False,
):
    """Run ``udf`` on each segment of the input stream(s).

    Args:
      udf: function of one local segment per input stream -> local output
        (an array or pytree of arrays). Runs per-device.
      streams: one or more SphereStreams sharded along ``axis``.
      mesh: the device mesh.
      axis: mesh axis name the stream is sharded over.
      out_axis: mesh axis of the output sharding (None = replicated output,
        e.g. for segment-level reductions followed by a psum inside the UDF).
    Returns:
      SphereStream wrapping the UDF output.
    """
    single = isinstance(streams, SphereStream)
    stream_list = [streams] if single else list(streams)
    in_specs = tuple(P(axis) for _ in stream_list)
    out_spec = P(out_axis) if out_axis is not None else P()

    mapped = shard_map(
        udf, mesh=mesh, in_specs=in_specs, out_specs=out_spec,
        check_vma=check_vma,
    )
    out = mapped(*[s.data for s in stream_list])
    template = stream_list[0]
    # a record-wise UDF (leading dim preserved, same sharding) keeps the
    # input's validity mask; any reshaping UDF invalidates it
    valid = None
    if out_axis == axis and template.valid is not None:
        leaves = jax.tree.leaves(out)
        if leaves and all(
                l.ndim and l.shape[0] == template.num_records for l in leaves):
            valid = template.valid
    return template.with_data(out, valid)
