"""Jaxpr introspection: count primitives in a traced program.

The one-wire-tensor shuffle's acceptance contract is structural — exactly
one ``all_to_all`` per flat hop, two per hierarchical hop (times ``chunks``)
— so the tests and the CI smoke step assert it directly on the jaxpr rather
than trusting byte accounting. Works on any traceable callable, including
``shard_map``-wrapped shuffles (the collectives sit inside the shard_map
sub-jaxpr; the walk recurses through every sub-jaxpr it finds in equation
params: pjit bodies, cond branches, scan/while carries, shard_map, ...).
"""

from __future__ import annotations

from typing import Callable, Dict

import jax

#: primitives that move bytes between devices.
COLLECTIVE_PRIMITIVES = (
    "all_to_all", "all_gather", "psum", "ppermute", "reduce_scatter",
    "pmax", "pmin",
)


def _sub_jaxprs(eqn):
    for v in eqn.params.values():
        for x in (v if isinstance(v, (tuple, list)) else (v,)):
            if hasattr(x, "jaxpr") and hasattr(x, "consts"):   # ClosedJaxpr
                yield x.jaxpr
            elif hasattr(x, "eqns"):                           # Jaxpr
                yield x


def primitive_counts(fn: Callable, *args, **kwargs) -> Dict[str, int]:
    """Trace ``fn(*args, **kwargs)`` and count every primitive, recursing
    through sub-jaxprs. Returns ``{primitive_name: count}``."""
    jaxpr = jax.make_jaxpr(fn)(*args, **kwargs).jaxpr
    acc: Dict[str, int] = {}

    def walk(j):
        for eqn in j.eqns:
            acc[eqn.primitive.name] = acc.get(eqn.primitive.name, 0) + 1
            for sub in _sub_jaxprs(eqn):
                walk(sub)

    walk(jaxpr)
    return acc


def collective_counts(fn: Callable, *args, **kwargs) -> Dict[str, int]:
    """Like :func:`primitive_counts`, filtered to cross-device collectives
    (every name in :data:`COLLECTIVE_PRIMITIVES`, 0 when absent)."""
    counts = primitive_counts(fn, *args, **kwargs)
    return {name: counts.get(name, 0) for name in COLLECTIVE_PRIMITIVES}
