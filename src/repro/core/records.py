"""RecordCodec: fixed-shape pytree records <-> flat byte rows.

The paper's Sphere records are opaque byte strings (a data file plus its
``.idx`` offset index, §3.2); the repo's shuffles want exactly one
``(n, *rec)`` array per exchange. Historically that forced every workload
into int32 pairs (``map_reduce`` silently cast keys *and* values). The codec
closes the gap: a **record** is any fixed-shape pytree of arrays sharing a
leading record axis, and the codec packs each record into a fixed-width byte
row — the same layout in two worlds:

- ``pack`` / ``unpack``: jax ops (``lax.bitcast_convert_type``), traceable
  inside ``shard_map``/``jit`` — this is what lets
  :class:`repro.sphere.dataflow.SPMDExecutor` ship arbitrary-dtype records
  through the capacity-bounded ``all_to_all`` shuffle.
- ``encode`` / ``decode``: the numpy mirror with the identical byte layout —
  this is what the host executor writes to Sector bucket files and what an
  SPE decodes before invoking a UDF.

Byte-for-byte equality of the two paths (asserted in
``tests/test_dataflow.py``) is what makes "write once, run in-XLA or on
Sector" literal: a bucket file written by one executor is readable by the
other. Layout is native-endian (little-endian on every supported platform);
bools travel as one byte.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class RecordCodec:
    """Schema of one record: a pytree structure plus per-leaf dtype/shape.

    ``shapes`` are the per-record *trailing* shapes — the leading record axis
    is implicit. Construct with :meth:`from_example` (from arrays carrying a
    leading record axis) or :meth:`from_fields` (from a {name: (dtype,
    shape)} mapping, which fixes the field order by name).
    """

    treedef: Any
    dtypes: Tuple[str, ...]
    shapes: Tuple[Tuple[int, ...], ...]
    #: byte-layout order: position i of a packed row holds flattened leaf
    #: ``layout[i]``. Lets the on-disk field order differ from the pytree
    #: flatten order (dict pytrees always flatten in sorted-key order).
    layout: Tuple[int, ...] = ()

    def __post_init__(self):
        if len(self.dtypes) != len(self.shapes):
            raise ValueError("one dtype per field required")
        if not self.layout:
            object.__setattr__(self, "layout",
                               tuple(range(len(self.dtypes))))
        if sorted(self.layout) != list(range(len(self.dtypes))):
            raise ValueError(f"layout {self.layout} is not a permutation of "
                             f"the {len(self.dtypes)} fields")

    # -- geometry -------------------------------------------------------------
    @property
    def field_nbytes(self) -> Tuple[int, ...]:
        return tuple(
            int(np.dtype(dt).itemsize * np.prod(s, dtype=np.int64))
            for dt, s in zip(self.dtypes, self.shapes))

    @property
    def nbytes(self) -> int:
        """Packed bytes per record (= ``record_bytes`` for Sector files)."""
        return sum(self.field_nbytes)

    # -- constructors ---------------------------------------------------------
    @classmethod
    def from_example(cls, records: Any) -> "RecordCodec":
        """Infer the schema from a records pytree (leading axis = records).

        Works on concrete arrays and on tracers (shape/dtype only), so the
        SPMD executor can derive shuffle codecs mid-trace.
        """
        leaves, treedef = jax.tree.flatten(records)
        if not leaves:
            raise ValueError("records pytree has no array leaves")
        n = leaves[0].shape[0] if leaves[0].ndim else None
        for l in leaves:
            if l.ndim == 0 or l.shape[0] != n:
                raise ValueError("all record fields need the same leading "
                                 f"record axis; got shapes "
                                 f"{[tuple(x.shape) for x in leaves]}")
        return cls(treedef=treedef,
                   dtypes=tuple(str(np.dtype(l.dtype)) for l in leaves),
                   shapes=tuple(tuple(l.shape[1:]) for l in leaves))

    @classmethod
    def from_fields(cls, fields: dict) -> "RecordCodec":
        """Build from ``{name: dtype}`` or ``{name: (dtype, trailing_shape)}``
        — records are then dicts of arrays. The **insertion order** of
        ``fields`` is the byte layout (how the raw record file is laid out),
        even though dict pytrees flatten in sorted-key order."""
        spec = {}
        for name, f in fields.items():
            dt, shape = f if isinstance(f, tuple) else (f, ())
            spec[name] = (str(np.dtype(dt)), tuple(shape))
        treedef = jax.tree.structure({k: 0 for k in spec})
        names = sorted(spec)  # dict pytrees flatten in sorted key order
        byte_order = list(fields)
        return cls(treedef=treedef,
                   dtypes=tuple(spec[k][0] for k in names),
                   shapes=tuple(spec[k][1] for k in names),
                   layout=tuple(names.index(k) for k in byte_order))

    # -- jax path (traceable) -------------------------------------------------
    def pack(self, records: Any) -> jax.Array:
        """(pytree with leading axis n) -> (n, nbytes) uint8."""
        leaves = self._check(records)
        self._check_x64()
        n = leaves[0].shape[0]
        nbytes = self.field_nbytes
        cols = []
        for i in self.layout:
            x = jnp.asarray(leaves[i])
            if x.dtype == jnp.bool_:
                x = x.astype(jnp.uint8)
            b = jax.lax.bitcast_convert_type(x, jnp.uint8)
            cols.append(b.reshape(n, nbytes[i]))
        return jnp.concatenate(cols, axis=1)

    def unpack(self, packed: jax.Array) -> Any:
        """(..., nbytes) uint8 -> pytree with leading axes ``...``.

        Accepts any number of leading dims (e.g. the ``(num_src, capacity)``
        layout of a shuffle receive buffer)."""
        if packed.shape[-1] != self.nbytes:
            raise ValueError(f"packed rows are {packed.shape[-1]} bytes, "
                             f"codec expects {self.nbytes}")
        self._check_x64()
        lead = packed.shape[:-1]
        nbytes = self.field_nbytes
        leaves, off = [None] * len(self.dtypes), 0
        for i in self.layout:
            dtype, shape, nb = np.dtype(self.dtypes[i]), self.shapes[i], nbytes[i]
            piece = jax.lax.slice_in_dim(packed, off, off + nb, axis=-1)
            if dtype.itemsize > 1:
                piece = piece.reshape(lead + shape + (dtype.itemsize,))
                leaf = jax.lax.bitcast_convert_type(piece, dtype)
            else:
                piece = piece.reshape(lead + shape)
                leaf = (piece != 0 if dtype == np.bool_
                        else jax.lax.bitcast_convert_type(piece, dtype))
            leaves[i] = leaf
            off += nb
        return jax.tree.unflatten(self.treedef, leaves)

    # -- numpy path (host executor / Sector files) ----------------------------
    def encode(self, records: Any) -> np.ndarray:
        """(pytree with leading axis n) -> (n, nbytes) uint8 ndarray, byte-
        identical to :meth:`pack` of the same records."""
        leaves = self._check(records)
        n = int(leaves[0].shape[0])
        nbytes = self.field_nbytes
        cols = []
        for i in self.layout:
            x = np.asarray(leaves[i])
            if x.dtype == np.bool_:
                x = x.astype(np.uint8)
            raw = np.ascontiguousarray(x).tobytes()
            cols.append(np.frombuffer(raw, np.uint8).reshape(n, nbytes[i]))
        if not cols:
            return np.zeros((n, 0), np.uint8)
        return np.concatenate(cols, axis=1)

    def decode(self, buf: Any) -> Any:
        """bytes or (n, nbytes)/(n*nbytes,) uint8 -> pytree of np arrays."""
        if isinstance(buf, (bytes, bytearray, memoryview)):
            buf = np.frombuffer(buf, np.uint8)
        buf = np.asarray(buf, np.uint8).reshape(-1, self.nbytes)
        n = buf.shape[0]
        nbytes = self.field_nbytes
        leaves, off = [None] * len(self.dtypes), 0
        for i in self.layout:
            dtype, shape, nb = np.dtype(self.dtypes[i]), self.shapes[i], nbytes[i]
            piece = np.ascontiguousarray(buf[:, off:off + nb])
            if dtype == np.bool_:
                leaf = piece.reshape((n,) + shape).astype(np.bool_)
            else:
                leaf = np.frombuffer(piece.tobytes(), dtype=dtype)
                leaf = leaf.reshape((n,) + shape)
            leaves[i] = leaf
            off += nb
        return jax.tree.unflatten(self.treedef, leaves)

    # -- internals ------------------------------------------------------------
    def _check_x64(self) -> None:
        """The jax path needs x64 enabled for 64-bit fields — otherwise
        ``jnp.asarray``/``bitcast`` silently downcast and the packed rows
        come out narrower than ``nbytes``. Fail loudly instead."""
        if any(np.dtype(dt).itemsize == 8 and np.dtype(dt).kind in "fiu"
               for dt in self.dtypes) and not jax.config.jax_enable_x64:
            raise RuntimeError(
                "codec has 64-bit fields but jax_enable_x64 is off; "
                "jax pack/unpack would silently truncate them. Enable it "
                "(jax.config.update('jax_enable_x64', True)) or use the "
                "numpy encode/decode path.")

    def _check(self, records: Any) -> Sequence[Any]:
        leaves, treedef = jax.tree.flatten(records)
        if treedef != self.treedef:
            raise ValueError(f"records structure {treedef} does not match "
                             f"codec structure {self.treedef}")
        for leaf, dt, shape in zip(leaves, self.dtypes, self.shapes):
            if str(np.dtype(leaf.dtype)) != dt or tuple(leaf.shape[1:]) != shape:
                raise ValueError(
                    f"field mismatch: got {np.dtype(leaf.dtype)}{tuple(leaf.shape)}, "
                    f"codec expects {dt} with trailing shape {shape}")
        return leaves


# -- wire framing -------------------------------------------------------------


#: bytes of the per-tile count header (one int32 per destination tile).
COUNT_NBYTES = 4


@dataclasses.dataclass(frozen=True)
class WireFrame:
    """Header codec for the one-wire-tensor shuffle hop.

    A shuffle hop historically shipped four capacity-padded tensors per
    exchange (``data``, ``valid``, ``bucket``, ``src_pos``) — four
    ``all_to_all`` collectives, each paying its own padding. A ``WireFrame``
    fuses everything into **one** ``uint8`` tensor: each record becomes one
    byte *row* holding its payload plus whatever per-record metadata the hop
    actually needs, and validity travels either

    - **positionally** (the default): :func:`repro.kernels.ops.partition_pack`
      lays real records out in the prefix slots of each destination tile, so
      one int32 *count* per tile (carried in an extra header row prepended by
      :meth:`seal`) fully encodes the old per-slot validity mask — zero
      per-record overhead; or
    - **explicitly** (``explicit_valid=True``): a leading validity byte per
      row, for return-trip (combine) tiles whose valid slots are not a
      prefix.

    Row layout (all native-endian, matching :class:`RecordCodec`):

    ``[valid u8?][meta int32 x len(meta)][payload bytes][zero pad]``

    ``meta`` names are free-form (the shuffles use ``bucket``/``src``/
    ``pos``); each is one int32 column. Rows are padded to at least
    ``COUNT_NBYTES`` in positional mode so the count header fits.
    """

    payload_dtype: str
    payload_shape: Tuple[int, ...]   # trailing shape of one record
    meta: Tuple[str, ...] = ()
    explicit_valid: bool = False

    # -- geometry -------------------------------------------------------------
    @property
    def payload_nbytes(self) -> int:
        return int(np.dtype(self.payload_dtype).itemsize
                   * np.prod(self.payload_shape, dtype=np.int64))

    @property
    def meta_nbytes(self) -> int:
        return 4 * len(self.meta)

    @property
    def row_nbytes(self) -> int:
        base = ((1 if self.explicit_valid else 0)
                + self.meta_nbytes + self.payload_nbytes)
        # positional mode prepends a count header row -> rows must fit it
        return base if self.explicit_valid else max(base, COUNT_NBYTES)

    def tile_nbytes(self, capacity: int) -> int:
        """Wire bytes of one destination tile at ``capacity`` slots (incl.
        the count header row in positional mode)."""
        rows = capacity if self.explicit_valid else capacity + 1
        return rows * self.row_nbytes

    # -- constructors ---------------------------------------------------------
    @classmethod
    def for_payload(cls, payload: Any, meta: Sequence[str] = (),
                    explicit_valid: bool = False) -> "WireFrame":
        """Infer the payload schema from an array with a leading record
        axis (works on tracers)."""
        return cls(payload_dtype=str(np.dtype(payload.dtype)),
                   payload_shape=tuple(payload.shape[1:]),
                   meta=tuple(meta), explicit_valid=explicit_valid)

    # -- framing (jax, traceable) ---------------------------------------------
    def frame_rows(self, payload: jax.Array, valid: Optional[jax.Array] = None,
                   **meta: jax.Array) -> jax.Array:
        """(n, *payload_shape) + per-record metadata -> (n, row_nbytes) uint8.

        ``valid`` is required iff ``explicit_valid``; rows with
        ``valid == False`` are zeroed entirely (their valid byte reads 0 and
        no payload bytes leak onto the wire)."""
        if set(meta) != set(self.meta):
            raise ValueError(f"frame meta {sorted(meta)} != schema "
                             f"{sorted(self.meta)}")
        if self.explicit_valid == (valid is None):
            raise ValueError("valid= required iff explicit_valid")
        n = payload.shape[0]
        cols = []
        if self.explicit_valid:
            cols.append(valid.astype(jnp.uint8).reshape(n, 1))
        for name in self.meta:
            m = jnp.asarray(meta[name], jnp.int32).reshape(n)
            cols.append(jax.lax.bitcast_convert_type(m, jnp.uint8))
        x = payload
        if x.dtype == jnp.bool_:
            x = x.astype(jnp.uint8)
        cols.append(jax.lax.bitcast_convert_type(x, jnp.uint8)
                    .reshape(n, self.payload_nbytes))
        used = sum(c.shape[1] for c in cols)
        if used < self.row_nbytes:
            cols.append(jnp.zeros((n, self.row_nbytes - used), jnp.uint8))
        rows = jnp.concatenate(cols, axis=1)
        if self.explicit_valid:
            rows = rows * valid.astype(jnp.uint8).reshape(n, 1)
        return rows

    def open_rows(self, rows: jax.Array):
        """(..., row_nbytes) uint8 -> (payload, valid_or_None, {meta}).

        Accepts any leading dims (e.g. a ``(num_src, capacity)`` receive
        tile). ``valid`` is decoded only in explicit mode — positional-mode
        callers derive it from the tile counts via :meth:`open`."""
        if rows.shape[-1] != self.row_nbytes:
            raise ValueError(f"rows are {rows.shape[-1]} bytes, frame "
                             f"expects {self.row_nbytes}")
        lead = rows.shape[:-1]
        off = 0
        valid = None
        if self.explicit_valid:
            valid = jax.lax.slice_in_dim(rows, 0, 1, axis=-1)
            valid = valid.reshape(lead) != 0
            off = 1
        metas = {}
        for name in self.meta:
            piece = jax.lax.slice_in_dim(rows, off, off + 4, axis=-1)
            metas[name] = jax.lax.bitcast_convert_type(piece, jnp.int32)
            off += 4
        dtype = np.dtype(self.payload_dtype)
        piece = jax.lax.slice_in_dim(rows, off, off + self.payload_nbytes,
                                     axis=-1)
        if dtype.itemsize > 1:
            piece = piece.reshape(lead + self.payload_shape
                                  + (dtype.itemsize,))
            payload = jax.lax.bitcast_convert_type(piece, dtype)
        else:
            piece = piece.reshape(lead + self.payload_shape)
            payload = (piece != 0 if dtype == np.bool_
                       else jax.lax.bitcast_convert_type(piece, dtype))
        return payload, valid, metas

    # -- tile sealing (positional-validity mode) ------------------------------
    def seal(self, tiles: jax.Array, counts: jax.Array) -> jax.Array:
        """Prepend the count header row: (D, C, row) + (D,) int32 counts ->
        (D, C+1, row) wire tensor. ``counts`` must already be clamped to C
        (``partition_pack``'s prefix contract: tile d's real records occupy
        slots [0, counts[d]))."""
        if self.explicit_valid:
            raise ValueError("seal() is for positional-validity frames")
        d = tiles.shape[0]
        cb = jax.lax.bitcast_convert_type(counts.astype(jnp.int32),
                                          jnp.uint8)          # (D, 4)
        hdr = jnp.zeros((d, self.row_nbytes), jnp.uint8)
        hdr = jax.lax.dynamic_update_slice_in_dim(hdr, cb, 0, axis=1)
        return jnp.concatenate([hdr[:, None, :], tiles], axis=1)

    def open(self, wire: jax.Array):
        """Inverse of :meth:`seal` after the exchange: (D, C+1, row) ->
        (payload (D, C, *shape), valid (D, C) bool, {meta (D, C) int32})."""
        if self.explicit_valid:
            raise ValueError("open() is for positional-validity frames")
        hdr = jax.lax.index_in_dim(wire, 0, axis=1, keepdims=False)
        counts = jax.lax.bitcast_convert_type(
            jax.lax.slice_in_dim(hdr, 0, COUNT_NBYTES, axis=-1), jnp.int32)
        rows = jax.lax.slice_in_dim(wire, 1, wire.shape[1], axis=1)
        cap = rows.shape[1]
        counts = jnp.clip(counts, 0, cap)
        valid = jnp.arange(cap, dtype=jnp.int32)[None, :] < counts[:, None]
        payload, _, metas = self.open_rows(rows)
        return payload, valid, metas
