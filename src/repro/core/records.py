"""RecordCodec: fixed-shape pytree records <-> flat byte rows.

The paper's Sphere records are opaque byte strings (a data file plus its
``.idx`` offset index, §3.2); the repo's shuffles want exactly one
``(n, *rec)`` array per exchange. Historically that forced every workload
into int32 pairs (``map_reduce`` silently cast keys *and* values). The codec
closes the gap: a **record** is any fixed-shape pytree of arrays sharing a
leading record axis, and the codec packs each record into a fixed-width byte
row — the same layout in two worlds:

- ``pack`` / ``unpack``: jax ops (``lax.bitcast_convert_type``), traceable
  inside ``shard_map``/``jit`` — this is what lets
  :class:`repro.sphere.dataflow.SPMDExecutor` ship arbitrary-dtype records
  through the capacity-bounded ``all_to_all`` shuffle.
- ``encode`` / ``decode``: the numpy mirror with the identical byte layout —
  this is what the host executor writes to Sector bucket files and what an
  SPE decodes before invoking a UDF.

Byte-for-byte equality of the two paths (asserted in
``tests/test_dataflow.py``) is what makes "write once, run in-XLA or on
Sector" literal: a bucket file written by one executor is readable by the
other. Layout is native-endian (little-endian on every supported platform);
bools travel as one byte.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class RecordCodec:
    """Schema of one record: a pytree structure plus per-leaf dtype/shape.

    ``shapes`` are the per-record *trailing* shapes — the leading record axis
    is implicit. Construct with :meth:`from_example` (from arrays carrying a
    leading record axis) or :meth:`from_fields` (from a {name: (dtype,
    shape)} mapping, which fixes the field order by name).
    """

    treedef: Any
    dtypes: Tuple[str, ...]
    shapes: Tuple[Tuple[int, ...], ...]
    #: byte-layout order: position i of a packed row holds flattened leaf
    #: ``layout[i]``. Lets the on-disk field order differ from the pytree
    #: flatten order (dict pytrees always flatten in sorted-key order).
    layout: Tuple[int, ...] = ()

    def __post_init__(self):
        if len(self.dtypes) != len(self.shapes):
            raise ValueError("one dtype per field required")
        if not self.layout:
            object.__setattr__(self, "layout",
                               tuple(range(len(self.dtypes))))
        if sorted(self.layout) != list(range(len(self.dtypes))):
            raise ValueError(f"layout {self.layout} is not a permutation of "
                             f"the {len(self.dtypes)} fields")

    # -- geometry -------------------------------------------------------------
    @property
    def field_nbytes(self) -> Tuple[int, ...]:
        return tuple(
            int(np.dtype(dt).itemsize * np.prod(s, dtype=np.int64))
            for dt, s in zip(self.dtypes, self.shapes))

    @property
    def nbytes(self) -> int:
        """Packed bytes per record (= ``record_bytes`` for Sector files)."""
        return sum(self.field_nbytes)

    # -- constructors ---------------------------------------------------------
    @classmethod
    def from_example(cls, records: Any) -> "RecordCodec":
        """Infer the schema from a records pytree (leading axis = records).

        Works on concrete arrays and on tracers (shape/dtype only), so the
        SPMD executor can derive shuffle codecs mid-trace.
        """
        leaves, treedef = jax.tree.flatten(records)
        if not leaves:
            raise ValueError("records pytree has no array leaves")
        n = leaves[0].shape[0] if leaves[0].ndim else None
        for l in leaves:
            if l.ndim == 0 or l.shape[0] != n:
                raise ValueError("all record fields need the same leading "
                                 f"record axis; got shapes "
                                 f"{[tuple(x.shape) for x in leaves]}")
        return cls(treedef=treedef,
                   dtypes=tuple(str(np.dtype(l.dtype)) for l in leaves),
                   shapes=tuple(tuple(l.shape[1:]) for l in leaves))

    @classmethod
    def from_fields(cls, fields: dict) -> "RecordCodec":
        """Build from ``{name: dtype}`` or ``{name: (dtype, trailing_shape)}``
        — records are then dicts of arrays. The **insertion order** of
        ``fields`` is the byte layout (how the raw record file is laid out),
        even though dict pytrees flatten in sorted-key order."""
        spec = {}
        for name, f in fields.items():
            dt, shape = f if isinstance(f, tuple) else (f, ())
            spec[name] = (str(np.dtype(dt)), tuple(shape))
        treedef = jax.tree.structure({k: 0 for k in spec})
        names = sorted(spec)  # dict pytrees flatten in sorted key order
        byte_order = list(fields)
        return cls(treedef=treedef,
                   dtypes=tuple(spec[k][0] for k in names),
                   shapes=tuple(spec[k][1] for k in names),
                   layout=tuple(names.index(k) for k in byte_order))

    # -- jax path (traceable) -------------------------------------------------
    def pack(self, records: Any) -> jax.Array:
        """(pytree with leading axis n) -> (n, nbytes) uint8."""
        leaves = self._check(records)
        self._check_x64()
        n = leaves[0].shape[0]
        nbytes = self.field_nbytes
        cols = []
        for i in self.layout:
            x = jnp.asarray(leaves[i])
            if x.dtype == jnp.bool_:
                x = x.astype(jnp.uint8)
            b = jax.lax.bitcast_convert_type(x, jnp.uint8)
            cols.append(b.reshape(n, nbytes[i]))
        return jnp.concatenate(cols, axis=1)

    def unpack(self, packed: jax.Array) -> Any:
        """(..., nbytes) uint8 -> pytree with leading axes ``...``.

        Accepts any number of leading dims (e.g. the ``(num_src, capacity)``
        layout of a shuffle receive buffer)."""
        if packed.shape[-1] != self.nbytes:
            raise ValueError(f"packed rows are {packed.shape[-1]} bytes, "
                             f"codec expects {self.nbytes}")
        self._check_x64()
        lead = packed.shape[:-1]
        nbytes = self.field_nbytes
        leaves, off = [None] * len(self.dtypes), 0
        for i in self.layout:
            dtype, shape, nb = np.dtype(self.dtypes[i]), self.shapes[i], nbytes[i]
            piece = jax.lax.slice_in_dim(packed, off, off + nb, axis=-1)
            if dtype.itemsize > 1:
                piece = piece.reshape(lead + shape + (dtype.itemsize,))
                leaf = jax.lax.bitcast_convert_type(piece, dtype)
            else:
                piece = piece.reshape(lead + shape)
                leaf = (piece != 0 if dtype == np.bool_
                        else jax.lax.bitcast_convert_type(piece, dtype))
            leaves[i] = leaf
            off += nb
        return jax.tree.unflatten(self.treedef, leaves)

    # -- numpy path (host executor / Sector files) ----------------------------
    def encode(self, records: Any) -> np.ndarray:
        """(pytree with leading axis n) -> (n, nbytes) uint8 ndarray, byte-
        identical to :meth:`pack` of the same records."""
        leaves = self._check(records)
        n = int(leaves[0].shape[0])
        nbytes = self.field_nbytes
        cols = []
        for i in self.layout:
            x = np.asarray(leaves[i])
            if x.dtype == np.bool_:
                x = x.astype(np.uint8)
            raw = np.ascontiguousarray(x).tobytes()
            cols.append(np.frombuffer(raw, np.uint8).reshape(n, nbytes[i]))
        if not cols:
            return np.zeros((n, 0), np.uint8)
        return np.concatenate(cols, axis=1)

    def decode(self, buf: Any) -> Any:
        """bytes or (n, nbytes)/(n*nbytes,) uint8 -> pytree of np arrays."""
        if isinstance(buf, (bytes, bytearray, memoryview)):
            buf = np.frombuffer(buf, np.uint8)
        buf = np.asarray(buf, np.uint8).reshape(-1, self.nbytes)
        n = buf.shape[0]
        nbytes = self.field_nbytes
        leaves, off = [None] * len(self.dtypes), 0
        for i in self.layout:
            dtype, shape, nb = np.dtype(self.dtypes[i]), self.shapes[i], nbytes[i]
            piece = np.ascontiguousarray(buf[:, off:off + nb])
            if dtype == np.bool_:
                leaf = piece.reshape((n,) + shape).astype(np.bool_)
            else:
                leaf = np.frombuffer(piece.tobytes(), dtype=dtype)
                leaf = leaf.reshape((n,) + shape)
            leaves[i] = leaf
            off += nb
        return jax.tree.unflatten(self.treedef, leaves)

    # -- internals ------------------------------------------------------------
    def _check_x64(self) -> None:
        """The jax path needs x64 enabled for 64-bit fields — otherwise
        ``jnp.asarray``/``bitcast`` silently downcast and the packed rows
        come out narrower than ``nbytes``. Fail loudly instead."""
        if any(np.dtype(dt).itemsize == 8 and np.dtype(dt).kind in "fiu"
               for dt in self.dtypes) and not jax.config.jax_enable_x64:
            raise RuntimeError(
                "codec has 64-bit fields but jax_enable_x64 is off; "
                "jax pack/unpack would silently truncate them. Enable it "
                "(jax.config.update('jax_enable_x64', True)) or use the "
                "numpy encode/decode path.")

    def _check(self, records: Any) -> Sequence[Any]:
        leaves, treedef = jax.tree.flatten(records)
        if treedef != self.treedef:
            raise ValueError(f"records structure {treedef} does not match "
                             f"codec structure {self.treedef}")
        for leaf, dt, shape in zip(leaves, self.dtypes, self.shapes):
            if str(np.dtype(leaf.dtype)) != dt or tuple(leaf.shape[1:]) != shape:
                raise ValueError(
                    f"field mismatch: got {np.dtype(leaf.dtype)}{tuple(leaf.shape)}, "
                    f"codec expects {dt} with trailing shape {shape}")
        return leaves
