"""Sphere streams (paper §3.2).

"A stream ... represents either a dataset or a part of a dataset. Sphere
takes streams as inputs and produces streams as outputs. A Sphere stream
consists of multiple data segments and the segments are processed by Sphere
Processing Engines (SPEs)."

Here a stream is a record array sharded along its leading axis over a mesh
axis: the per-device block *is* the segment an SPE (device) processes. The
segment-size bounds S_min/S_max of the paper's scheduler (§3.5.1) become the
per-device block size induced by the sharding; ``segments()`` exposes the
host-level segment table that the :mod:`repro.sphere.scheduler` schedules
across hosts when streams are read from Sector.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: Paper defaults for segment sizing (§3.5.1), in records here rather than MB.
S_MIN_DEFAULT = 8 << 20
S_MAX_DEFAULT = 128 << 20


@dataclasses.dataclass(frozen=True)
class SegmentInfo:
    """Host-level segment descriptor: which records, from which Sector file."""
    index: int
    file_path: str
    offset: int
    num_records: int


@dataclasses.dataclass
class SphereStream:
    """A sharded record array plus its segment table.

    ``data``: (num_records, ...) array (sharded or to-be-sharded) — or a
    pytree of such arrays when the stream carries structured records.
    ``valid``: optional (num_records,) bool mask — Sphere outputs may be
    padded (capacity-bounded shuffles), and downstream UDFs must know which
    rows are real records.
    ``codec``: optional :class:`repro.core.records.RecordCodec` describing
    the record schema — the byte layout the same stream has when stored in
    Sector, which is what lets :class:`repro.sphere.dataflow.HostExecutor`
    and :class:`~repro.sphere.dataflow.SPMDExecutor` consume one source
    definition.
    """

    data: jax.Array
    valid: Optional[jax.Array] = None
    segment_table: Optional[List[SegmentInfo]] = None
    codec: Optional[object] = None  # RecordCodec (kept untyped: no cycle)

    @property
    def num_records(self) -> int:
        return jax.tree.leaves(self.data)[0].shape[0]

    def with_data(self, data: jax.Array, valid: Optional[jax.Array] = None
                  ) -> "SphereStream":
        # codec intentionally not carried over: a UDF may change the schema
        return SphereStream(data=data, valid=valid,
                            segment_table=self.segment_table)

    # -- sharding -------------------------------------------------------------
    def shard(self, mesh: Mesh, axis: str | Tuple[str, ...] = "data") -> "SphereStream":
        spec = P(axis)
        sharding = NamedSharding(mesh, spec)
        data = jax.device_put(self.data, sharding)
        valid = None
        if self.valid is not None:
            valid = jax.device_put(self.valid, NamedSharding(mesh, P(axis)))
        return SphereStream(data=data, valid=valid,
                            segment_table=self.segment_table,
                            codec=self.codec)

    # -- micro-batching --------------------------------------------------------
    def micro_batches(self, batch_records: int,
                      drop_remainder: bool = False):
        """Yield the stream as dense numpy record chunks of at most
        ``batch_records`` rows — the micro-batch source for
        :meth:`repro.sphere.streaming.StreamExecutor.submit` (paper §3.2:
        the stream *is* a sequence of segments; here each chunk becomes one
        admission request). Rows masked out by ``valid`` are compacted away
        first, so every yielded row is a real record."""
        if batch_records <= 0:
            raise ValueError(f"batch_records must be > 0, got "
                             f"{batch_records}")
        data = jax.tree.map(np.asarray, self.data)
        if self.valid is not None:
            mask = np.asarray(self.valid)
            data = jax.tree.map(lambda a: a[mask], data)
        n = jax.tree.leaves(data)[0].shape[0]
        for off in range(0, n, batch_records):
            end = min(off + batch_records, n)
            if drop_remainder and end - off < batch_records:
                return
            yield jax.tree.map(lambda a: a[off:end], data)

    # -- segment bookkeeping ---------------------------------------------------
    @staticmethod
    def plan_segments(total_records: int, record_bytes: int,
                      files: Sequence[Tuple[str, int]],
                      s_min: int = S_MIN_DEFAULT, s_max: int = S_MAX_DEFAULT,
                      num_spes: int = 1) -> List[SegmentInfo]:
        """Paper §3.5.1 segmentation: uniform split across SPEs, clamped to
        [S_min, S_max] bytes, whole records only, never spanning files.

        ``files``: (sector_path, num_records) per input file.
        """
        if total_records == 0:
            return []
        target = max(1, total_records // max(num_spes, 1))
        min_rec = max(1, math.ceil(s_min / record_bytes))
        max_rec = max(1, s_max // record_bytes)
        per_seg = min(max(target, min_rec), max_rec)
        segs: List[SegmentInfo] = []
        idx = 0
        for path, nrec in files:
            off = 0
            while off < nrec:
                n = min(per_seg, nrec - off)
                segs.append(SegmentInfo(idx, path, off, n))
                idx += 1
                off += n
        return segs


def make_stream(data: jnp.ndarray, mesh: Optional[Mesh] = None,
                axis: str = "data") -> SphereStream:
    s = SphereStream(data=jnp.asarray(data))
    if mesh is not None:
        s = s.shard(mesh, axis)
    return s
