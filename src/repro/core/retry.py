"""Shared retry/backoff policy for every layer that re-tries work.

The engine re-pools lost segments, :meth:`SectorClient.recover` re-resolves
stale metadata, and :class:`~repro.sphere.streaming.TenantQueue` requeues
timed-out tickets — before this module each did so with zero-delay retries,
which hammers a recovering component exactly when it is least able to serve.
:class:`RetryPolicy` gives all three the same capped exponential backoff with
*seeded, deterministic* jitter: two processes configured with the same
``(seed, key, attempt)`` compute byte-identical delays, so chaos replays stay
reproducible and tests can assert exact schedules against a virtual clock.

The default policy is ``base=0.0`` — zero delay everywhere — so wiring a
policy through a call path is behaviour-preserving until a caller opts into
real backoff.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Tuple

__all__ = ["RetryPolicy"]


def _mix(*parts: int) -> int:
    """Deterministic integer mix (never ``hash()`` — PYTHONHASHSEED)."""
    acc = 0
    for p in parts:
        acc = (acc * 1000003 + int(p)) & 0xFFFFFFFFFFFFFFFF
    return acc


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with seeded deterministic jitter.

    ``delay(attempt, key)`` returns ``min(cap, base * factor**attempt)``
    scaled by a jitter factor drawn uniformly from
    ``[1 - jitter, 1 + jitter]`` with a :class:`random.Random` seeded from
    ``(seed, key, attempt)``. ``attempt`` counts from 0 (the delay before
    the first retry); ``key`` namespaces independent retry streams (a
    segment index, a ticket id, a crc of a path) so concurrent retriers do
    not thunder in lockstep.
    """

    base: float = 0.0       # seconds before the first retry (0 => no delay)
    factor: float = 2.0     # exponential growth per attempt
    cap: float = 30.0       # delay ceiling in seconds
    jitter: float = 0.0     # +/- fraction of the delay, in [0, 1)
    seed: int = 0

    def __post_init__(self) -> None:
        if self.base < 0 or self.factor < 1.0 or self.cap < 0:
            raise ValueError("base/cap must be >= 0 and factor >= 1")
        if not (0.0 <= self.jitter < 1.0):
            raise ValueError(f"jitter must be in [0, 1): {self.jitter}")

    def delay(self, attempt: int, key: int = 0) -> float:
        """Deterministic delay in seconds before retry number ``attempt``."""
        if attempt < 0:
            raise ValueError(f"attempt must be >= 0: {attempt}")
        d = min(self.cap, self.base * self.factor ** attempt)
        if d <= 0.0:
            return 0.0
        if self.jitter:
            rng = random.Random(_mix(self.seed, key, attempt))
            d *= 1.0 + self.jitter * (2.0 * rng.random() - 1.0)
        return d

    def schedule(self, attempts: int, key: int = 0) -> Tuple[float, ...]:
        """The full delay sequence for ``attempts`` retries (testing aid)."""
        return tuple(self.delay(a, key=key) for a in range(attempts))
