"""Distributed Terasort (paper §4.2, Fig 3) and the Hadoop-style baseline.

``terasort`` is now a thin shim over the unified dataflow API — the whole
two-stage sort is one pipeline::

    Dataflow.source().sort(key=lambda r: r["key"], splitters=...,
                           num_buckets=...)

executed by :class:`repro.sphere.dataflow.SPMDExecutor` (or, over
Sector-stored records, by the host executor with bucket files).

Stage 1 ("hashing"): every record's key is range-partitioned into a bucket
(``searchsorted`` against splitters — the paper's T_0 < T_1 < ... thresholds)
and shuffled to the device owning that bucket via
:class:`repro.core.shuffle.ShufflePlan` (flat or two-level wide-area).

Stage 2 ("sort each bucket"): each device sorts its received records — the
paper's point that "the SPE processes the *whole* data segment ... and does
not just process each record individually". The sort is the Pallas bitonic
kernel (TPU-native) or the XLA sort oracle.

After stage 2 the stream is globally sorted: all keys on device d precede all
keys on device d+1 (bucket ranges are contiguous per device).

``hadoop_style_sort`` is the comparison baseline (paper Table 1): a
block-store shuffle where every reducer reads the full map output — realized
as an ``all_gather`` followed by a local range filter + sort. It moves
``axis_size``× the bytes of the direct bucket shuffle; the roofline
collective term quantifies the paper's 2× claim on our hardware model.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map

from repro.core.shuffle import ShufflePlan
from repro.kernels import ops as kops

KEY_MAX = jnp.iinfo(jnp.int32).max


@dataclasses.dataclass
class SortResult:
    """keys/payloads: (num_devices * capacity,) globally laid out so that the
    valid records on device d are ascending and all precede device d+1's."""
    keys: jax.Array
    payload: jax.Array
    valid: jax.Array
    dropped: jax.Array


def uniform_splitters(num_buckets: int, key_min: int = 0,
                      key_max: int = KEY_MAX) -> jnp.ndarray:
    """Equal-width range splitters (terasort keys are uniform)."""
    edges = jnp.linspace(key_min, key_max, num_buckets + 1)[1:-1]
    return edges.astype(jnp.int32)


def sampled_splitters(keys: jax.Array, num_buckets: int,
                      sample_per_shard: int, mesh: Mesh,
                      axis: str = "data") -> jnp.ndarray:
    """Sample-based splitters for non-uniform keys: every shard contributes a
    strided sample; quantiles of the gathered sample become the thresholds
    (the paper's 'more advanced hashing technique ... to more evenly
    distribute' remark, §3.6)."""

    def local_sample(k):
        n = k.shape[0]
        # clamp to the shard size: a shard smaller than sample_per_shard
        # contributes every record instead of slicing out of bounds
        take = min(sample_per_shard, n)
        stride = max(n // take, 1)
        samp = jax.lax.slice(k, (0,), (take * stride,), (stride,))
        return jax.lax.all_gather(samp, axis, tiled=True)

    gathered = shard_map(local_sample, mesh=mesh, in_specs=(P(axis),),
                         out_specs=P(), check_vma=False)(keys)
    ssorted = jnp.sort(gathered)
    m = ssorted.shape[0]
    idx = (jnp.arange(1, num_buckets) * m) // num_buckets
    return ssorted[idx]


def terasort(
    keys: jax.Array,
    payload: jax.Array,
    mesh: Mesh,
    axis: Union[str, Sequence[str]] = "data",
    splitters: Optional[jnp.ndarray] = None,
    capacity_factor: float = 2.0,
    use_pallas: bool = True,
    buckets_per_device: int = 1,
    plan: Optional[ShufflePlan] = None,
    chunks: Optional[int] = None,
    sort_algo: Optional[str] = None,
) -> SortResult:
    """Globally sort (keys, payload) sharded over ``axis``.

    keys: (N,) int32 >= 0; payload: (N,) int32 (e.g. record index into the
    90-byte values held in Sector).

    ``axis`` may be a single mesh axis (flat bucket shuffle) or a pair
    ``(dc_axis, node_axis)`` — then stage 1 runs the wide-area two-level
    shuffle of :mod:`repro.core.shuffle`, keeping cross-DC traffic to one
    dense tile per remote data center. An explicit ``plan`` overrides
    ``axis``/``buckets_per_device``/``capacity_factor``: its axes and bucket
    count drive the sharding specs and splitters. ``sort_algo`` pins the
    stage-2 segment-sort kernel (``"bitonic"``/``"radix"``/``"oracle"``);
    ``None`` defers to the legacy ``use_pallas`` switch (``True`` → the
    bitonic kernel, ``False`` → the backend-aware autotuner of
    :mod:`repro.kernels.autotune`), independently of ``plan.use_pallas``
    (which governs the shuffle histogram) — the kernel-vs-oracle parity
    benchmark relies on switching them separately. ``chunks`` sets the
    shuffle pipeline depth:
    W interleaved pack/exchange rounds per hop (see
    :func:`repro.core.shuffle.sphere_shuffle`); ``None`` defers to
    ``plan.chunks`` (or 1).

    .. deprecated:: thin shim — build the pipeline directly with
       ``Dataflow.source().sort(...)`` and an executor; a pipeline object
       reused across calls also reuses its compiled program.
    """
    from repro.sphere.dataflow import Dataflow, SPMDExecutor

    if plan is not None:
        axes = plan.axes
        num_buckets = plan.num_buckets
    else:
        axes = (axis,) if isinstance(axis, str) else tuple(axis)
        axis_size = math.prod(mesh.shape[a] for a in axes)
        num_buckets = axis_size * buckets_per_device
    if splitters is None:
        splitters = uniform_splitters(num_buckets)
    elif splitters.shape[0] != num_buckets - 1:
        raise ValueError(f"{splitters.shape[0]} splitters for "
                         f"{num_buckets} buckets")

    df = Dataflow.source().sort(key=lambda r: r["key"], splitters=splitters,
                                num_buckets=num_buckets,
                                capacity_factor=capacity_factor)
    ex = SPMDExecutor(mesh, axes=axes, plan=plan, use_pallas=use_pallas,
                      chunks=chunks, sort_algo=sort_algo)
    res = ex.run(df, {"key": keys.astype(jnp.int32),
                      "payload": payload})
    return SortResult(keys=res.records["key"], payload=res.records["payload"],
                      valid=res.valid, dropped=res.dropped)


def hadoop_style_sort(
    keys: jax.Array,
    payload: jax.Array,
    mesh: Mesh,
    axis: str = "data",
    splitters: Optional[jnp.ndarray] = None,
    use_pallas=kops._UNSET,
    algo: Optional[str] = None,
) -> SortResult:
    """Baseline: every reducer pulls the complete map output (block-store
    shuffle read amplification), then filters its own key range and sorts.
    Semantically identical to :func:`terasort`; moves D× the bytes.

    The local sort goes through the autotuned
    :func:`repro.kernels.ops.sort_kv_segments` entry point; ``algo`` pins
    ``"bitonic"``/``"radix"``/``"oracle"``, ``None`` autotunes.
    ``use_pallas`` is deprecated (``True`` → ``algo="bitonic"``, ``False``
    → ``algo="oracle"``)."""
    algo = kops._legacy_algo(use_pallas, algo, "hadoop_style_sort")
    axis_size = mesh.shape[axis]
    if splitters is None:
        splitters = uniform_splitters(axis_size)
    n_local = keys.shape[0] // axis_size

    def udf(k, p, spl):
        k = k.reshape(-1)
        p = p.reshape(-1)
        all_k = jax.lax.all_gather(k, axis, tiled=True)    # (N,) everywhere
        all_p = jax.lax.all_gather(p, axis, tiled=True)
        me = jax.lax.axis_index(axis)
        bucket = jnp.searchsorted(spl, all_k, side="right").astype(jnp.int32)
        mine = bucket == me
        # keep at most n_local * axis_size rows (full dataset upper bound);
        # realistic capacity: same as terasort's receive capacity.
        cap = k.shape[0] * 2
        skey = jnp.where(mine, all_k, KEY_MAX)
        pos = jnp.arange(skey.shape[0], dtype=jnp.int32)
        sk_row, order_row = kops.sort_kv_segments(skey[None, :],
                                                  pos[None, :], algo=algo)
        order, sk = order_row[0, :cap], sk_row[0, :cap]
        sp = jnp.take(all_p, order)
        sv = jnp.take(mine, order)
        return sk, sp, sv, jnp.zeros((), jnp.int32)

    sk, sp, sv, dropped = shard_map(
        udf, mesh=mesh,
        in_specs=(P(axis), P(axis), P()),
        out_specs=(P(axis), P(axis), P(axis), P()),
        check_vma=False,
    )(keys, payload, splitters)
    return SortResult(keys=sk, payload=sp, valid=sv, dropped=dropped)


def is_globally_sorted(result: SortResult, num_devices: int) -> bool:
    """Host-side verification: valid keys ascend within each device block and
    block maxima never exceed the next block's minima."""
    keys = jax.device_get(result.keys)
    valid = jax.device_get(result.valid)
    per = keys.shape[0] // num_devices
    prev_max = -1
    for d in range(num_devices):
        k = keys[d * per:(d + 1) * per][valid[d * per:(d + 1) * per]]
        if k.size == 0:
            continue
        import numpy as np
        if not bool(np.all(np.diff(k) >= 0)):
            return False
        if k[0] < prev_max:
            return False
        prev_max = int(k[-1])
    return True
