"""Sphere compute primitives (paper §3), as composable JAX modules.

The paper's abstractions map onto SPMD JAX:

- a *stream* of *segments*  -> :class:`repro.core.stream.SphereStream`
  (a sharded array; one segment per device along a mesh axis);
- an *SPE applying a UDF*   -> :func:`repro.core.udf.sphere_map`
  (``shard_map``: the UDF body runs per-device on its local segment);
- *bucket shuffle*          -> :func:`repro.core.shuffle.sphere_shuffle`
  (capacity-bounded ``all_to_all``; also drives MoE expert dispatch);
- *two-stage sort* (Fig 3)  -> :func:`repro.core.sort.terasort`;
- *MapReduce as Map UDF + Reduce UDF* (§3.6)
                            -> :func:`repro.core.mapreduce.map_reduce`;
- *records* of any fixed-shape pytree schema
                            -> :class:`repro.core.records.RecordCodec`;
- *framed UDT transfers* (§2.3: one large framed stream per hop)
                            -> :class:`repro.core.records.WireFrame`
  (every shuffle hop ships exactly one fused wire tensor; the structural
  guarantee is checkable via :mod:`repro.core.introspect`).

These are the primitives; the one-API-two-executors layer on top is
:mod:`repro.sphere.dataflow` (``Dataflow`` / ``SPMDExecutor`` /
``HostExecutor``).
"""

from repro.core.records import RecordCodec, WireFrame
from repro.core.stream import SphereStream
from repro.core.udf import sphere_map
from repro.core.shuffle import ShuffleResult, sphere_shuffle, sphere_combine
from repro.core.sort import terasort, hadoop_style_sort
from repro.core.mapreduce import map_reduce

__all__ = [
    "RecordCodec", "WireFrame", "SphereStream", "sphere_map",
    "ShuffleResult", "sphere_shuffle", "sphere_combine",
    "terasort", "hadoop_style_sort", "map_reduce",
]
