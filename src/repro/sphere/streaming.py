"""Streaming Sphere: continuous micro-batch dataflow + multi-tenant admission.

The paper's Sphere is a *stream* processor — "Sphere takes streams as inputs
and produces streams as outputs" (§3.2) — but the batch executors in
:mod:`repro.sphere.dataflow` run a pipeline exactly once. This module turns
the same declarative stage graph into a long-lived serving loop:

- :class:`StreamExecutor` runs a ``Dataflow.stream_source()`` pipeline
  continuously over fixed-shape **micro-batches**. Every micro-batch is one
  invocation of the same compiled ``jit(shard_map)`` program (the
  :class:`~repro.sphere.dataflow.SPMDExecutor` LRU cache guarantees zero
  recompiles after warm-up — asserted via ``cache_info()``), reusing the
  one-wire-tensor shuffle path unchanged. Pipelines whose last stage is a
  ``reduce`` keep **bounded cross-batch carry state**: the reduce output is
  compacted into a fixed-capacity per-device buffer and merged back into the
  next batch's reduce input, so running aggregates (word counts, top-K — a
  reduce that emits its group's best K rows) stream forward without
  unbounded growth. Carry never crosses devices: the deterministic shuffle
  routes a given key to the same device every batch, so per-key state stays
  co-located with the records that update it.

- :class:`TenantQueue` is the admission layer in front of the executor:
  per-tenant **priority classes** (strict: a class is served only when every
  more-urgent class is empty), **weighted fair share** inside a class via
  deficit round-robin, per-request **deadlines** with timeout/requeue
  semantics (a request that waits past its deadline is requeued at the head
  with a fresh deadline; after ``max_requeues`` it is reported failed — the
  paper's §3.5.2 discard/re-pool rule, built on the scheduler module's
  segment-state machinery), and **bounded queues** for backpressure
  (``admit`` raises :class:`QueueFull`). Delivery is exactly-once: a ticket
  completes at most once no matter how many requeued or speculative copies
  finish.

Carry-state contract (what a streaming ``reduce`` UDF must satisfy):

1. *schema-preserving*: output records have the same pytree structure,
   trailing shapes and dtypes as the input (the output is fed back in);
2. *merge-idempotent*: re-reducing its own output together with new records
   gives the same aggregate as reducing everything at once
   (``fn(out ++ new) == fn(all)`` up to row order) — true for per-key sums,
   min/max, top-K;
3. *bounded*: at most ``carry_capacity`` valid rows per device survive a
   batch; overflow is dropped AND counted in ``dropped`` (§3.5.1's bounded
   capacity contract, applied to state).

The emitted stream of a carried reduce is a sequence of *snapshots*: each
micro-batch's output is the aggregate over everything admitted so far, so
the final snapshot equals the one-shot batch run over the concatenation —
the stream/batch equivalence tests assert exactly that.
"""

from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.records import RecordCodec
from repro.core.retry import RetryPolicy
from repro.obs.metrics import MS_BUCKETS, REGISTRY
from repro.obs.trace import NULL_TRACER
from repro.sphere.chaos import (SPMD_KINDS, STREAM_KINDS, ChaosSchedule,
                                StreamCheckpoint)
from repro.sphere.dataflow import (Dataflow, MapStage, ReduceStage,
                                   SortStage, SPMDExecutor,
                                   _last_reduce_index, _leading, _phases,
                                   _split_reduce_out)
from repro.sphere.scheduler import DeadlineHeap, SegStatus


class QueueFull(RuntimeError):
    """Backpressure: the tenant's bounded admission queue is at capacity."""

    def __init__(self, tenant: str, depth: int):
        super().__init__(f"tenant {tenant!r} queue full ({depth} pending); "
                         f"retry after completions drain it")
        self.tenant = tenant
        self.depth = depth


@dataclasses.dataclass
class Ticket:
    """One admitted request. Status reuses the scheduler's segment states:
    PENDING = queued, RUNNING = in a dispatched micro-batch, DONE =
    delivered (exactly once), DATA_ERROR = abandoned after max requeues."""

    req_id: int
    tenant: str
    payload: Any
    cost: int                          # admission-budget units (records)
    admitted_at: float
    timeout: Optional[float] = None
    deadline: Optional[float] = None
    status: SegStatus = SegStatus.PENDING
    attempts: int = 0                  # times dispatched into a batch
    requeues: int = 0                  # timeout / failure re-admissions
    completed_at: Optional[float] = None
    #: earliest re-dispatch time set by the queue's RetryPolicy on requeue;
    #: the ticket keeps its head seniority but is not served before this
    not_before: Optional[float] = None


@dataclasses.dataclass
class TenantState:
    name: str
    weight: float = 1.0
    priority: int = 0                  # lower = more urgent (strict classes)
    capacity: int = 64                 # max queued tickets (backpressure)
    deficit: float = 0.0               # DRR credit, persists across rounds
    queue: "deque[Ticket]" = dataclasses.field(default_factory=deque)
    # -- stats ---------------------------------------------------------------
    admitted: int = 0
    rejected: int = 0
    delivered: int = 0
    records_served: int = 0
    timeouts: int = 0
    requeues: int = 0
    failed: int = 0
    latencies: "deque[float]" = dataclasses.field(
        default_factory=lambda: deque(maxlen=4096))


def _percentile(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


class TenantQueue:
    """Multi-tenant admission queue: strict priority classes, weighted
    deficit-round-robin fair share within a class, deadlines with
    timeout/requeue, bounded per-tenant queues (see module docstring).

    All methods take an explicit ``now`` (any monotonic unit — seconds,
    engine steps, virtual time); omit it to use ``time.monotonic()``.
    """

    def __init__(self, quantum: float = 64.0, timeout: Optional[float] = None,
                 max_requeues: int = 3, capacity: int = 64,
                 retry_policy: Optional[RetryPolicy] = None):
        #: DRR credit added per round per unit weight. Any value > 0 is
        #: fair in the long run; >= the typical request cost keeps each
        #: acquire() pass O(tenants).
        self.quantum = quantum
        self.timeout = timeout          # default per-request deadline
        self.max_requeues = max_requeues
        self.capacity = capacity
        #: when set, a requeued ticket backs off (``not_before``) per the
        #: policy before it can be dispatched again; the deadline is pushed
        #: past the backoff so the delay never eats the ticket's timeout
        self.retry_policy = retry_policy
        self._tenants: "Dict[str, TenantState]" = {}
        self._deadlines = DeadlineHeap()
        self._next_id = 0
        self._rr_offset = 0             # rotates DRR start tenant per acquire

    @staticmethod
    def _now(now: Optional[float]) -> float:
        return time.monotonic() if now is None else now

    def register(self, tenant: str, weight: float = 1.0, priority: int = 0,
                 capacity: Optional[int] = None) -> TenantState:
        if weight <= 0:
            raise ValueError(f"tenant weight must be > 0, got {weight}")
        st = self._tenants.get(tenant)
        if st is None:
            st = self._tenants[tenant] = TenantState(
                tenant, weight=weight, priority=priority,
                capacity=self.capacity if capacity is None else capacity)
        else:
            st.weight, st.priority = weight, priority
            if capacity is not None:
                st.capacity = capacity
        return st

    # -- admission -----------------------------------------------------------
    def admit(self, tenant: str, payload: Any, cost: int = 1,
              timeout: Optional[float] = -1.0,
              now: Optional[float] = None) -> Ticket:
        """Admit one request; raises :class:`QueueFull` at capacity.
        ``timeout`` overrides the queue default (None disables the
        deadline; the -1.0 sentinel means "use the default")."""
        now = self._now(now)
        st = self._tenants.get(tenant) or self.register(tenant)
        if len(st.queue) >= st.capacity:
            st.rejected += 1
            REGISTRY.counter("tenant.rejected", tenant=tenant).inc()
            raise QueueFull(tenant, len(st.queue))
        if timeout == -1.0:
            timeout = self.timeout
        tk = Ticket(req_id=self._next_id, tenant=tenant, payload=payload,
                    cost=int(cost), admitted_at=now, timeout=timeout)
        self._next_id += 1
        if timeout is not None:
            tk.deadline = now + timeout
            self._deadlines.push(tk.deadline, tk)
        st.queue.append(tk)
        st.admitted += 1
        REGISTRY.counter("tenant.admitted", tenant=tenant).inc()
        return tk

    # -- dispatch: strict priority + deficit round-robin ---------------------
    def acquire(self, budget: int, now: Optional[float] = None
                ) -> List[Ticket]:
        """Pull up to ``budget`` cost units of requests for one micro-batch.

        Priority classes are strict and non-bypassing: a class is only
        served once every more-urgent class is drained, and if its head
        request no longer fits the remaining budget, lower classes do NOT
        fill the gap (the leftover budget is padding — fairness beats batch
        packing). Within a class, deficit round-robin: each round every
        backlogged tenant earns ``weight * quantum`` credit and serves
        requests while credit and budget allow, so served cost converges to
        the weight ratio whatever the request sizes.

        A head ticket still inside its retry backoff window (``not_before``
        in the future) makes its tenant temporarily non-backlogged: the
        slot passes to peers (or lower classes) instead of busy-waiting on
        a ticket that chose to sit out."""
        now = self._now(now)
        self.expire(now)

        def ready(t: TenantState) -> bool:
            return bool(t.queue) and (t.queue[0].not_before is None
                                      or t.queue[0].not_before <= now)

        taken: List[Ticket] = []
        remaining = budget
        self._rr_offset += 1
        classes = sorted({t.priority for t in self._tenants.values()
                          if ready(t)})
        for prio in classes:
            cls = [t for t in self._tenants.values() if t.priority == prio]
            off = self._rr_offset % len(cls)
            cls = cls[off:] + cls[:off]
            while remaining > 0:
                backlog = [t for t in cls if ready(t)]
                if not backlog:
                    break
                if min(t.queue[0].cost for t in backlog) > remaining:
                    remaining = 0       # strict: no bypass by lower classes
                    break
                for t in backlog:
                    if not ready(t):
                        if not t.queue:
                            t.deficit = 0.0
                        continue
                    t.deficit += t.weight * self.quantum
                    while (ready(t) and t.queue[0].cost <= t.deficit
                           and t.queue[0].cost <= remaining):
                        tk = t.queue.popleft()
                        tk.status = SegStatus.RUNNING
                        tk.attempts += 1
                        t.deficit -= tk.cost
                        remaining -= tk.cost
                        taken.append(tk)
                        if remaining <= 0:
                            break
                    if not t.queue:
                        t.deficit = 0.0  # classic DRR: no credit hoarding
                    if remaining <= 0:
                        break
            if remaining <= 0:
                break
        return taken

    # -- completion / failure / expiry ---------------------------------------
    def complete(self, ticket: Ticket, now: Optional[float] = None) -> bool:
        """Mark delivered. Returns False (and changes nothing) if the ticket
        already completed or failed — the exactly-once guard: late
        completions of a requeued copy are suppressed, and a still-queued
        duplicate is withdrawn when its twin completes first."""
        now = self._now(now)
        if ticket.status in (SegStatus.DONE, SegStatus.DATA_ERROR):
            return False
        if ticket.status == SegStatus.PENDING:
            # completed by an earlier dispatch while its requeued copy
            # waited — withdraw the copy so it cannot deliver again
            try:
                self._tenants[ticket.tenant].queue.remove(ticket)
            except ValueError:
                pass
        ticket.status = SegStatus.DONE
        ticket.completed_at = now
        st = self._tenants[ticket.tenant]
        st.delivered += 1
        st.records_served += ticket.cost
        st.latencies.append(now - ticket.admitted_at)
        REGISTRY.counter("tenant.delivered", tenant=ticket.tenant).inc()
        REGISTRY.histogram("tenant.latency", tenant=ticket.tenant).observe(
            now - ticket.admitted_at)
        return True

    def requeue(self, ticket: Ticket, now: Optional[float] = None) -> bool:
        """Put a dispatched-but-unfinished (or timed-out) ticket back at the
        *head* of its tenant's queue with a fresh deadline — it keeps its
        seniority (a blown deadline escalates, it must not start over behind
        the backlog that starved it, or it would time out forever). After
        ``max_requeues`` the ticket is abandoned and reported (status
        DATA_ERROR) — the paper's §3.5.2 bounded-retry rule. Returns True
        iff the ticket is queued again."""
        now = self._now(now)
        if ticket.status in (SegStatus.DONE, SegStatus.DATA_ERROR):
            return False
        st = self._tenants[ticket.tenant]
        if ticket.status == SegStatus.PENDING:
            try:
                st.queue.remove(ticket)
            except ValueError:
                pass
        ticket.requeues += 1
        st.requeues += 1
        REGISTRY.counter("tenant.requeues", tenant=ticket.tenant).inc()
        if ticket.requeues > self.max_requeues:
            ticket.status = SegStatus.DATA_ERROR
            st.failed += 1
            REGISTRY.counter("tenant.failed", tenant=ticket.tenant).inc()
            return False
        ticket.status = SegStatus.PENDING
        delay = 0.0
        if self.retry_policy is not None:
            # keyed by req_id so concurrent requeuers de-synchronize while
            # a given ticket replays the same deterministic backoff ladder
            delay = self.retry_policy.delay(max(0, ticket.requeues - 1),
                                            key=ticket.req_id)
            ticket.not_before = now + delay
            REGISTRY.histogram("tenant.backoff_ms", bounds=MS_BUCKETS,
                               tenant=ticket.tenant).observe(delay * 1e3)
        if ticket.timeout is not None:
            ticket.deadline = now + delay + ticket.timeout
            self._deadlines.push(ticket.deadline, ticket)
        st.queue.appendleft(ticket)
        return True

    def expire(self, now: Optional[float] = None) -> List[Ticket]:
        """Requeue every *queued* ticket whose deadline has passed (fresh
        deadline, head position, ``timeouts`` counted; abandoned once
        ``max_requeues`` is exhausted). RUNNING tickets are left alone —
        a lost in-flight batch is the dispatcher's to report via
        :meth:`requeue`. Returns the tickets that were requeued."""
        now = self._now(now)
        requeued = []
        for deadline, tk in self._deadlines.pop_due(now):
            if tk.status != SegStatus.PENDING or tk.deadline != deadline:
                continue                # stale entry (refreshed or moved on)
            self._tenants[tk.tenant].timeouts += 1
            REGISTRY.counter("tenant.timeouts", tenant=tk.tenant).inc()
            if self.requeue(tk, now=now):
                requeued.append(tk)
        return requeued

    # -- introspection -------------------------------------------------------
    def depth(self, tenant: Optional[str] = None) -> int:
        if tenant is not None:
            st = self._tenants.get(tenant)
            return len(st.queue) if st else 0
        return sum(len(t.queue) for t in self._tenants.values())

    def pending(self) -> int:
        return self.depth()

    def pending_items(self) -> List[Ticket]:
        return [tk for t in self._tenants.values() for tk in t.queue]

    def stats(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant serving stats: depth, throughput counters, latency
        percentiles (in whatever ``now`` unit the caller used)."""
        out = {}
        for name, t in self._tenants.items():
            out[name] = {
                "weight": t.weight, "priority": t.priority,
                "queue_depth": len(t.queue), "admitted": t.admitted,
                "delivered": t.delivered, "rejected": t.rejected,
                "records_served": t.records_served,
                "timeouts": t.timeouts, "requeues": t.requeues,
                "failed": t.failed,
                "latency_p50": _percentile(t.latencies, 50),
                "latency_p99": _percentile(t.latencies, 99),
            }
        return out


# -- streaming executor ------------------------------------------------------


@dataclasses.dataclass
class StreamBatch:
    """One micro-batch's emitted output (a slice of the output stream)."""

    step: int
    records: Any
    valid: Any
    dropped: int
    delivered: List[Ticket]
    requeued: List[Ticket] = dataclasses.field(default_factory=list)

    def valid_records(self) -> Any:
        v = np.asarray(self.valid)
        return jax.tree.map(lambda a: np.asarray(a)[v], self.records)


class StreamExecutor:
    """Run one ``Dataflow.stream_source()`` pipeline continuously over
    micro-batches fed by a :class:`TenantQueue` (see module docstring).

    ``micro_batch`` is the global records-per-batch (divisible by the mesh
    axis size); short batches are padded with invalid rows so every batch
    has the same shape — the whole stream reuses ONE compiled program.
    ``carry_capacity`` > 0 (per-device rows) enables cross-batch carry for
    pipelines whose last reduce is schema-preserving; 0 disables carry
    (each batch is independent, the output stream is the union of batch
    outputs).

    Sorted stages run through ``inner``'s stage-2 segment-sort path and so
    inherit its ``sort_algo`` / autotuner choice (the choice is resolved at
    first-trace time and cached, so the steady-state zero-recompile
    guarantee is unaffected; ``REPRO_KERNEL_FORCE`` is part of the inner
    compile-cache key).

    ``chaos``: a :class:`~repro.sphere.chaos.ChaosSchedule` (or a single
    batch-armed :class:`~repro.sphere.chaos.FaultPlan`) of faults fired at
    micro-batch boundaries: ``lose_batch`` drops the in-flight batch
    (tickets requeue), ``lose_device`` additionally shrinks the mesh and
    remeshes the carry from the boundary's :class:`StreamCheckpoint`
    (exactly one recompile), and host faults hit the Sector deployment
    wired in via :meth:`attach_sector`. Every fault and recovery appends
    to the schedule's shared, deterministically-replayable audit log.
    """

    def __init__(self, inner: SPMDExecutor, pipeline: Dataflow,
                 micro_batch: int, carry_capacity: int = 0,
                 queue: Optional[TenantQueue] = None,
                 clock: Optional[Callable[[], float]] = None,
                 trace: Optional[Any] = None,
                 chaos: Optional[Any] = None):
        if not pipeline.stream:
            raise ValueError(
                "StreamExecutor needs a Dataflow.stream_source() pipeline "
                "(got a one-shot source; batch executors run those)")
        if micro_batch % inner.axis_size != 0:
            raise ValueError(f"micro_batch={micro_batch} must be divisible "
                             f"by the mesh axis size {inner.axis_size}")
        if carry_capacity:
            _last_reduce_index(pipeline)   # raises if there is no reduce
        if chaos is not None and not hasattr(chaos, "due_at_batch"):
            # a bare FaultPlan rides as a one-entry schedule; seed=0 keeps
            # the plan's own seed untouched ((0*P+0)*P + s == s)
            chaos = ChaosSchedule([chaos], seed=0)
        self.inner = inner
        self.pipeline = pipeline
        self.micro_batch = micro_batch
        self.carry_capacity = carry_capacity
        self.queue = queue if queue is not None else TenantQueue()
        self.trace = trace if trace is not None else NULL_TRACER
        self.chaos: Optional[ChaosSchedule] = chaos
        self._clock = clock or time.monotonic
        self._carry: Optional[Tuple[Any, Any]] = None
        self._codec: Optional[RecordCodec] = None
        self._steps = 0
        self._records_in = 0
        self._batch_failures = 0
        self._run_seconds = 0.0
        self._recoveries = 0
        #: cache_info() of meshes retired by mid-stream recovery — stats()
        #: sums them with the live executor so the "recompile once per
        #: recovery" invariant stays checkable after the mesh shrank
        self._retired_cache: List[Any] = []
        self._checkpoint: Optional[StreamCheckpoint] = None
        self._sector: Optional[Dict[str, Any]] = None
        #: the carry buffer's GLOBAL row capacity is frozen at construction
        #: (not re-derived from the current mesh) so a stream that loses
        #: devices before its first carried batch still allocates the same
        #: global state as the fault-free run
        self._carry_cap_total = carry_capacity * inner.axis_size

    # -- submission ----------------------------------------------------------
    def submit(self, records: Any, tenant: str = "default",
               timeout: Optional[float] = -1.0,
               now: Optional[float] = None) -> Ticket:
        """Admit one request: a record pytree (its leading dim is the cost).
        All requests must share one schema; a request larger than a
        micro-batch is rejected outright (it could never be dispatched)."""
        records = jax.tree.map(np.asarray, records)
        codec = RecordCodec.from_example(records)
        if self._codec is None:
            self._codec = codec
        elif self._codec != codec:
            raise ValueError(f"request schema {codec} differs from the "
                             f"stream's {self._codec}")
        cost = _leading(records)
        if cost == 0 or cost > self.micro_batch:
            raise ValueError(f"request of {cost} records cannot ride a "
                             f"{self.micro_batch}-record micro-batch")
        return self.queue.admit(tenant, records, cost=cost, timeout=timeout,
                                now=self._now(now))

    def _now(self, now: Optional[float]) -> float:
        return self._clock() if now is None else now

    # -- the continuous loop -------------------------------------------------
    def step(self, now: Optional[float] = None) -> Optional[StreamBatch]:
        """One micro-batch: expire deadlines, admit a fair batch, seal a
        :class:`~repro.sphere.chaos.StreamCheckpoint` (carry + in-flight
        ticket ids), run Sector upkeep and any due chaos faults, run the
        compiled pipeline once, deliver. Returns None on an idle tick (or a
        failed batch, whose tickets are requeued)."""
        now = self._now(now)
        self.queue.expire(now)
        tickets = self.queue.acquire(self.micro_batch, now=now)
        if not tickets:
            return None
        tr = self.trace
        ckpt = StreamCheckpoint.seal(self._steps, tickets, self._carry)
        self._checkpoint = ckpt
        if self._sector is not None:
            self._sector_boundary(ckpt, now, tr)
        if self.chaos is not None:
            failed = self._fire_chaos(tickets, ckpt, now, tr)
            if failed is not None:
                return failed
        batch, valid, n = self._assemble(tickets)
        if self.carry_capacity and self._carry is None:
            self._carry = self._init_carry(batch, valid)
        with tr.span(f"stream.batch[{self._steps}]", records=n,
                     tenants=sorted({t.tenant for t in tickets}),
                     admission_wait_max=max(now - t.admitted_at
                                            for t in tickets)) as bsp:
            t0 = time.monotonic()
            with self.inner.mesh:
                res = self.inner.run(self.pipeline, batch, valid=valid,
                                     carry=self._carry,
                                     trace=tr if tr.enabled else None)
            dropped = int(res.dropped)
            self._run_seconds += time.monotonic() - t0
            if self.carry_capacity:
                self._carry = res.carry
            if tr.enabled:
                carry_rows = (int(np.asarray(self._carry[1]).sum())
                              if self._carry is not None else 0)
                bsp.set(dropped=dropped, carry_rows=carry_rows)
        self._steps += 1
        self._records_in += n
        REGISTRY.counter("stream.batches").inc()
        REGISTRY.counter("stream.records").inc(n)
        delivered = [t for t in tickets if self.queue.complete(t, now=now)]
        return StreamBatch(step=self._steps, records=res.records,
                           valid=res.valid, dropped=dropped,
                           delivered=delivered)

    def drain(self, max_steps: int = 10_000) -> List[StreamBatch]:
        """Step until the admission queue is empty (or ``max_steps``)."""
        out = []
        while self.queue.pending() and max_steps > 0:
            b = self.step()
            if b is not None:
                out.append(b)
            max_steps -= 1
        return out

    # -- durability + chaos --------------------------------------------------
    def attach_sector(self, master: Any, client: Any, daemon: Any = None,
                      detector: Any = None, prefix: str = "/stream/ckpt",
                      retain: int = 8) -> None:
        """Make the stream durable against Sector faults: at every
        micro-batch boundary the sealed :class:`StreamCheckpoint` is
        uploaded to a *versioned* path (``{prefix}.{step:06d}``; the last
        ``retain`` are kept), the :class:`~repro.sector.master.FailureDetector`
        ticks on the stream clock, newly-down slaves trigger
        ``client.recover`` over the retained checkpoints (counted in
        ``stats()["recoveries"]``), and finally the
        :class:`~repro.sector.master.ReplicationDaemon` runs its lazy
        re-replication pass. Host-level chaos faults (``kill_slave``,
        ``rejoin_slave``, ``drop_bucket``) in the schedule fire against
        this deployment and target the retained checkpoint paths."""
        self._sector = {"master": master, "client": client, "daemon": daemon,
                        "detector": detector, "prefix": prefix,
                        "retain": max(1, int(retain)), "paths": []}

    def _sector_boundary(self, ckpt: StreamCheckpoint, now: float,
                         tr: Any) -> None:
        s = self._sector
        client, master = s["client"], s["master"]
        path = f"{s['prefix']}.{ckpt.step:06d}"
        client.upload(path, ckpt.to_bytes())
        s["paths"].append(path)
        while len(s["paths"]) > s["retain"]:
            old = s["paths"].pop(0)
            try:
                client.delete(old)
            except (IOError, OSError, KeyError):
                pass                    # retention GC is best-effort
        det = s["detector"]
        if det is not None:
            newly_down = det.tick(now)
            if newly_down:
                before = master.stats["recoveries"]
                for p in list(s["paths"]):
                    try:
                        client.recover(p)
                    except (IOError, OSError):
                        pass            # daemon will keep trying
                if master.stats["recoveries"] > before:
                    self._recoveries += 1
                    REGISTRY.counter("stream.recoveries").inc()
                    tr.event("sector_recover", step=self._steps,
                             slaves=str(newly_down),
                             checkpoints=len(s["paths"]))
                    if self.chaos is not None:
                        self.chaos.events.append(
                            f"batch {self._steps}: slaves {newly_down} "
                            f"declared down; re-replicated "
                            f"{len(s['paths'])} stream checkpoints")
        if s["daemon"] is not None:
            s["daemon"].tick()

    def _fire_chaos(self, tickets: Sequence[Ticket],
                    ckpt: StreamCheckpoint, now: float,
                    tr: Any) -> Optional[StreamBatch]:
        """Fire every schedule entry armed at this batch. Device loss
        re-forms the mesh *and* abandons the in-flight batch (its tickets
        requeue with full exactly-once protection); ``lose_batch`` only
        abandons; host faults hit the attached Sector deployment and the
        stream keeps running on top of it."""
        failed: Optional[StreamBatch] = None
        sector = self._sector or {}
        for f in self.chaos.due_at_batch(self._steps):
            if f.kind in SPMD_KINDS:
                lost = f.fire_stream(self._steps,
                                     num_devices=self.inner.axis_size)
                self._recover_mesh(int(lost), ckpt, tr)
                if failed is None:
                    failed = self._abandon_batch(tickets, now, tr,
                                                 reason="lose_device")
            elif f.kind in STREAM_KINDS:
                f.fire_stream(self._steps)
                if failed is None:
                    failed = self._abandon_batch(tickets, now, tr,
                                                 reason="lose_batch")
            else:                       # Sector-level host fault
                f.fire_stream(self._steps, master=sector.get("master"),
                              paths=tuple(sector.get("paths", ())))
        return failed

    def _abandon_batch(self, tickets: Sequence[Ticket], now: float,
                       tr: Any, reason: str) -> StreamBatch:
        self._batch_failures += 1
        tr.event("batch_lost", step=self._steps, tickets=len(tickets),
                 reason=reason)
        requeued = [t for t in tickets if self.queue.requeue(t, now=now)]
        return StreamBatch(step=self._steps, records=None,
                           valid=np.zeros((0,), bool), dropped=0,
                           delivered=[], requeued=requeued)

    def _recover_mesh(self, lost: int, ckpt: StreamCheckpoint,
                      tr: Any) -> None:
        """Mid-stream elastic recovery: re-form the survivor mesh, restore
        the carry from the just-sealed checkpoint onto it (the FULL padded
        buffer — global shape unchanged, so exactly one recompile), swap
        the inner executor, count the recovery."""
        from repro.train import elastic
        inner = self.inner
        nb = self._bucket_constraint()
        with tr.span("stream.recover", step=self._steps, lost_device=lost):
            new_mesh = elastic.shrink_mesh(inner.mesh, inner.axes, lost, nb)
            new_inner = inner._sub_executor(new_mesh)
            if self._carry is not None:
                self._carry = ckpt.restore_carry(new_mesh, inner.axes)
            self._retired_cache.append(inner.cache_info())
            self.inner = new_inner
        if self.micro_batch % new_inner.axis_size:
            raise AssertionError(   # unreachable: new extent divides old
                "survivor mesh must divide the micro-batch")
        self._recoveries += 1
        REGISTRY.counter("stream.recoveries").inc()
        shape = dict(zip(inner.axes,
                         (new_mesh.shape[a] for a in inner.axes)))
        self.chaos.events.append(
            f"batch {self._steps}: resumed stream on mesh {shape} "
            f"({new_inner.axis_size} devices); carry remeshed, "
            f"{len(ckpt.ticket_ids)} tickets requeued")

    def _bucket_constraint(self) -> int:
        """gcd of the pipeline's explicit bucket counts — the same contract
        :meth:`SPMDExecutor.run` enforces for chaos/resume: every shuffle
        and sort must pin its bucket count, or the auto default (the axis
        size) would change under the shrunken mesh."""
        nbs = []
        for ph in _phases(self.pipeline):
            t = ph.terminator
            if t is None:
                continue
            nb = t.num_buckets
            if (nb is None and isinstance(t, SortStage)
                    and t.splitters is not None):
                nb = int(np.asarray(t.splitters).shape[0]) + 1
            if nb is None:
                raise ValueError(
                    "mid-stream elastic recovery needs an explicit "
                    "num_buckets (or sort splitters) on every shuffle/sort "
                    "stage — an auto bucket count would change when the "
                    "mesh shrinks")
            nbs.append(nb)
        return math.gcd(*nbs) if nbs else self.inner.axis_size

    # -- batch assembly / carry ----------------------------------------------
    def _assemble(self, tickets: Sequence[Ticket]):
        rows = [t.payload for t in tickets]
        n = sum(t.cost for t in tickets)
        pad = self.micro_batch - n
        merged = jax.tree.map(lambda *xs: np.concatenate(xs, axis=0), *rows)
        if pad:
            merged = jax.tree.map(
                lambda a: np.concatenate(
                    [a, np.zeros((pad,) + a.shape[1:], a.dtype)], axis=0),
                merged)
        valid = np.zeros((self.micro_batch,), bool)
        valid[:n] = True
        return merged, valid, n

    def _init_carry(self, batch, valid) -> Tuple[Any, Any]:
        """Zero carry state, shaped like the final reduce's output schema
        (derived by abstract evaluation — no compile, no FLOPs). Also
        enforces the carry contract: the reduce must be schema-preserving."""
        df = self.pipeline
        carry_at = _last_reduce_index(df)

        def prefix(records, valid, upto):
            valid = valid.reshape(-1)
            for stage in df.stages[:upto]:
                if isinstance(stage, MapStage):
                    records = stage.fn(records)
                    if _leading(records) != valid.shape[0]:
                        valid = jnp.ones((_leading(records),), jnp.bool_)
                elif isinstance(stage, ReduceStage):
                    records, valid, _ = _split_reduce_out(
                        stage.fn(records, valid))
                    valid = valid.reshape(-1)
                # shuffle/sort: schema-preserving, leading dim irrelevant
            return records

        def schema_of(upto):
            shape = jax.eval_shape(lambda r, v: prefix(r, v, upto),
                                   batch, valid)
            leaves, treedef = jax.tree.flatten(shape)
            return treedef, tuple((l.shape[1:], jnp.dtype(l.dtype))
                                  for l in leaves)

        t_in, in_schema = schema_of(carry_at)
        t_out, out_schema = schema_of(carry_at + 1)
        if (t_in, in_schema) != (t_out, out_schema):
            raise ValueError(
                "streaming carry requires a schema-preserving reduce (its "
                "output is fed back into its input next batch); got input "
                f"schema {in_schema} vs output {out_schema}")
        cap = self._carry_cap_total
        leaves = [jnp.zeros((cap,) + tuple(s), d) for s, d in out_schema]
        return (jax.tree.unflatten(t_out, leaves),
                jnp.zeros((cap,), jnp.bool_))

    def carry_state(self) -> Optional[Any]:
        """Dense numpy view of the current cross-batch aggregate (the valid
        carry rows), or None before the first carried batch."""
        if self._carry is None:
            return None
        rec, valid = self._carry
        v = np.asarray(valid)
        return jax.tree.map(lambda a: np.asarray(a)[v], rec)

    # -- stats ---------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """Executor + per-tenant serving stats: throughput, compile-cache
        counters (zero recompiles after warm-up <=> ``misses`` frozen; a
        mesh-shrinking recovery adds exactly one miss — retired meshes'
        counters are summed in), queue depths, latency percentiles,
        timeout/requeue counts, mid-stream recoveries."""
        infos = [*self._retired_cache, self.inner.cache_info()]
        cache = infos[-1]._asdict()
        for key in ("hits", "misses", "evictions"):
            cache[key] = sum(getattr(i, key) for i in infos)
        secs = max(self._run_seconds, 1e-9)
        return {
            "steps": self._steps,
            "records_in": self._records_in,
            "records_per_s": self._records_in / secs,
            "run_seconds": self._run_seconds,
            "batch_failures": self._batch_failures,
            "recoveries": self._recoveries,
            "cache": cache,
            "tenants": self.queue.stats(),
        }
