"""Sphere Processing Engine (paper §3.3).

The SPE loop, verbatim from the paper:

  1. accept a new data segment (file name, offset, rows, params);
  2. read the segment (+ its .idx index) from local disk or another slave;
  3. run the processing function on records / groups / the whole segment,
     writing results to the proper destinations, with periodic progress acks;
  4. ack segment completion; release when the client closes.

Here an SPE executes a Python/JAX UDF over bytes fetched through the Sector
master (locality is the scheduler's job). ``result`` is returned to the
client (engine) or routed to bucket files via the engine's bucket writer —
including the paper's local-dump-first fault-tolerance contract.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import numpy as np

from repro.core.records import RecordCodec
from repro.core.stream import SegmentInfo
from repro.obs.trace import NULL_TRACER
from repro.sector.master import Master
from repro.sector.topology import NodeAddress


class SegmentLost(IOError):
    """The SPE itself is healthy but its input segment could not be fetched
    from Sector (every listed replica dead or missing). Distinguished from a
    plain IOError (SPE crash) so the engine blames the *data*, not the
    worker: the SPE stays in the pool and the engine triggers
    ``SectorClient.recover`` before re-pooling the segment (§3.5.2)."""

    def __init__(self, path: str, reason: str):
        super().__init__(f"segment input {path} lost: {reason}")
        self.path = path


@dataclasses.dataclass
class SPE:
    spe_id: int
    address: NodeAddress
    master: Master
    session_id: int
    #: injected failure: raise IOError after this many segments (None = never)
    fail_after: Optional[int] = None
    segments_done: int = 0

    def read_segment(self, seg: SegmentInfo, record_bytes: int) -> np.ndarray:
        """Step 2: fetch the segment's bytes (whole-file slice + offset)."""
        try:
            data = self.master.download(self.session_id, seg.file_path,
                                        client_addr=self.address)
        except (FileNotFoundError, IOError, OSError) as e:
            raise SegmentLost(seg.file_path, repr(e)) from e
        start = seg.offset * record_bytes
        stop = start + seg.num_records * record_bytes
        chunk = data[start:stop]
        return np.frombuffer(chunk, dtype=np.uint8).reshape(
            seg.num_records, record_bytes)

    def process(self, seg: SegmentInfo, udf: Callable[[np.ndarray], Any],
                record_bytes: int,
                codec: Optional[RecordCodec] = None,
                trace: Optional[Any] = None) -> Any:
        """Steps 1-4 for one segment.

        With a ``codec`` the SPE decodes the raw bytes into the structured
        record pytree before invoking the UDF — the schema travels with the
        shipped UDF, mirroring the paper's ``.idx``-indexed record files.
        With a ``trace`` the read (fetch + decode) and UDF phases become
        ``spe.read`` / ``spe.udf`` sub-spans of the engine's segment span."""
        tr = trace if trace is not None else NULL_TRACER
        if self.fail_after is not None and self.segments_done >= self.fail_after:
            raise IOError(f"SPE {self.spe_id} crashed")
        with tr.span("spe.read", path=seg.file_path,
                     records=seg.num_records):
            records = self.read_segment(seg, record_bytes)
            if codec is not None:
                records = codec.decode(records)
        with tr.span("spe.udf"):
            result = udf(records)
        self.segments_done += 1
        return result
