"""Unified Sphere dataflow: one pipeline description, two executors.

The paper's whole pitch is a *single* simple client API (§3.1):

    SphereStream sdss;  sdss.init(<slices>);
    SphereProcess myproc;  myproc.run(sdss, "myFunc");

This module is that API for the repo. A :class:`Dataflow` is a declarative,
executor-independent chain of stages over *records* (any fixed-shape pytree
of arrays sharing a leading record axis, see
:class:`repro.core.records.RecordCodec`):

    df = (Dataflow.source(codec)
          .map(extract)                      # record-wise UDF
          .shuffle(by=hash_fn, num_buckets=B)  # paper §3.2 bucket shuffle
          .reduce(aggregate))                # per-bucket-group UDF
    # or:  Dataflow.source().sort(key=..., splitters=...)   # paper §4.2

The same pipeline object runs on two executors with identical results:

- :class:`SPMDExecutor` fuses every stage into ONE ``jit(shard_map(...))``
  program: maps/reduces inline per device, shuffles become capacity-bounded
  ``all_to_all`` via :class:`repro.core.shuffle.ShufflePlan` (flat or
  two-level wide-area, all sends through the fused O(n) partition/pack),
  sort stage 2 regroups bucket-major and runs the multi-segment Pallas
  bitonic kernel. Compiled programs are cached keyed on (pipeline, plan,
  input shapes/dtypes).
- :class:`HostExecutor` lowers the same graph onto
  :class:`repro.sphere.engine.SphereProcess` / SPEs over Sector-stored
  files: maps run at the SPEs with locality scheduling and retry, shuffle
  stages materialize **bucket files** back into Sector (the paper's bucket
  handlers), and post-shuffle stages run as the next Sphere stage over
  those buckets.

UDF contracts (shared by both executors — write them once with
``jax.numpy``; on the host path numpy arrays go in and the outputs are
converted back):

- ``map(fn)``: ``fn(records) -> records``. Record-wise / vectorized. On the
  SPMD path padding rows may be present, so the function must be
  padding-oblivious (pure row-wise transforms are). If the leading dimension
  changes (static re-emission), validity resets to all-true; encode
  "emit nothing" by keying the following ``shuffle`` with a negative bucket.
- ``shuffle(by, ...)``: ``by(records) -> (n,) int`` bucket ids; negative or
  out-of-range ids mean "emit nothing".
- ``reduce(fn)``: ``fn(records, valid) -> (records, valid)`` or
  ``(records, valid, dropped)`` — a whole-group UDF (the paper's "the SPE
  processes the whole data segment"). The group is one device's received
  records (SPMD) or one bucket file (host); per-key aggregations see every
  record of a key either way, because the shuffle co-located them.
- ``sort(key, splitters, ...)``: range-partition by ``key`` then sort each
  partition locally — the two-stage terasort of §4.2.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import os
import time
from collections import OrderedDict, namedtuple
from typing import (Any, Callable, Dict, List, NamedTuple, Optional,
                    Sequence, Tuple)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.introspect import collective_counts
from repro.core.records import RecordCodec
from repro.core.shuffle import ShufflePlan, record_hops
from repro.kernels import autotune
from repro.kernels import ops as kops
from repro.obs.metrics import REGISTRY
from repro.obs.trace import NULL_TRACER

_KEY_MAX = np.iinfo(np.int32).max


# -- pipeline description ----------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class MapStage:
    fn: Callable


@dataclasses.dataclass(frozen=True, eq=False)
class ShuffleStage:
    by: Callable
    num_buckets: Optional[int] = None
    capacity_factor: float = 4.0
    chunks: Optional[int] = None          # None -> executor default


@dataclasses.dataclass(frozen=True, eq=False)
class ReduceStage:
    fn: Callable


@dataclasses.dataclass(frozen=True, eq=False)
class SortStage:
    key: Callable
    splitters: Optional[Any] = None       # (num_buckets - 1,) int32 thresholds
    num_buckets: Optional[int] = None
    capacity_factor: float = 2.0
    chunks: Optional[int] = None          # None -> executor default


@dataclasses.dataclass(frozen=True, eq=False)
class Dataflow:
    """An immutable, chainable pipeline of stages (see module docstring).

    ``codec`` is the *source* record schema. Executors that read raw bytes
    (the host executor over Sector files) require it; the SPMD executor
    infers schemas from the arrays it is handed, so it is optional there.
    """

    stages: Tuple[Any, ...] = ()
    codec: Optional[RecordCodec] = None
    #: declared as a *streaming* source (``stream_source``): the stage graph
    #: is meant to run continuously over micro-batches via
    #: :class:`repro.sphere.streaming.StreamExecutor`. Batch executors run
    #: it unchanged (one micro-batch == one batch).
    stream: bool = False

    @classmethod
    def source(cls, codec: Optional[RecordCodec] = None) -> "Dataflow":
        return cls(stages=(), codec=codec)

    @classmethod
    def stream_source(cls, codec: Optional[RecordCodec] = None) -> "Dataflow":
        """A continuous micro-batch source (paper §3.2: "Sphere takes
        streams as inputs and produces streams as outputs"). The same stage
        verbs apply; :class:`repro.sphere.streaming.StreamExecutor` runs the
        graph over an unbounded sequence of fixed-shape micro-batches,
        compiled once."""
        return cls(stages=(), codec=codec, stream=True)

    def _with(self, stage) -> "Dataflow":
        return Dataflow(stages=self.stages + (stage,), codec=self.codec,
                        stream=self.stream)

    def map(self, fn: Callable) -> "Dataflow":
        return self._with(MapStage(fn))

    def shuffle(self, by: Callable, num_buckets: Optional[int] = None,
                capacity_factor: float = 4.0,
                chunks: Optional[int] = None) -> "Dataflow":
        return self._with(ShuffleStage(by, num_buckets, capacity_factor,
                                       chunks))

    def reduce(self, fn: Callable) -> "Dataflow":
        return self._with(ReduceStage(fn))

    def sort(self, key: Callable, splitters: Optional[Any] = None,
             num_buckets: Optional[int] = None,
             capacity_factor: float = 2.0,
             chunks: Optional[int] = None) -> "Dataflow":
        return self._with(SortStage(key, splitters, num_buckets,
                                    capacity_factor, chunks))

    def describe(self) -> str:
        parts = ["stream-source" if self.stream else "source"]
        for st in self.stages:
            if isinstance(st, MapStage):
                parts.append(f"map[{getattr(st.fn, '__name__', '<fn>')}]")
            elif isinstance(st, ShuffleStage):
                parts.append(f"shuffle[{st.num_buckets or 'auto'}]")
            elif isinstance(st, ReduceStage):
                parts.append(f"reduce[{getattr(st.fn, '__name__', '<fn>')}]")
            elif isinstance(st, SortStage):
                parts.append(f"sort[{st.num_buckets or 'auto'}]")
        return " |> ".join(parts)

    def run(self, executor: Any, data: Any, **kwargs: Any) -> "DataflowResult":
        """The paper's §3.1 client call, executor-polymorphic:
        ``df.run(spmd_executor, records, trace=tracer)`` or
        ``df.run(host_executor, sector_paths)``. All keyword arguments
        (``trace=``, ``chaos=``, ``valid=``, ...) pass through to the
        executor's ``run``; the result's ``trace`` handle carries the
        tracer back (``result.trace.to_perfetto("trace.json")``)."""
        return executor.run(self, data, **kwargs)

    def run_stream(self, inner: "SPMDExecutor", micro_batch: int,
                   **kwargs: Any) -> Any:
        """Wrap this ``stream_source`` pipeline in a
        :class:`repro.sphere.streaming.StreamExecutor` (accepts
        ``carry_capacity=``, ``queue=``, ``clock=``, ``trace=``)."""
        from repro.sphere.streaming import StreamExecutor
        return StreamExecutor(inner, self, micro_batch, **kwargs)


@dataclasses.dataclass
class DataflowResult:
    """Executor-independent result.

    records: output pytree. SPMD: padded, globally sharded arrays — mask
             with ``valid``. Host: dense numpy arrays, ``valid`` all-true.
    dropped: records lost to capacity bounds (SPMD shuffles) plus drops
             reported by reduce UDFs, summed over the whole run.
    errors/retries: host-executor fault accounting (empty/0 on SPMD).
    """

    records: Any
    valid: Any
    dropped: Any
    errors: Dict[Any, str] = dataclasses.field(default_factory=dict)
    retries: int = 0
    #: mid-job recoveries fault tolerance performed: Sector re-replications
    #: of lost bucket files (host) or hop-checkpoint resumes (SPMD)
    recoveries: int = 0
    #: segments that permanently failed and are MISSING from ``records``
    #: (every one also appears in ``errors`` with a ``DATA_ERROR:`` prefix)
    data_errors: int = 0
    #: streaming only: the ``(records, valid)`` cross-batch carry state the
    #: run produced (None on one-shot runs) — feed it back as the next
    #: micro-batch's ``carry``. See :mod:`repro.sphere.streaming`.
    carry: Optional[Tuple[Any, Any]] = None
    #: the tracer this run recorded into (None when untraced) — call
    #: ``result.trace.to_perfetto("trace.json")`` / ``result.trace.flame()``.
    trace: Optional[Any] = None
    #: host executor: one dict per phase with wall-clock accounting
    #: (``seconds``, ``engine_s``, ``materialize_s``, segments, retries,
    #: recoveries) — populated even without a tracer, so
    #: ``benchmarks/make_report.py`` can print a phase table.
    phase_times: List[Dict[str, Any]] = dataclasses.field(
        default_factory=list)

    def valid_records(self) -> Any:
        """Dense numpy view: only real records, in device/bucket order."""
        v = np.asarray(self.valid)
        return jax.tree.map(lambda a: np.asarray(a)[v], self.records)


def _split_reduce_out(out):
    if not isinstance(out, tuple) or len(out) not in (2, 3):
        raise ValueError("reduce UDF must return (records, valid) or "
                         "(records, valid, dropped)")
    records, valid = out[0], out[1]
    dropped = out[2] if len(out) == 3 else None
    return records, valid, dropped


def _leading(records) -> int:
    return jax.tree.leaves(records)[0].shape[0]


def _compact_carry(records, valid, cap: int):
    """Compress ``records[valid]`` into a fixed ``cap``-row carry buffer.

    Valid rows move (stably) to the prefix; rows past ``cap`` are dropped and
    counted — the carry is *bounded* state, the same §3.5.1 capacity contract
    as the shuffle. Returns ``(carry_records, carry_valid, dropped)``."""
    valid = valid.reshape(-1)
    n = valid.shape[0]
    if n < cap:
        records = jax.tree.map(
            lambda a: jnp.pad(a, ((0, cap - n),) + ((0, 0),) * (a.ndim - 1)),
            records)
        valid = jnp.pad(valid, (0, cap - n))
    order = jnp.argsort(jnp.logical_not(valid), stable=True)
    top = order[:cap].astype(jnp.int32)
    carry = jax.tree.map(lambda a: jnp.take(a, top, axis=0), records)
    nvalid = jnp.sum(valid.astype(jnp.int32))
    cvalid = jnp.arange(cap, dtype=jnp.int32) < jnp.minimum(nvalid, cap)
    dropped = jnp.maximum(nvalid - cap, 0)
    return carry, cvalid, dropped


def _last_reduce_index(df: Dataflow) -> int:
    idx = [i for i, s in enumerate(df.stages) if isinstance(s, ReduceStage)]
    if not idx:
        raise ValueError(
            "cross-batch carry state needs a reduce stage to merge into — "
            f"pipeline is {df.describe()}")
    return idx[-1]


#: ``SPMDExecutor.cache_info()`` result, ``functools.lru_cache`` style plus
#: an eviction counter: steady-state streaming asserts ``misses`` stops
#: growing after warm-up (zero recompiles per micro-batch).
CacheInfo = namedtuple("CacheInfo",
                       ["hits", "misses", "evictions", "currsize", "maxsize"])


class _CacheEntry(NamedTuple):
    """One compiled program. ``fn`` is the AOT-compiled executable
    (``jit(...).lower(args).compile()`` — compile cost is paid exactly once
    per entry, separable from execute time under a tracer). ``hops`` is the
    static shuffle-hop geometry captured at lowering time via
    :func:`repro.core.shuffle.record_hops`; ``collectives`` the jaxpr
    collective counts (only computed when the entry was built under an
    active tracer — the extra trace is not free)."""

    pipeline: "Dataflow"
    fn: Callable
    has_sort: bool
    hops: List[dict]
    collectives: Optional[Dict[str, int]]


_STAGE_KIND = {MapStage: "map", ShuffleStage: "shuffle",
               ReduceStage: "reduce", SortStage: "sort"}


# -- SPMD executor -----------------------------------------------------------


class SPMDExecutor:
    """Runs a :class:`Dataflow` as one compiled SPMD program.

    All stages fuse into a single ``jit(shard_map(...))``: per-device UDFs
    inline, shuffles as capacity-bounded collectives over ``axes`` (one axis
    = flat ``all_to_all``; a ``(dc, node)`` pair or a hierarchical ``plan`` =
    the two-level wide-area path). Every shuffle hop ships exactly one
    fused wire tensor (``wire_meta="min"`` — the executor regroups from the
    records themselves, so no per-record metadata rides the wire), and
    ``chunks`` sets the pipeline depth of every hop (``None`` defers to the
    explicit ``plan``'s chunks, or 1; a per-stage ``chunks`` overrides
    both). Compiled programs are cached on (pipeline identity, plan, input
    shapes/dtypes) in an LRU bounded by ``cache_size``, so re-running the
    same pipeline object on same-shaped data costs zero retracing while
    long-lived executors cannot accumulate compiled programs without bound.

    ``sort_algo`` pins the stage-2 segment-sort kernel (``"bitonic"`` /
    ``"radix"`` / ``"oracle"``); ``None`` defers to the backend-aware
    autotuner (:mod:`repro.kernels.autotune`) — measured once per segment
    geometry, replayed from cache afterwards — except that the legacy
    ``use_pallas=True`` keeps its historical meaning and pins
    ``"bitonic"``. ``REPRO_KERNEL_FORCE`` overrides everything (and is part
    of the compile-cache key, so flipping it between runs retraces).

    ``debug_checks`` (on by default) validates, after each run of a
    pipeline containing a sort, that no real record key collided with the
    stage-2 padding sentinel (the key dtype's maximum) **while an unstable
    sort kernel is selected** — the bitonic network could silently swap
    such keys with padding slots. Stable kernels (radix, oracle) keep real
    keys ahead of the suffix padding, so max-value keys are delivered
    correctly and the check never fires. The check costs one scalar device
    sync per run; pass ``debug_checks=False`` to skip it.
    """

    def __init__(self, mesh: Mesh, axes: Sequence[str] = ("data",),
                 plan: Optional[ShufflePlan] = None,
                 use_pallas: bool = False,
                 chunks: Optional[int] = None,
                 cache_size: int = 32,
                 debug_checks: bool = True,
                 sort_algo: Optional[str] = None):
        self.mesh = mesh
        self.plan = plan
        self.axes = tuple(plan.axes) if plan is not None else tuple(
            (axes,) if isinstance(axes, str) else axes)
        self.use_pallas = use_pallas
        self.sort_algo = (sort_algo if sort_algo is not None
                          else ("bitonic" if use_pallas else None))
        self.chunks = chunks
        self.cache_size = cache_size
        self.debug_checks = debug_checks
        # LRU keyed on (pipeline id, plan, shapes/dtypes/shardings). Entries
        # hold a strong ref to the pipeline: while cached, its id() cannot be
        # reused by a new object, so an id-keyed hit is always the same
        # pipeline; eviction drops the ref together with the entry. Input
        # shardings are part of the key because entries store AOT-compiled
        # executables, which (unlike jit dispatch) do not re-specialize when
        # a committed input arrives with a different sharding.
        self._cache: "OrderedDict[Any, _CacheEntry]" = OrderedDict()
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._last_entry: Optional[_CacheEntry] = None
        # chaos/resume + staged-trace machinery: per-hop/per-stage
        # sub-pipelines (pinning their parent so id()-keyed lookups stay
        # sound) and sub-executors per mesh, so repeated runs reuse
        # compiled sub-programs
        self._subflows: Dict[Tuple, Tuple[Dataflow, Dataflow]] = {}
        self._sub_execs: Dict[Any, "SPMDExecutor"] = {}

    @property
    def axis_size(self) -> int:
        return math.prod(self.mesh.shape[a] for a in self.axes)

    def cache_info(self) -> CacheInfo:
        """Compile-cache counters (``functools.lru_cache`` style). A cache
        miss means the pipeline was (re)lowered and traced — steady-state
        streaming asserts ``misses`` is frozen after warm-up."""
        return CacheInfo(self._hits, self._misses, self._evictions,
                         len(self._cache), self.cache_size)

    def run(self, pipeline: Dataflow, records: Any,
            valid: Optional[Any] = None,
            carry: Optional[Tuple[Any, Any]] = None,
            chaos: Optional[Any] = None,
            trace: Optional[Any] = None,
            trace_stages: bool = False) -> DataflowResult:
        """Execute ``pipeline`` over ``records`` sharded along ``axes``.

        ``records``: pytree of global arrays (or a
        :class:`repro.core.stream.SphereStream`, whose ``valid`` is used).

        ``chaos``: a :class:`repro.sphere.chaos.FaultPlan` or
        :class:`~repro.sphere.chaos.ChaosSchedule`. When given, the
        pipeline runs *segmented* — one compiled program per shuffle-hop
        phase, with a :class:`~repro.sphere.chaos.HopCheckpoint` sealed at
        every boundary — instead of one fused program, so an injected
        ``lose_device`` fault can be survived by re-forming a smaller mesh
        and resuming from the last checkpoint. ``kind="none"`` runs the
        segmented path with no fault (it must deliver exactly the fused
        result — asserted in tests/test_chaos.py).

        ``carry``: optional ``(records, valid)`` cross-batch state from the
        previous micro-batch of a *streaming* run. It is concatenated into
        the pipeline's **last reduce stage** input (per device — carry never
        crosses devices, which is sound because the deterministic shuffle
        sends a given key to the same device every batch), and the result
        carries the reduce output back out, compacted to the same fixed
        capacity (overflow is dropped and counted). Requires the reduce UDF
        to be schema-preserving; see :mod:`repro.sphere.streaming`.

        ``trace``: a :class:`repro.obs.trace.Tracer`. The run records
        lower/compile/execute spans (compile separated from execute by AOT
        compilation; execute fenced with ``block_until_ready`` so the span
        covers real device time) with wire-byte, chunk-round and drop
        attributes, and publishes wire-bytes / collective / drop counters
        to the metrics registry. Untraced runs skip everything that would
        force a device sync.

        ``trace_stages``: with a tracer, run one compiled program per stage
        instead of the fused program, so every stage and every shuffle/sort
        hop gets its own span. A profiling mode — per-stage dispatch has
        real overhead and is NOT held to the obs_bench <5% bound.
        """
        from repro.core.stream import SphereStream
        if isinstance(records, SphereStream):
            valid = records.valid if valid is None else valid
            records = records.data
        tr = trace if trace is not None else NULL_TRACER
        if chaos is not None:
            return self._run_segmented(pipeline, records, valid, carry,
                                       chaos, tr)
        if trace_stages and tr.enabled:
            if carry is not None:
                raise ValueError("trace_stages does not compose with "
                                 "streaming carry state")
            return self._run_staged(pipeline, records, valid, tr)
        records = jax.tree.map(jnp.asarray, records)
        n = _leading(records)
        if valid is None:
            valid = jnp.ones((n,), jnp.bool_)
        if carry is not None:
            carry = (jax.tree.map(jnp.asarray, carry[0]),
                     jnp.asarray(carry[1]))
            ckey = (jax.tree.structure(carry[0]),
                    tuple((tuple(l.shape), str(l.dtype))
                          for l in jax.tree.leaves(carry[0])),
                    tuple(carry[1].shape))
        else:
            ckey = None
        leaves = jax.tree.leaves(records)
        key = (id(pipeline), self.plan, self.chunks,
               self.sort_algo, os.environ.get(autotune.FORCE_ENV),
               jax.tree.structure(records),
               tuple((tuple(l.shape), str(l.dtype),
                      str(getattr(l, "sharding", None))) for l in leaves),
               ckey)
        args = ((records, valid, carry[0], carry[1]) if carry is not None
                else (records, valid))
        with tr.span("spmd.run", pipeline=pipeline.describe(),
                     records=n) as root:
            entry = self._cache.get(key)
            if entry is None:
                root.set(cache="miss")
                entry = self._compile_entry(pipeline, args,
                                            carry is not None, key, tr)
            else:
                self._hits += 1
                REGISTRY.counter("spmd.cache.hits").inc()
                self._cache.move_to_end(key)
                root.set(cache="hit")
            self._last_entry = entry
            with tr.span("spmd.execute", hops=len(entry.hops)):
                out = entry.fn(*args)
                if tr.enabled:
                    # fence: the span must cover device time, not dispatch
                    out = jax.block_until_ready(out)
            if carry is not None:
                (out_records, out_valid, dropped, sentinel_hits,
                 c_rec, c_valid) = out
                out_carry = (c_rec, c_valid)
            else:
                out_records, out_valid, dropped, sentinel_hits = out
                out_carry = None
            if self.debug_checks and entry.has_sort and int(sentinel_hits) > 0:
                raise ValueError(
                    f"{int(sentinel_hits)} record key(s) equal the key "
                    f"dtype's maximum — the stage-2 sort padding sentinel — "
                    f"while the unstable 'bitonic' kernel is selected: the "
                    f"network's tie order is unspecified, so they could "
                    f"silently swap with padding slots. Use a stable sort "
                    f"(sort_algo='radix' or 'oracle' — both deliver "
                    f"max-value keys correctly), rescale the keys, or pass "
                    f"debug_checks=False to accept the old silent "
                    f"behaviour.")
            self._record_run(entry, n, dropped, tr, root)
        return DataflowResult(records=out_records, valid=out_valid,
                              dropped=dropped, carry=out_carry, trace=trace)

    # -- compile + per-run accounting -----------------------------------------
    def _compile_entry(self, pipeline: Dataflow, args: Tuple,
                       with_carry: bool, key, tr) -> _CacheEntry:
        self._misses += 1
        REGISTRY.counter("spmd.cache.misses").inc()
        hops: List[dict] = []
        with tr.span("spmd.lower", pipeline=pipeline.describe()):
            jitted = self._lower(pipeline, with_carry=with_carry)
            with record_hops(hops):
                lowered = jitted.lower(*args)
        with tr.span("spmd.compile"):
            fn = lowered.compile()
        collectives = None
        if tr.enabled:
            with tr.span("spmd.introspect"):
                collectives = collective_counts(jitted, *args)
        entry = _CacheEntry(
            pipeline=pipeline, fn=fn,
            has_sort=any(isinstance(s, SortStage) for s in pipeline.stages),
            hops=hops, collectives=collectives)
        self._cache[key] = entry
        if len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)
            self._evictions += 1
            REGISTRY.counter("spmd.cache.evictions").inc()
        return entry

    def _record_run(self, entry: _CacheEntry, n: int, dropped, tr,
                    root) -> None:
        """Publish per-run metrics. Wire bytes replay the hop geometry
        captured at lowering time; drop counts force a device sync, so they
        are only recorded under an active tracer."""
        m = REGISTRY
        m.counter("spmd.runs").inc()
        m.counter("spmd.records_in").inc(n)
        wire = 0
        if entry.hops:
            wire = (sum(h["wire_bytes_per_device"] for h in entry.hops)
                    * self.axis_size)
            m.counter("spmd.shuffle.wire_bytes").inc(wire)
            m.counter("spmd.shuffle.hops").inc(len(entry.hops))
        if not tr.enabled:
            return
        d = int(dropped)
        m.counter("spmd.dropped").inc(d)
        if entry.collectives is not None:
            m.counter("spmd.collectives.all_to_all").inc(
                entry.collectives.get("all_to_all", 0))
        root.set(dropped=d, wire_bytes=wire,
                 hops=[{k: h[k] for k in ("axis", "num_dest", "chunks",
                                          "wire_bytes_per_device")}
                       for h in entry.hops])

    # -- per-stage traced execution -------------------------------------------
    def _stage_flow(self, pipeline: Dataflow, i: int) -> Dataflow:
        key = (id(pipeline), "stage", i)
        hit = self._subflows.get(key)
        if hit is not None and hit[0] is pipeline:
            return hit[1]
        sub = Dataflow(stages=(pipeline.stages[i],), codec=pipeline.codec)
        self._subflows[key] = (pipeline, sub)
        return sub

    def _run_staged(self, df: Dataflow, records: Any, valid: Any,
                    tr) -> DataflowResult:
        """One compiled program per stage, so every stage — and every
        shuffle/sort hop — is its own span with wire-byte and chunk
        attributes. Delivers the same records as the fused program (each
        stage is a one-stage sub-pipeline over the identical shard
        layout)."""
        records = jax.tree.map(jnp.asarray, records)
        if valid is None:
            valid = jnp.ones((_leading(records),), jnp.bool_)
        total_dropped = 0
        with tr.span("spmd.run.staged", pipeline=df.describe(),
                     stages=len(df.stages)) as root:
            for i, stage in enumerate(df.stages):
                kind = _STAGE_KIND[type(stage)]
                name = (f"hop[{i}]:{kind}" if kind in ("shuffle", "sort")
                        else f"stage[{i}]:{kind}")
                with tr.span(name) as sp:
                    res = self.run(self._stage_flow(df, i), records,
                                   valid=valid, trace=tr)
                    records, valid = res.records, res.valid
                    d = int(res.dropped)
                    total_dropped += d
                    attrs: Dict[str, Any] = {"dropped": d}
                    entry = self._last_entry
                    if entry is not None and entry.hops:
                        attrs["wire_bytes_per_device"] = sum(
                            h["wire_bytes_per_device"] for h in entry.hops)
                        attrs["chunks"] = entry.hops[0]["chunks"]
                    sp.set(**attrs)
            root.set(dropped=total_dropped)
        return DataflowResult(records=records, valid=valid,
                              dropped=total_dropped, trace=tr)

    # -- segmented execution + device-loss recovery ---------------------------
    def _sub_executor(self, mesh: Mesh) -> "SPMDExecutor":
        sub = self._sub_execs.get(mesh)
        if sub is None:
            sub = SPMDExecutor(mesh, axes=self.axes, plan=None,
                               use_pallas=self.use_pallas, chunks=self.chunks,
                               cache_size=self.cache_size,
                               debug_checks=self.debug_checks,
                               sort_algo=self.sort_algo)
            self._sub_execs[mesh] = sub
        return sub

    def _subflow(self, pipeline: Dataflow, pi: int, phase) -> Dataflow:
        key = (id(pipeline), pi)
        hit = self._subflows.get(key)
        if hit is not None and hit[0] is pipeline:
            return hit[1]
        stages = tuple(phase.stages)
        if phase.terminator is not None:
            stages = stages + (phase.terminator,)
        sub = Dataflow(stages=stages, codec=pipeline.codec)
        self._subflows[key] = (pipeline, sub)
        return sub

    def _run_segmented(self, pipeline: Dataflow, records: Any, valid: Any,
                       carry, chaos, tr=NULL_TRACER) -> DataflowResult:
        """Run ``pipeline`` one shuffle-hop phase at a time, sealing a
        :class:`~repro.sphere.chaos.HopCheckpoint` at every boundary; on an
        injected device loss, re-form the largest usable smaller mesh
        (``elastic.shrink_mesh``) and resume the interrupted hop from the
        checkpoint (``elastic.remesh`` re-shards the layout-agnostic byte
        rows — every old shard lands whole on one new device, so the
        delivered multiset is identical to the fault-free run)."""
        from repro.sphere.chaos import (HOST_KINDS, STREAM_KINDS,
                                        HopCheckpoint, plan_kinds)
        from repro.train import elastic

        for kind in plan_kinds(chaos):
            if kind in HOST_KINDS:
                raise ValueError(
                    f"{kind!r} is a Sector-level fault; inject it via "
                    f"HostExecutor.run(chaos=...)")
            if kind in STREAM_KINDS:
                raise ValueError(
                    f"{kind!r} is a streaming fault; inject it via "
                    f"StreamExecutor(chaos=...)")
        if carry is not None:
            raise ValueError("chaos injection does not compose with "
                             "streaming carry state")
        if self.plan is not None:
            raise ValueError("chaos/resume re-forms the mesh on device loss "
                             "and cannot honor an explicit ShufflePlan; "
                             "construct the executor with axes=... instead")
        phases = _phases(pipeline)
        # the bucket layout must be pinned up front: after a device loss an
        # auto bucket count (= axis_size) would silently re-bucket the data
        nbs = []
        for ph in phases:
            t = ph.terminator
            if t is None:
                continue
            nb = t.num_buckets
            if (nb is None and isinstance(t, SortStage)
                    and t.splitters is not None):
                nb = int(np.asarray(t.splitters).shape[0]) + 1
            if nb is None:
                raise ValueError(
                    "chaos/resume needs an explicit num_buckets (or sort "
                    "splitters) on every shuffle/sort stage — an auto bucket "
                    "count would change when the mesh shrinks")
            nbs.append(nb)
        nb_constraint = math.gcd(*nbs) if nbs else self.axis_size

        records = jax.tree.map(jnp.asarray, records)
        if valid is None:
            valid = jnp.ones((_leading(records),), jnp.bool_)
        exec_ = self._sub_executor(self.mesh)
        dropped = 0
        recoveries = 0
        for pi, phase in enumerate(phases):
            # seal the hop: the checkpoint survives whatever dies next
            ckpt = HopCheckpoint.snapshot(records, valid, pi, dropped)
            lost = chaos.fire_spmd(pi, exec_.axis_size)
            if lost is not None:
                with tr.span(f"recover[{pi}]", lost_device=lost):
                    new_mesh = elastic.shrink_mesh(exec_.mesh, self.axes,
                                                   lost, nb_constraint)
                    exec_ = self._sub_executor(new_mesh)
                    records, valid = ckpt.restore(new_mesh, self.axes)
                    dropped = ckpt.dropped
                    recoveries += 1
                    REGISTRY.counter("spmd.recoveries").inc()
                chaos.events.append(
                    f"resumed hop {pi} on mesh "
                    f"{dict(zip(self.axes, (new_mesh.shape[a] for a in self.axes)))}")
            with tr.span(f"phase[{pi}]", devices=exec_.axis_size) as psp:
                res = exec_.run(self._subflow(pipeline, pi, phase), records,
                                valid=valid,
                                trace=tr if tr.enabled else None)
                records, valid = res.records, res.valid
                d = int(res.dropped)
                dropped += d
                psp.set(dropped=d)
        return DataflowResult(records=records, valid=valid,
                              dropped=dropped, recoveries=recoveries,
                              trace=tr if tr.enabled else None)

    # -- lowering -------------------------------------------------------------
    def _lower(self, df: Dataflow, with_carry: bool = False) -> Callable:
        spec = P(self.axes[0]) if len(self.axes) == 1 else P(self.axes)
        axes = self.axes
        carry_at = _last_reduce_index(df) if with_carry else -1

        def body(records, valid, carry_records, carry_valid):
            valid = valid.reshape(-1)
            dropped = jnp.zeros((), jnp.int32)
            sentinel = jnp.zeros((), jnp.int32)
            new_carry = (None, None)
            for i, stage in enumerate(df.stages):
                if isinstance(stage, MapStage):
                    records = stage.fn(records)
                    if _leading(records) != valid.shape[0]:
                        valid = jnp.ones((_leading(records),), jnp.bool_)
                elif isinstance(stage, ReduceStage):
                    if i == carry_at:
                        # merge last batch's aggregate into this group; the
                        # reduce output below becomes the next batch's carry
                        records = jax.tree.map(
                            lambda a, c: jnp.concatenate([a, c], axis=0),
                            records, carry_records)
                        valid = jnp.concatenate(
                            [valid, carry_valid.reshape(-1)])
                    records, valid, rd = _split_reduce_out(
                        stage.fn(records, valid))
                    valid = valid.reshape(-1)
                    if rd is not None:
                        dropped += jax.lax.psum(
                            jnp.asarray(rd, jnp.int32), axes)
                    if i == carry_at:
                        cap = carry_valid.reshape(-1).shape[0]
                        c_rec, c_valid, c_drop = _compact_carry(
                            records, valid, cap)
                        new_carry = (c_rec, c_valid)
                        dropped += jax.lax.psum(
                            c_drop.astype(jnp.int32), axes)
                elif isinstance(stage, ShuffleStage):
                    ids = jnp.asarray(stage.by(records)).reshape(-1)
                    records, valid, d, _ = self._exchange(
                        records, valid, ids, stage.num_buckets,
                        stage.capacity_factor, stage.chunks)
                    dropped += d
                elif isinstance(stage, SortStage):
                    records, valid, d, hits = self._sort(records, valid,
                                                         stage)
                    dropped += d
                    sentinel += hits
                else:
                    raise TypeError(f"unknown stage {stage!r}")
            return records, valid, dropped, sentinel, new_carry

        if with_carry:
            def local(records, valid, carry_records, carry_valid):
                records, valid, dropped, sentinel, (c_rec, c_valid) = body(
                    records, valid, carry_records, carry_valid)
                return records, valid, dropped, sentinel, c_rec, c_valid

            mapped = shard_map(local, mesh=self.mesh,
                               in_specs=(spec, spec, spec, spec),
                               out_specs=(spec, spec, P(), P(), spec, spec),
                               check_vma=False)
            return jax.jit(mapped)

        def local(records, valid):
            records, valid, dropped, sentinel, _ = body(records, valid,
                                                        None, None)
            return records, valid, dropped, sentinel

        mapped = shard_map(local, mesh=self.mesh, in_specs=(spec, spec),
                           out_specs=(spec, spec, P(), P()), check_vma=False)
        return jax.jit(mapped)

    def _stage_plan(self, num_buckets: Optional[int], n_local: int,
                    capacity_factor: float,
                    chunks: Optional[int]) -> ShufflePlan:
        # precedence: stage chunks > executor chunks > plan chunks > 1
        w = chunks if chunks is not None else self.chunks
        if self.plan is not None:
            if num_buckets not in (None, self.plan.num_buckets):
                raise ValueError(
                    f"stage wants {num_buckets} buckets but the executor "
                    f"plan has {self.plan.num_buckets}")
            if w is None or w == self.plan.chunks:
                return self.plan
            return dataclasses.replace(self.plan, chunks=w)
        nb = num_buckets or self.axis_size
        return ShufflePlan.for_mesh(self.mesh, nb, n_local, capacity_factor,
                                    self.axes, use_pallas=self.use_pallas,
                                    chunks=1 if w is None else w)

    def _exchange(self, records, valid, ids, num_buckets, capacity_factor,
                  chunks=None):
        """One bucket shuffle: pack -> plan.shuffle -> unpack. The wire
        carries pure payload rows (``wire_meta="min"``): every post-shuffle
        consumer here regroups from the decoded records, so bucket/src
        metadata would be dead bytes."""
        codec = RecordCodec.from_example(records)
        packed = codec.pack(records)
        plan = self._stage_plan(num_buckets, packed.shape[0], capacity_factor,
                                chunks)
        res = plan.shuffle(packed, ids.astype(jnp.int32), valid=valid,
                           wire_meta="min")
        flat = res.data.reshape(-1, codec.nbytes)
        return codec.unpack(flat), res.valid.reshape(-1), res.dropped, plan

    def _sort(self, records, valid, stage: SortStage):
        """Range-partition shuffle (stage 1) + local **segmented** sort
        (stage 2) — paper §4.2 / Fig 3.

        Stage 2 regroups the received records bucket-major with the same
        fused O(n) partition/pack the send path uses, then sorts the
        ``buckets_per_device`` segments independently through the autotuned
        :func:`repro.kernels.ops.sort_kv_segments` entry point (``sort_algo``
        pins bitonic/radix/oracle; ``None`` lets the autotuner measure the
        segment geometry once and replay the cached winner). Because each
        device's buckets are consecutive key ranges,
        concatenating its sorted segments is already globally sorted —
        cutting the sorting-network work from O(R log² R) to
        O(R log² (R/bpd)). With one bucket per device the segment is the
        whole receive buffer and the layout matches the historical path
        exactly. Segments get ``capacity_factor`` headroom over the uniform
        share; records past a segment's capacity are dropped *and counted*
        (the same §3.5.1 bounded-skew contract as the shuffle itself —
        impossible when ``buckets_per_device == 1``).

        Returns ``(records, valid, dropped, sentinel_hits)`` —
        ``sentinel_hits`` counts real received keys equal to the padding
        sentinel (the key dtype's maximum, via
        :func:`repro.kernels.ops.pad_sentinel`), checked host-side by
        :meth:`run` when ``debug_checks``. The count is only taken when the
        resolved kernel is the *unstable* bitonic network — padding sits in
        each segment's suffix, so any stable sort (radix, oracle) keeps
        real max-value keys ahead of it and delivers them correctly; for
        those the hit count is a constant 0 and the guard can never fire.
        """
        nb = (self.plan.num_buckets if self.plan is not None
              else stage.num_buckets or self.axis_size)
        if stage.splitters is not None:
            spl = jnp.asarray(stage.splitters)
            if spl.shape[0] != nb - 1:
                raise ValueError(f"{spl.shape[0]} splitters for {nb} buckets")
        else:
            spl = jnp.linspace(0, _KEY_MAX, nb + 1)[1:-1].astype(jnp.int32)
        keys = jnp.asarray(stage.key(records)).astype(jnp.int32).reshape(-1)
        bucket = jnp.searchsorted(spl, keys, side="right").astype(jnp.int32)
        records, valid, dropped, plan = self._exchange(
            records, valid, bucket, nb, stage.capacity_factor, stage.chunks)

        # stage 2: bucket-major regroup (O(n) partition, stable) ...
        keys = jnp.asarray(stage.key(records)).astype(jnp.int32).reshape(-1)
        sentinel = kops.pad_sentinel(keys.dtype)
        skey = jnp.where(valid, keys, sentinel)
        r = skey.shape[0]
        bpd = plan.buckets_per_device
        seg_cap = (r if bpd == 1 else
                   min(r, int(r / bpd * stage.capacity_factor) + 1))
        # resolve the stage-2 kernel now (trace-time): stability decides
        # whether sentinel-collision accounting is needed at all.
        algo = kops.resolve_sort_algo(bpd, seg_cap, skey.dtype,
                                      self.sort_algo, kv=True)
        if autotune.is_stable(algo):
            sentinel_hits = jnp.zeros((), jnp.int32)
        else:
            sentinel_hits = jax.lax.psum(
                jnp.sum((valid & (keys == sentinel)).astype(jnp.int32)),
                plan.pmean_axes())
        local = (jnp.searchsorted(spl, skey, side="right").astype(jnp.int32)
                 - plan.device_index() * bpd)
        seg_dest = jnp.where(valid, local, bpd)       # invalid -> overflow
        leaves, treedef = jax.tree.flatten(records)
        tiles, in_rng, _, seg_drop = kops.partition_pack(
            [skey] + leaves, seg_dest, bpd, seg_cap,
            use_pallas=self.use_pallas)
        dropped += jax.lax.psum(seg_drop, plan.pmean_axes())

        # ... then one multi-segment sort: bpd rows of seg_cap. Empty slots
        # carry the max-key sentinel so each segment's valid records end up
        # in its prefix — exactly where ``in_rng`` already points (pads sit
        # in the suffix, which stable kernels preserve even on key ties).
        seg_keys = jnp.where(in_rng, tiles[0], sentinel)
        pos = jnp.arange(bpd * seg_cap, dtype=jnp.int32).reshape(bpd, seg_cap)
        _, order = kops.sort_kv_segments(seg_keys, pos, algo=algo)
        order = order.reshape(-1)
        records = jax.tree.unflatten(treedef, [
            jnp.take(t.reshape((bpd * seg_cap,) + t.shape[2:]), order, axis=0)
            for t in tiles[1:]])
        return records, in_rng.reshape(-1), dropped, sentinel_hits


# -- host (Sector/SPE) executor ----------------------------------------------


class _Phase:
    """Consecutive record-wise stages, optionally ended by a shuffle/sort."""

    def __init__(self, stages: List[Any], terminator: Optional[Any]):
        self.stages = stages
        self.terminator = terminator


def _phases(df: Dataflow) -> List[_Phase]:
    out, cur = [], []
    for st in df.stages:
        if isinstance(st, (ShuffleStage, SortStage)):
            out.append(_Phase(cur, st))
            cur = []
        else:
            cur.append(st)
    out.append(_Phase(cur, None))
    return out


def _np_records(records) -> Any:
    return jax.tree.map(np.asarray, records)


_scratch_counter = itertools.count()


class HostExecutor:
    """Runs a :class:`Dataflow` on the Sector/SPE data plane.

    The pipeline splits into phases at shuffle/sort boundaries. Each phase is
    one :class:`repro.sphere.engine.SphereProcess` stage: SPEs decode Sector
    segments through the source codec, run the phase's UDFs, and route the
    (re-encoded) outputs either back to the client or into **bucket files**
    (the paper's §3.2 "bucket writers"), which are uploaded to Sector and
    become the next phase's input stream. Locality scheduling, SPE failure
    retry, and data-error reporting all come from the engine; validity masks
    never appear on this path because host buckets are variable-size (no
    capacity bound -> nothing is dropped by shuffles here).
    """

    def __init__(self, master, client, spes: Sequence[Any],
                 max_retries: int = 2, scratch_prefix: str = "/.dataflow",
                 daemon: Optional[Any] = None,
                 retry_policy: Optional[Any] = None):
        self.master = master
        self.client = client
        self.spes = list(spes)
        self.max_retries = max_retries
        #: optional :class:`repro.core.retry.RetryPolicy` for the engine's
        #: segment re-pools (None keeps immediate zero-delay retries)
        self.retry_policy = retry_policy
        self.scratch_prefix = scratch_prefix
        #: optional :class:`repro.sector.master.ReplicationDaemon`; when set,
        #: freshly uploaded bucket files are replicated before the next phase
        #: reads them — without it a mid-job slave death can take the only
        #: copy of a bucket with it (a DATA_ERROR, not silent loss)
        self.daemon = daemon

    def run(self, pipeline: Dataflow, file_paths: Sequence[str],
            chaos: Optional[Any] = None,
            trace: Optional[Any] = None) -> DataflowResult:
        """Execute ``pipeline`` over Sector files. ``pipeline.codec`` is
        required: it decodes the source records (record_bytes =
        ``codec.nbytes``).

        ``chaos``: a :class:`repro.sphere.chaos.FaultPlan` or
        :class:`~repro.sphere.chaos.ChaosSchedule` fired at each phase
        boundary (``kill_slave`` / ``drop_bucket`` / ``rejoin_slave``).
        Recovery is
        always armed regardless: segment reads that fail because every
        listed replica is gone trigger ``SectorClient.recover`` (master
        prunes stale locations, rediscovers survivors by §2.2 scan,
        re-replicates) and the segment is re-pooled per §3.5.2.

        ``trace``: a :class:`repro.obs.trace.Tracer` — records
        ``host.run`` → ``phase[i]`` → per-segment spans (with retry /
        recovery sub-spans from the engine) and ``hop[i]:buckets`` spans
        for bucket materialization. Per-phase wall time is ALWAYS
        accounted in ``result.phase_times`` (a cheap ``time.monotonic``
        pair), tracer or not."""
        from repro.sphere.chaos import SPMD_KINDS, STREAM_KINDS, plan_kinds
        from repro.sphere.engine import SphereProcess

        if chaos is not None:
            for kind in plan_kinds(chaos):
                if kind in SPMD_KINDS:
                    raise ValueError(
                        f"{kind!r} is a device-mesh fault; inject it via "
                        f"SPMDExecutor.run(chaos=...)")
                if kind in STREAM_KINDS:
                    raise ValueError(
                        f"{kind!r} is a streaming fault; inject it via "
                        f"StreamExecutor(chaos=...)")

        if pipeline.codec is None:
            raise ValueError("HostExecutor needs Dataflow.source(codec=...) "
                             "to decode Sector records")
        tr = trace if trace is not None else NULL_TRACER
        codec = pipeline.codec
        paths = list(file_paths)
        scratch = f"{self.scratch_prefix}/run{next(_scratch_counter)}"
        errors: Dict[Any, str] = {}
        retries = 0
        dropped = 0
        recoveries = 0
        data_errors = 0
        pending_sort: Optional[SortStage] = None
        phase_times: List[Dict[str, Any]] = []

        phases = _phases(pipeline)
        with tr.span("host.run", pipeline=pipeline.describe(),
                     files=len(paths)) as root:
            for pi, phase in enumerate(phases):
                t0 = time.monotonic()
                term = phase.terminator
                term_kind = ("output" if term is None else
                             _STAGE_KIND[type(term)])
                with tr.span(f"phase[{pi}]", paths=len(paths),
                             terminator=term_kind) as psp:
                    if chaos is not None:
                        chaos.fire_host(pi, self.master, paths, self.spes)
                    proc = SphereProcess(self.master, self.client.session_id,
                                         self.spes,
                                         max_retries=self.max_retries,
                                         retry_policy=self.retry_policy)
                    holder: Dict[str, Any] = {"codec": None, "dropped": 0}
                    udf = self._phase_udf(phase, pending_sort, holder)
                    nb = self._num_buckets(term)
                    if term is not None:
                        def bucket_fn(out):
                            packed, ids = out
                            return {b: packed[ids == b] for b in range(nb)}
                    else:
                        bucket_fn, nb = None, 0
                    # after a shuffle, a bucket file must stay one segment
                    # (one reduce group) — force whole-file segmentation
                    seg_kw = ({} if pi == 0 else
                              {"s_min": 1 << 40, "s_max": 1 << 40})
                    res = proc.run(paths, udf, record_bytes=codec.nbytes,
                                   codec=codec, bucket_fn=bucket_fn,
                                   num_buckets=nb,
                                   recover=self.client.recover,
                                   trace=trace, **seg_kw)
                    retries += res.retries
                    recoveries += res.recoveries
                    data_errors += res.data_errors
                    dropped += holder["dropped"]
                    errors.update({(pi, k): v for k, v in res.errors.items()})
                    out_codec = holder["codec"] or codec
                    psp.set(segments=res.segments_processed, retries=res.retries,
                            recoveries=res.recoveries,
                            data_errors=res.data_errors)
                    materialize_s = 0.0
                    if term is not None:
                        # materialize bucket files as the next phase's input
                        m0 = time.monotonic()
                        with tr.span(f"hop[{pi}]:buckets", buckets=nb):
                            prefix = f"{scratch}/s{pi}"
                            self.client.upload_dataset(
                                prefix,
                                [np.ascontiguousarray(res.outputs[b])
                                 .tobytes() for b in range(nb)])
                            paths = [f"{prefix}.{b:05d}" for b in range(nb)]
                            if self.daemon is not None:
                                # replicate fresh bucket files before
                                # anything can eat them
                                self.daemon.run_until_stable()
                        materialize_s = time.monotonic() - m0
                    elapsed = time.monotonic() - t0
                    phase_times.append({
                        "phase": pi, "terminator": term_kind,
                        "seconds": elapsed, "engine_s": res.elapsed_s,
                        "materialize_s": materialize_s,
                        "segments": res.segments_processed,
                        "retries": res.retries,
                        "recoveries": res.recoveries,
                        "data_errors": res.data_errors,
                    })
                    REGISTRY.histogram("host.phase_seconds").observe(elapsed)
                    if term is None:
                        REGISTRY.counter("host.dropped").inc(dropped)
                        root.set(phases=len(phase_times), dropped=dropped)
                        parts = [res.outputs[i] for i in sorted(res.outputs)]
                        packed = (np.concatenate(parts, axis=0) if parts
                                  else np.zeros((0, out_codec.nbytes),
                                                np.uint8))
                        records = out_codec.decode(packed)
                        return DataflowResult(
                            records=records,
                            valid=np.ones((_leading(records),), bool),
                            dropped=dropped, errors=errors, retries=retries,
                            recoveries=recoveries, data_errors=data_errors,
                            trace=trace, phase_times=phase_times)
                    codec = out_codec
                    pending_sort = (term if isinstance(term, SortStage)
                                    else None)
        raise AssertionError("unreachable: final phase returns")

    # -- phase lowering -------------------------------------------------------
    def _num_buckets(self, term) -> int:
        if term is None:
            return 0
        if term.num_buckets is not None:
            return term.num_buckets
        if isinstance(term, SortStage) and term.splitters is not None:
            return int(np.asarray(term.splitters).shape[0]) + 1
        return len(self.spes)

    def _phase_udf(self, phase: _Phase, pending_sort: Optional[SortStage],
                   holder: Dict[str, Any]) -> Callable:
        """Build the (decoded records) -> packed bytes UDF one SPE runs.

        The output record schema is only known once a segment has been
        processed; it is stashed in ``holder`` so the executor can decode the
        bucket files / final outputs (every segment must agree)."""
        term = phase.terminator
        nb = self._num_buckets(term)

        def udf(records):
            records = _np_records(records)
            if pending_sort is not None:
                # stage 2 of a sort: this segment IS one range partition
                key = np.asarray(pending_sort.key(records))
                order = np.argsort(key, kind="stable")
                records = jax.tree.map(lambda a: a[order], records)
            valid = np.ones((_leading(records),), bool)
            for stage in phase.stages:
                if isinstance(stage, MapStage):
                    records = _np_records(stage.fn(records))
                    if _leading(records) != valid.shape[0]:
                        valid = np.ones((_leading(records),), bool)
                elif isinstance(stage, ReduceStage):
                    records, valid, rd = _split_reduce_out(
                        stage.fn(records, valid))
                    records = _np_records(records)
                    valid = np.asarray(valid).reshape(-1)
                    if rd is not None:
                        holder["dropped"] += int(rd)
                else:
                    raise TypeError(f"unexpected mid-phase stage {stage!r}")
            records = jax.tree.map(lambda a: a[valid], records)
            codec = RecordCodec.from_example(records)
            if holder["codec"] is None:
                holder["codec"] = codec
            elif holder["codec"] != codec:
                raise ValueError("UDF output schema differs across segments: "
                                 f"{holder['codec']} vs {codec}")
            packed = codec.encode(records)
            if term is None:
                return packed
            if isinstance(term, SortStage):
                keys = np.asarray(term.key(records)).astype(np.int32)
                spl = (np.asarray(term.splitters) if term.splitters is not None
                       else np.linspace(0, _KEY_MAX, nb + 1)[1:-1]
                       .astype(np.int32))
                ids = np.searchsorted(spl, keys, side="right")
            else:
                ids = np.asarray(term.by(records)).reshape(-1)
            return packed, ids.astype(np.int64)

        return udf
