"""Client-driven segment scheduler (paper §3.5).

Implements the paper's scheduling rules verbatim:

  1. "Each data segment is assigned to an SPE on the same node if there is
     one available."  (data locality)
  2. "Segments from the same file are processed at the same time unless
     following this rule leaves SPEs idle."  (read concurrency: prefer to
     spread *distinct* files across simultaneously-running SPEs)
  3. "If there are still idle SPEs available ... assign them parts of data
     segments to process in the same order as they occur in the input
     stream."

plus the fault-tolerance and straggler policies of §3.5.2:

  - an SPE that misses its progress heartbeat past ``timeout`` is discarded
    and its segment goes back to the pool (re-executed from scratch — Sphere
    does no SPE checkpointing);
  - near the end, idle SPEs are assigned *duplicates* of still-running
    segments and the client takes whichever copy finishes first;
  - a segment that fails ``max_data_errors`` times with a *data* error (bad
    input / UDF bug) is reported to the client, not retried elsewhere.

The implementation is a deterministic discrete-event simulation: the same
logic drives host-level data-pipeline assignment (``static_assignment``) and
the runnability tests/benchmarks (``run``).
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Set, \
    Tuple, Union

from repro.core.stream import SegmentInfo
from repro.sector.topology import (DIST_CROSS_POD, DIST_SAME_POD,
                                   DIST_SAME_RACK, NodeAddress, distance)


class SegStatus(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"
    DATA_ERROR = "data_error"


@dataclasses.dataclass
class SPEState:
    spe_id: int
    address: NodeAddress
    speed: float = 1.0           # records / second
    alive: bool = True
    fail_at: Optional[float] = None   # injected crash time
    busy_until: float = 0.0
    current: Optional[int] = None     # segment index being processed
    processed: int = 0


@dataclasses.dataclass
class SegmentState:
    info: SegmentInfo
    locations: List[NodeAddress]      # replicas (from the Sector master)
    status: SegStatus = SegStatus.PENDING
    running_on: Set[int] = dataclasses.field(default_factory=set)
    completed_by: Optional[int] = None
    attempts: int = 0
    data_errors: int = 0


@dataclasses.dataclass(frozen=True)
class ScheduleEvent:
    time: float
    kind: str                 # assign / complete / timeout / duplicate / error
    spe_id: int
    segment: int


class DeadlineHeap:
    """Min-heap of ``(deadline, item)`` with lazy invalidation — the same
    stale-event discipline the :class:`SegmentScheduler` simulation uses for
    its timeout events, factored out so live queues (the streaming
    :class:`repro.sphere.streaming.TenantQueue`) can share it.

    Entries are never removed eagerly: when an item's deadline is refreshed
    (requeue) a new entry is pushed and the old one goes stale. ``pop_due``
    hands back ``(deadline, item)`` pairs and the *caller* decides staleness
    (typically: the recorded deadline no longer matches the item's current
    one, or the item already left the state the deadline guarded)."""

    def __init__(self):
        self._heap: List[Tuple[float, int, object]] = []
        self._seq = itertools.count()

    def push(self, deadline: float, item: object) -> None:
        heapq.heappush(self._heap, (deadline, next(self._seq), item))

    def pop_due(self, now: float) -> List[Tuple[float, object]]:
        due = []
        while self._heap and self._heap[0][0] <= now:
            deadline, _, item = heapq.heappop(self._heap)
            due.append((deadline, item))
        return due

    def peek(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)


class SegmentScheduler:
    def __init__(
        self,
        segments: Sequence[SegmentInfo],
        spes: Sequence[SPEState],
        locations: Dict[str, List[NodeAddress]],
        timeout: float = 60.0,
        speculate: bool = True,
        max_data_errors: int = 2,
        remote_read_penalty: Union[float, Mapping[int, float]] = 2.0,
        shuffle_plan=None,
    ):
        """``remote_read_penalty`` is either the legacy scalar (applied to any
        non-local read) or a mapping from topology distance class
        (:mod:`repro.sector.topology` ``DIST_*``) to a slowdown multiplier —
        cross-pod reads ride the WAN and should cost more than same-rack. A
        mapping must price every remote class (1, 2, 3) explicitly so a
        partial map cannot silently make remote reads free; DIST_SAME_NODE
        may be omitted (defaults to 1.0).

        ``shuffle_plan``: an optional :class:`repro.core.shuffle.ShufflePlan`
        (duck-typed — only ``.hierarchical`` is read). When the downstream
        shuffle is hierarchical, segments of one file should stay
        pod-coherent: their bucket output aggregates intra-DC in stage A
        before crossing the WAN once, so scattering a file's segments across
        pods multiplies stage-B traffic. The assignment rules gain a
        pod-coherence tiebreak in that case.
        """
        self.segments = [
            SegmentState(info=s, locations=list(locations.get(s.file_path, [])))
            for s in segments
        ]
        self.spes = {s.spe_id: s for s in spes}
        self.timeout = timeout
        self.speculate = speculate
        self.max_data_errors = max_data_errors
        if isinstance(remote_read_penalty, Mapping):
            missing = {DIST_SAME_RACK, DIST_SAME_POD,
                       DIST_CROSS_POD} - set(remote_read_penalty)
            if missing:
                raise ValueError("remote_read_penalty mapping must price "
                                 f"every remote distance class; missing "
                                 f"{sorted(missing)}")
        self.remote_read_penalty = remote_read_penalty
        self.shuffle_plan = shuffle_plan
        self.events: List[ScheduleEvent] = []

    def _read_penalty(self, dloc: int) -> float:
        """Slowdown multiplier for reading input at topology distance dloc."""
        if isinstance(self.remote_read_penalty, Mapping):
            return float(self.remote_read_penalty.get(dloc, 1.0))
        return 1.0 if dloc == 0 else float(self.remote_read_penalty)

    # -- the paper's assignment rules ------------------------------------
    def _pick_segment(self, spe: SPEState, now: float) -> Optional[int]:
        pending = [i for i, s in enumerate(self.segments)
                   if s.status == SegStatus.PENDING]
        if pending:
            running_files = {self.segments[i].info.file_path
                             for i, s in enumerate(self.segments)
                             if s.status == SegStatus.RUNNING}

            hier = (self.shuffle_plan is not None
                    and getattr(self.shuffle_plan, "hierarchical", False))
            file_pods: Dict[str, Set[int]] = {}
            if hier:
                # pods already committed to each *pending* file — running
                # and completed segments, so affinity survives sequential
                # processing on few SPEs. One O(segments) scan per pick,
                # same cost class as the pending/running_files scans above.
                pending_files = {self.segments[i].info.file_path
                                 for i in pending}
                for s in self.segments:
                    if s.info.file_path not in pending_files:
                        continue
                    pods = file_pods.setdefault(s.info.file_path, set())
                    if s.status == SegStatus.RUNNING:
                        for sid in s.running_on:
                            pods.add(self.spes[sid].address.pod)
                    elif (s.status == SegStatus.DONE
                          and s.completed_by is not None):
                        pods.add(self.spes[s.completed_by].address.pod)

            def rule_key(i: int) -> Tuple:
                seg = self.segments[i]
                # rule 1: locality — min topology distance to a replica
                dloc = min((distance(spe.address, a) for a in seg.locations),
                           default=3)
                # rule 2: prefer files NOT already being read (spread reads
                # over distinct files); but never leave the SPE idle (we are
                # already committed to assigning something).
                same_file_penalty = 1 if seg.info.file_path in running_files else 0
                # rule 2b (two-level shuffle only): keep a file's segments
                # pod-coherent so their bucket output aggregates intra-DC
                # (stage A) before crossing the WAN once in stage B.
                pod_penalty = 0
                if hier:
                    pods = file_pods.get(seg.info.file_path)
                    if pods and spe.address.pod not in pods:
                        pod_penalty = 1
                # rule 3: stream order
                return (dloc, same_file_penalty, pod_penalty, seg.info.index)

            return min(pending, key=rule_key)

        # tail: speculative duplicates of still-running segments (§3.5.2)
        if self.speculate:
            running = [i for i, s in enumerate(self.segments)
                       if s.status == SegStatus.RUNNING
                       and spe.spe_id not in s.running_on]
            if running:
                # duplicate the one that started earliest (most overdue)
                return min(running, key=lambda i: self.segments[i].info.index)
        return None

    def _proc_time(self, spe: SPEState, seg: SegmentState) -> float:
        base = seg.info.num_records / spe.speed
        dloc = min((distance(spe.address, a) for a in seg.locations), default=3)
        return base * self._read_penalty(dloc)  # remote read (rule-1 rationale)

    # -- static assignment for the data pipeline --------------------------
    def static_assignment(self) -> Dict[int, List[int]]:
        """One pass of rules 1-3 assigning every segment to exactly one SPE
        (round-robin over SPEs, locality-greedy). Used to map dataset segments
        to hosts before a training run; no simulation."""
        assignment: Dict[int, List[int]] = {sid: [] for sid in self.spes}
        load = {sid: 0 for sid in self.spes}
        for i, seg in enumerate(self.segments):
            def key(sid: int) -> Tuple:
                spe = self.spes[sid]
                dloc = min((distance(spe.address, a) for a in seg.locations),
                           default=3)
                return (load[sid], dloc, sid)
            best = min(self.spes, key=key)
            assignment[best].append(i)
            load[best] += seg.info.num_records
        return assignment

    # -- discrete-event simulation -----------------------------------------
    def run(self, fail_segments: Optional[Set[int]] = None) -> Dict[str, float]:
        """Simulate the full Sphere process; returns summary stats.

        ``fail_segments``: segment indices whose *data* is bad — every attempt
        raises a data error (paper: reported to client, never retried on
        another SPE beyond max_data_errors).
        """
        fail_segments = fail_segments or set()
        counter = itertools.count()
        heap: List[Tuple[float, int, str, int, int]] = []  # (t, seq, kind, spe, seg)
        now = 0.0
        last_useful = 0.0   # time of the last segment-state transition;
        #                     zombie duplicate completions don't extend it

        def log(kind: str, spe_id: int, seg_i: int, t: float) -> None:
            self.events.append(ScheduleEvent(t, kind, spe_id, seg_i))

        def try_assign(spe: SPEState, t: float) -> None:
            if not spe.alive or spe.current is not None:
                return
            seg_i = self._pick_segment(spe, t)
            if seg_i is None:
                return
            seg = self.segments[seg_i]
            dup = seg.status == SegStatus.RUNNING
            seg.status = SegStatus.RUNNING
            seg.running_on.add(spe.spe_id)
            seg.attempts += 1
            spe.current = seg_i
            dt = self._proc_time(spe, seg)
            spe.busy_until = t + dt
            if spe.fail_at is not None and spe.fail_at < t + dt:
                # SPE dies mid-segment: client sees heartbeat loss at
                # fail time + timeout
                heapq.heappush(heap, (spe.fail_at + self.timeout, next(counter),
                                      "timeout", spe.spe_id, seg_i))
            else:
                heapq.heappush(heap, (t + dt, next(counter),
                                      "complete", spe.spe_id, seg_i))
            log("duplicate" if dup else "assign", spe.spe_id, seg_i, t)

        for spe in self.spes.values():
            try_assign(spe, now)

        while heap:
            now, _, kind, spe_id, seg_i = heapq.heappop(heap)
            spe = self.spes[spe_id]
            seg = self.segments[seg_i]
            if kind == "complete":
                if not spe.alive or spe.current != seg_i:
                    continue  # stale event
                spe.current = None
                if seg.status == SegStatus.DONE:
                    pass  # a speculative twin already finished
                elif seg_i in fail_segments:
                    seg.data_errors += 1
                    seg.running_on.discard(spe_id)
                    log("error", spe_id, seg_i, now)
                    last_useful = now
                    if seg.data_errors >= self.max_data_errors:
                        seg.status = SegStatus.DATA_ERROR
                    else:
                        seg.status = SegStatus.PENDING
                else:
                    seg.status = SegStatus.DONE
                    seg.completed_by = spe_id
                    seg.running_on.discard(spe_id)
                    spe.processed += seg.info.num_records
                    log("complete", spe_id, seg_i, now)
                    last_useful = now
                try_assign(spe, now)
                # completion may free speculation slots for other idle SPEs
                for other in self.spes.values():
                    try_assign(other, now)
            elif kind == "timeout":
                if spe.fail_at is not None and spe.alive:
                    spe.alive = False  # discard the SPE (paper §3.5.2)
                    spe.current = None
                    log("timeout", spe_id, seg_i, now)
                    if seg.status == SegStatus.RUNNING:
                        seg.running_on.discard(spe_id)
                        if not seg.running_on:
                            seg.status = SegStatus.PENDING
                    for other in self.spes.values():
                        try_assign(other, now)

        done = sum(1 for s in self.segments if s.status == SegStatus.DONE)
        err = sum(1 for s in self.segments if s.status == SegStatus.DATA_ERROR)
        return {
            "makespan": last_useful,
            "done": done,
            "data_errors": err,
            "unfinished": len(self.segments) - done - err,
            "attempts": sum(s.attempts for s in self.segments),
        }
