"""Sphere runtime (paper §3.3-3.5): SPEs, the client-driven segment
scheduler (locality rules, straggler duplication, fault tolerance), and the
client orchestration engine.

This layer schedules *host-level* work: which host reads/processes which
Sector segment. Inside a compiled XLA step scheduling is static, so the
paper's dynamic behaviours live where dynamism still exists on a TPU cluster
— the input pipeline, per-host data loading, and checkpoint/restart — and in
the benchmark simulations that reproduce the paper's tables.
"""

from repro.sphere.scheduler import (
    DeadlineHeap, SegmentScheduler, SPEState, SegmentState, ScheduleEvent,
)
from repro.sphere.spe import SPE
from repro.sphere.engine import SphereProcess
from repro.sphere.dataflow import (
    Dataflow, DataflowResult, HostExecutor, SPMDExecutor,
)
from repro.sphere.streaming import (
    QueueFull, StreamBatch, StreamExecutor, TenantQueue, Ticket,
)

__all__ = [
    "DeadlineHeap", "SegmentScheduler", "SPEState", "SegmentState",
    "ScheduleEvent",
    "SPE", "SphereProcess",
    "Dataflow", "DataflowResult", "HostExecutor", "SPMDExecutor",
    "QueueFull", "StreamBatch", "StreamExecutor", "TenantQueue", "Ticket",
]
