"""Sphere client orchestration (paper §3.4): ``SphereProcess.run``.

"The client is responsible for orchestrating the complete running of each
Sphere process" — it segments the input stream (§3.5.1), assigns segments to
SPEs (scheduler rules), tracks per-segment status, retries failed segments on
other SPEs, reports UDF/data errors back to the application, and collects
results (or routes them to bucket files for the next stage).

This host-level engine actually executes UDFs over data stored in Sector —
it is what `examples/inverted_index.py` and the Terasort data plane use. The
in-XLA analogue of the same pattern is :func:`repro.core.udf.sphere_map`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.records import RecordCodec
from repro.core.retry import RetryPolicy
from repro.core.stream import SegmentInfo, SphereStream
from repro.obs.metrics import MS_BUCKETS, REGISTRY
from repro.obs.trace import NULL_TRACER
from repro.sector.master import Master
from repro.sector.topology import NodeAddress
from repro.sphere.spe import SPE, SegmentLost


@dataclasses.dataclass
class SphereResult:
    #: per-segment UDF outputs, indexed by segment index
    outputs: Dict[int, Any]
    #: segments that permanently failed with data/UDF errors (paper: reported
    #: to the application, not silently retried forever)
    errors: Dict[int, str]
    #: total SPE-level retries that fault tolerance absorbed
    retries: int
    #: mid-job Sector recoveries (lost bucket re-replicated from a survivor)
    recoveries: int = 0
    #: permanently failed segments surfaced as DATA_ERROR in ``errors`` —
    #: a non-zero count means the output is *incomplete*, not just retried
    data_errors: int = 0
    #: wall-clock seconds the whole stage took (one engine run = one phase
    #: of the host dataflow) — a cheap ``time.monotonic`` pair, recorded
    #: whether or not a tracer is attached
    elapsed_s: float = 0.0
    #: input segments successfully processed (NOT the bucket count —
    #: ``outputs`` is re-keyed by bucket when a ``bucket_fn`` is active)
    segments_processed: int = 0

    def concat(self) -> np.ndarray:
        parts = [self.outputs[i] for i in sorted(self.outputs)]
        return np.concatenate(parts, axis=0)


class SphereProcess:
    """myproc.run(stream, udf) — the paper's client API (§3.1 pseudo-code)."""

    def __init__(self, master: Master, session_id: int,
                 spes: Sequence[SPE], max_retries: int = 2,
                 retry_policy: Optional[RetryPolicy] = None,
                 sleep: Optional[Callable[[float], None]] = None):
        self.master = master
        self.session_id = session_id
        self.spes = list(spes)
        self.max_retries = max_retries
        #: backoff between segment re-pools; the zero-base default keeps
        #: retries immediate while still recording the (zero) delays
        self.retry_policy = (RetryPolicy() if retry_policy is None
                             else retry_policy)
        self._sleep = time.sleep if sleep is None else sleep

    def _backoff(self, tr: Any, seg_i: int, attempt: int,
                 reason: str) -> None:
        """Account one re-pool: delay per the policy (keyed by segment so
        concurrent retriers de-synchronize), record it in the
        ``host.backoff_ms`` histogram, and stamp the ``retry`` trace event
        with the attempt number and the delay actually taken."""
        d = self.retry_policy.delay(max(0, attempt - 1), key=seg_i)
        REGISTRY.histogram("host.backoff_ms",
                           bounds=MS_BUCKETS).observe(d * 1e3)
        tr.event("retry", segment=seg_i, reason=reason, attempt=attempt,
                 delay_ms=round(d * 1e3, 3))
        if d > 0:
            self._sleep(d)

    def segment_stream(self, file_paths: Sequence[str], record_bytes: int,
                       s_min: int = 1, s_max: int = 1 << 30,
                       ) -> List[SegmentInfo]:
        files: List[Tuple[str, int]] = []
        total = 0
        for p in file_paths:
            meta = self.master.lookup(p)
            if meta is None:
                raise FileNotFoundError(p)
            nrec = meta.size // record_bytes
            files.append((p, nrec))
            total += nrec
        return SphereStream.plan_segments(
            total, record_bytes, files, s_min=s_min, s_max=s_max,
            num_spes=len(self.spes))

    def run(
        self,
        file_paths: Sequence[str],
        udf: Callable[[np.ndarray], Any],
        record_bytes: int,
        bucket_fn: Optional[Callable[[Any], Dict[int, Any]]] = None,
        num_buckets: int = 0,
        codec: Optional[RecordCodec] = None,
        s_min: int = 1,
        s_max: int = 1 << 30,
        recover: Optional[Callable[[str], Any]] = None,
        trace: Optional[Any] = None,
    ) -> SphereResult:
        """Execute ``udf`` over every segment; optionally route outputs to
        buckets (``bucket_fn`` maps a UDF output to {bucket_id: records}),
        which become the input stream of the next stage.

        ``codec``: when given, SPEs decode each raw ``(n, record_bytes)``
        byte segment into a structured record pytree before calling ``udf``
        (the paper ships the UDF library *to* the SPE; the record schema
        rides along). ``s_min``/``s_max`` are the §3.5.1 segment-size clamp
        in bytes — pass a huge ``s_min`` to force whole-file segments (one
        bucket file = one reduce group for the dataflow host executor).

        ``recover``: called with the Sector path of a segment whose input
        bytes could not be fetched (every listed replica dead/missing, see
        :class:`repro.sphere.spe.SegmentLost`). Normally
        ``SectorClient.recover`` — it restores the file from a surviving
        copy so the re-pooled segment succeeds; if it raises IOError the
        data is truly gone and the segment becomes a DATA_ERROR.

        ``trace``: a :class:`repro.obs.trace.Tracer` — each segment
        attempt becomes a ``segment[i]`` span (with the SPE's read/udf
        sub-spans) annotated with its outcome; recoveries become nested
        ``recover[i]`` spans and re-pools emit ``retry`` instant events."""
        tr = trace if trace is not None else NULL_TRACER
        t_start = time.monotonic()
        segments = self.segment_stream(file_paths, record_bytes,
                                       s_min=s_min, s_max=s_max)
        outputs: Dict[int, Any] = {}
        errors: Dict[int, str] = {}
        buckets: Dict[int, List[Any]] = {b: [] for b in range(num_buckets)}
        retries = 0
        recoveries = 0

        # locality-greedy assignment, then round-robin execution with retry
        pending = list(range(len(segments)))
        rr = 0
        attempt: Dict[int, int] = {i: 0 for i in pending}
        live = [s for s in self.spes]
        while pending:
            seg_i = pending.pop(0)
            seg = segments[seg_i]
            if not live:
                errors[seg_i] = "no live SPEs"
                continue
            # rule 1: prefer an SPE co-located with a replica
            locs = [self.master.slaves[s].address
                    for s in (self.master.lookup(seg.file_path).locations)
                    if s in self.master.slaves and self.master.slaves[s].alive]
            def loc_key(spe: SPE):
                from repro.sector.topology import distance
                d = min((distance(spe.address, a) for a in locs), default=3)
                return (d, spe.segments_done, spe.spe_id)
            if locs:
                spe = min(live, key=loc_key)
            else:
                # round-robin only advances when it actually picked — a
                # locality hit must not burn an rr slot for other segments
                spe = live[rr % len(live)]
                rr += 1
            with tr.span(f"segment[{seg_i}]", spe=spe.spe_id,
                         records=seg.num_records,
                         attempt=attempt[seg_i]) as ssp:
                try:
                    out = spe.process(seg, udf, record_bytes, codec=codec,
                                      trace=trace)
                except SegmentLost as e:          # input data lost; SPE fine
                    ssp.set(outcome="segment_lost")
                    attempt[seg_i] += 1
                    if recover is not None:
                        try:
                            with tr.span(f"recover[{seg_i}]", path=e.path):
                                recover(e.path)
                            recoveries += 1
                            REGISTRY.counter("host.recoveries").inc()
                        except (IOError, OSError) as gone:
                            errors[seg_i] = f"DATA_ERROR: {gone}"
                            REGISTRY.counter("host.data_errors").inc()
                            continue
                    if attempt[seg_i] > self.max_retries + len(self.spes):
                        errors[seg_i] = f"DATA_ERROR: gave up: {e}"
                        REGISTRY.counter("host.data_errors").inc()
                    else:
                        retries += 1
                        REGISTRY.counter("host.retries").inc()
                        self._backoff(tr, seg_i, attempt[seg_i],
                                      reason="segment_lost")
                        pending.append(seg_i)     # re-pool (paper §3.5.2)
                    continue
                except (IOError, OSError) as e:   # SPE/node failure
                    ssp.set(outcome="spe_failure")
                    live = [s for s in live if s is not spe]
                    attempt[seg_i] += 1
                    retries += 1
                    REGISTRY.counter("host.retries").inc()
                    if attempt[seg_i] > self.max_retries + len(self.spes):
                        errors[seg_i] = f"DATA_ERROR: gave up: {e}"
                        REGISTRY.counter("host.data_errors").inc()
                    else:
                        self._backoff(tr, seg_i, attempt[seg_i],
                                      reason="spe_failure")
                        pending.append(seg_i)     # reassign (paper §3.5.2)
                    continue
                except Exception as e:            # data/UDF error
                    ssp.set(outcome="udf_error")
                    attempt[seg_i] += 1
                    if attempt[seg_i] >= self.max_retries:
                        # report to application, *counted*: the output is
                        # missing this segment, the caller must be able to
                        # tell
                        errors[seg_i] = f"DATA_ERROR: {e!r}"
                        REGISTRY.counter("host.data_errors").inc()
                    else:
                        retries += 1
                        REGISTRY.counter("host.retries").inc()
                        self._backoff(tr, seg_i, attempt[seg_i],
                                      reason="udf_error")
                        pending.append(seg_i)
                    continue
                ssp.set(outcome="ok")
                REGISTRY.counter("host.segments").inc()
            outputs[seg_i] = out
            if bucket_fn is not None:
                # the paper: SPE dumps results locally first, then sends to
                # bucket handlers; handler accepts per-segment data exactly once
                for b, recs in bucket_fn(out).items():
                    buckets[b].append(recs)

        result = SphereResult(
            outputs=outputs, errors=errors, retries=retries,
            recoveries=recoveries,
            data_errors=sum(1 for v in errors.values()
                            if v.startswith("DATA_ERROR")),
            elapsed_s=time.monotonic() - t_start,
            segments_processed=len(outputs))
        if bucket_fn is not None:
            # an empty bucket must keep the records' dtype and trailing dims
            # (np.zeros((0,)) would silently decay to 1-D float64)
            exemplar = next((recs[0] for recs in buckets.values() if recs),
                            None)
            def empty() -> np.ndarray:
                if exemplar is None:
                    return np.zeros((0,))
                return np.zeros((0,) + exemplar.shape[1:], exemplar.dtype)
            result.outputs = {
                b: (np.concatenate(v, axis=0) if v else empty())
                for b, v in buckets.items()
            }
        return result
