"""Deterministic fault injection for Sphere dataflows.

The paper's fault-tolerance story (§2.2 lazy re-replication, §3.5.2 SPE
re-pooling) is only worth anything if a *running* job survives it. This
module is the chaos layer that proves it: a :class:`FaultPlan` describes one
failure — which kind, at which phase boundary, against which victim — and
the executors consult it at every boundary. Faults are seeded and replayable:
the same plan against the same deployment kills the same slave / drops the
same bucket / loses the same device every time, so the chaos test matrix in
``tests/test_chaos.py`` is a deterministic property suite, not a flake
generator.

Fault kinds and the recovery path each one exercises:

``kill_slave``   (HostExecutor) — a storage node dies (optionally with its
    disk, ``wipe=True``) and every SPE co-located with it crashes on its next
    segment. Survived by master routing around dead slaves + §3.5.2 segment
    re-pooling + the replication daemon restoring the replica count.

``drop_bucket``  (HostExecutor) — one input file of the target phase rots
    away from *every* slave the master's index lists, while one unlisted
    survivor copy exists (the copy is stashed slave-to-slave, bypassing the
    index — modelling the index going stale while bytes survive, e.g. after
    a partial node recovery). The read fails with
    :class:`~repro.sphere.spe.SegmentLost`; the engine calls
    ``SectorClient.recover``; the master prunes the stale locations, finds
    the survivor by the §2.2 directory scan, re-replicates, and the re-pooled
    segment succeeds.

``lose_device``  (SPMDExecutor) — one device of the mesh is lost at a
    shuffle-hop boundary. Survived by the hop checkpoint (layout-agnostic
    byte rows, the same property ``train/elastic.py`` exploits): the
    executor re-forms the largest usable smaller mesh
    (:func:`repro.train.elastic.shrink_mesh`), re-shards the checkpoint onto
    it (:func:`repro.train.elastic.remesh`) and resumes the interrupted hop.

``rejoin_slave`` (HostExecutor / streaming) — a previously-killed storage
    node comes back: the slave restarts and the master re-absorbs whatever
    survives on its disk via the §2.2 scan path (``register_slave``). With a
    :class:`~repro.sector.master.FailureDetector` attached, its resumed
    heartbeats also flip the detector's belief back to alive.

``lose_batch``   (StreamExecutor) — the in-flight micro-batch is lost at a
    batch boundary; every ticket of the batch is requeued through
    :class:`~repro.sphere.streaming.TenantQueue` (exactly-once preserved)
    and re-dispatched on a later step.

``none``         — no fault; with ``SPMDExecutor.run(chaos=...)`` it still
    forces the segmented per-hop execution path, which is how the tests
    prove segmented == fused before trusting the recovery runs.

Single faults are described by a :class:`FaultPlan`; an ordered *sequence*
of faults — armed at phase boundaries (``phase=``) or stream batch indices
(``at_batch=``) — is a :class:`ChaosSchedule`, which derives every member's
seed from its own seed + position and shares one audit log, so a multi-fault
run replays byte-identically too.

The headline invariant, asserted by ``tests/test_chaos.py``: **the delivered
multiset is unchanged under any single injected failure between stage A and
stage B**, for both executors and both (flat / hierarchical) topologies.
PR 10 extends it to streams: a continuously-serving StreamExecutor under a
multi-fault schedule delivers the same snapshot as the fault-free one-shot
batch run, with zero duplicate ticket deliveries.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Any, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.records import RecordCodec

HOST_KINDS = ("kill_slave", "drop_bucket", "rejoin_slave")
SPMD_KINDS = ("lose_device",)
STREAM_KINDS = ("lose_batch",)
KINDS = ("none",) + HOST_KINDS + SPMD_KINDS + STREAM_KINDS


def plan_kinds(chaos: Any) -> Tuple[str, ...]:
    """The fault kinds a plan or schedule can fire — the executors' guard
    rails accept either a :class:`FaultPlan` (``.kind``) or a
    :class:`ChaosSchedule` (``.kinds``)."""
    kinds = getattr(chaos, "kinds", None)
    if kinds is not None:
        return tuple(kinds)
    return (chaos.kind,)


@dataclasses.dataclass
class FaultPlan:
    """One injected failure, fully determined by its fields + ``seed``.

    ``phase`` is the phase-boundary index at which the fault fires:
    boundary ``b`` is *before* phase ``b`` runs (0 = before the first
    phase, i.e. against the source files / initial shards; 1 = between the
    first and second phase — "between stage A and stage B").

    ``victim`` pins the target (slave id for ``kill_slave``/``rejoin_slave``,
    global device index for ``lose_device``); ``path`` pins the file for
    ``drop_bucket``. When unset, the target is drawn from a
    ``random.Random(seed)`` over the *sorted* candidate set — deterministic
    per (plan, deployment).

    ``at_batch`` arms the fault at a StreamExecutor micro-batch boundary
    instead of a phase boundary: batch ``b`` means *before* micro-batch
    ``b`` is dispatched. Batch-armed faults are fired via
    :meth:`fire_stream` (normally through a :class:`ChaosSchedule` given to
    ``StreamExecutor(chaos=...)``) and are ignored by the batch executors'
    ``fire_host`` / ``fire_spmd``.
    """

    kind: str = "none"
    phase: int = 1
    victim: Optional[int] = None
    path: Optional[str] = None
    #: ``kill_slave``: also lose the disk (the harsher variant)
    wipe: bool = True
    seed: int = 0
    #: arm at a stream micro-batch index instead of a phase boundary
    at_batch: Optional[int] = None
    fired: bool = dataclasses.field(default=False, init=False)
    #: human-readable audit log of what was actually broken
    events: List[str] = dataclasses.field(default_factory=list, init=False)

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; one of {KINDS}")

    def _rng(self) -> random.Random:
        # integer mix, NOT hash(tuple): str hashes vary per-process with
        # PYTHONHASHSEED, and a chaos plan must replay identically anywhere
        mix = 0
        batch = -1 if self.at_batch is None else self.at_batch
        for part in (self.seed, KINDS.index(self.kind), self.phase, batch):
            mix = mix * 1000003 + part
        return random.Random(mix)

    # -- host (Sector/SPE) faults -------------------------------------------
    def fire_host(self, boundary: int, master, paths: Sequence[str],
                  spes: Sequence[Any] = ()) -> bool:
        """Called by :class:`~repro.sphere.dataflow.HostExecutor` at every
        phase boundary with that phase's input ``paths``. Injects the fault
        iff this is the armed boundary; returns whether it fired."""
        if (self.fired or self.at_batch is not None
                or boundary != self.phase or self.kind not in HOST_KINDS):
            return False
        self._fire_host_kind(f"boundary {boundary}", master, paths, spes)
        self.fired = True
        return True

    def _fire_host_kind(self, label: str, master, paths: Sequence[str],
                        spes: Sequence[Any]) -> None:
        if self.kind == "kill_slave":
            self._kill_slave(label, master, paths, spes)
        elif self.kind == "rejoin_slave":
            self._rejoin_slave(label, master)
        else:
            self._drop_bucket(label, master, paths)

    def _kill_slave(self, label: str, master, paths: Sequence[str],
                    spes: Sequence[Any]) -> None:
        if self.victim is not None:
            slave = master.slaves[self.victim]
        else:
            holders = set()
            for p in paths:
                meta = master.lookup(p)
                if meta is not None:
                    holders |= meta.locations
            cands = [master.slaves[s] for s in sorted(holders)
                     if s in master.slaves and master.slaves[s].alive]
            if not cands:
                cands = sorted(master.live_slaves(), key=lambda s: s.slave_id)
            if not cands:
                raise RuntimeError("kill_slave: no live slave to kill")
            slave = self._rng().choice(cands)
        slave.kill(wipe=self.wipe)
        crashed = []
        for spe in spes:
            if spe.address == slave.address:
                # its next segment raises IOError -> engine re-pools (§3.5.2)
                spe.fail_after = spe.segments_done
                crashed.append(spe.spe_id)
        self.events.append(
            f"{label}: killed slave {slave.slave_id} "
            f"at {slave.address}{' (disk wiped)' if self.wipe else ''}; "
            f"crashed SPEs {crashed}")

    def _rejoin_slave(self, label: str, master) -> None:
        if self.victim is not None:
            slave = master.slaves[self.victim]
        else:
            dead = sorted((s for s in master.slaves.values() if not s.alive),
                          key=lambda s: s.slave_id)
            if not dead:
                raise RuntimeError("rejoin_slave: no dead slave to rejoin")
            slave = self._rng().choice(dead)
        slave.restart()
        # the §2.2 scan path re-absorbs whatever survived on its disk; a
        # FailureDetector, if one is watching, also re-registers on the
        # slave's next heartbeat — both are idempotent
        master.register_slave(slave)
        self.events.append(
            f"{label}: slave {slave.slave_id} rejoined at {slave.address} "
            f"(incarnation {slave.incarnation}); "
            f"re-absorbed {len(slave.scan())} files by scan")

    def _drop_bucket(self, label: str, master, paths: Sequence[str]) -> None:
        cands = []
        for p in sorted(set(paths)):
            meta = master.lookup(p)
            if meta is None:
                continue
            if any(s in master.slaves and master.slaves[s].has_file(p)
                   for s in meta.locations):
                cands.append(p)
        if self.path is not None:
            path = self.path
        elif cands:
            path = self._rng().choice(cands)
        else:
            raise RuntimeError("drop_bucket: no input file with a live copy")
        meta = master.lookup(path)
        holders = [s for s in sorted(meta.locations)
                   if s in master.slaves and master.slaves[s].has_file(path)]
        data = master.slaves[holders[0]].read_file(path)
        # stash one survivor copy on a slave the index does NOT list, writing
        # slave-to-slave behind the master's back: the index is now fully
        # stale and only the §2.2 scan in recover_file can find the bytes
        hide = [s for s in master.live_slaves()
                if s.slave_id not in meta.locations
                and s.available_bytes() >= meta.size]
        hide.sort(key=lambda s: s.slave_id)
        keep: Optional[int] = None
        if hide:
            stash = self._rng().choice(hide)
            stash.write_file(path, data)
            where = f"stashed unlisted copy on slave {stash.slave_id}"
        else:
            # every live slave is a listed holder: keep one, drop the rest —
            # the index is still stale (pruned holders) and recovery must run
            keep = holders[-1]
            where = f"kept only listed copy on slave {keep}"
        for sid in holders:
            if sid != keep:
                master.slaves[sid].drop_file(path)
        self.events.append(
            f"{label}: dropped {path} from listed holders "
            f"{[s for s in holders if s != keep]}; {where}")

    # -- SPMD (device) faults -------------------------------------------------
    def fire_spmd(self, boundary: int, num_devices: int) -> Optional[int]:
        """Called by the SPMD executor at every hop boundary. Returns the
        global index of the lost device when the fault fires, else None."""
        if (self.fired or self.at_batch is not None
                or boundary != self.phase or self.kind not in SPMD_KINDS):
            return None
        lost = self._pick_device(num_devices)
        self.fired = True
        self.events.append(
            f"boundary {boundary}: lost device {lost}/{num_devices}")
        return lost

    def _pick_device(self, num_devices: int) -> int:
        lost = (self.victim if self.victim is not None
                else self._rng().randrange(num_devices))
        if not 0 <= lost < num_devices:
            raise ValueError(f"victim device {lost} out of range {num_devices}")
        return lost

    # -- stream (micro-batch boundary) faults ---------------------------------
    def fire_stream(self, batch: int, *, master: Any = None,
                    paths: Sequence[str] = (),
                    num_devices: Optional[int] = None) -> Optional[Any]:
        """Called by :class:`~repro.sphere.streaming.StreamExecutor` at every
        micro-batch boundary (normally via
        :meth:`ChaosSchedule.due_at_batch`). Fires iff this fault is armed at
        batch index ``batch``. Returns the lost device index for
        ``lose_device``, ``True`` for every other kind that fired, ``None``
        when not due.

        Host kinds need the stream's attached Sector deployment (``master``;
        ``paths`` are the stream's durable checkpoint files, the only Sector
        state a pure stream owns)."""
        if self.fired or self.at_batch != batch or self.kind == "none":
            return None
        label = f"batch {batch}"
        if self.kind in SPMD_KINDS:
            if num_devices is None:
                raise ValueError("lose_device needs num_devices")
            lost = self._pick_device(num_devices)
            self.fired = True
            self.events.append(f"{label}: lost device {lost}/{num_devices}")
            return lost
        if self.kind in HOST_KINDS:
            if master is None:
                raise ValueError(
                    f"{self.kind!r} at a batch boundary needs an attached "
                    f"Sector deployment (StreamExecutor.attach_sector)")
            self._fire_host_kind(label, master, paths, spes=())
            self.fired = True
            return True
        # lose_batch: the executor requeues the in-flight tickets
        self.fired = True
        self.events.append(f"{label}: lost in-flight micro-batch")
        return True


class ChaosSchedule:
    """An ordered, seeded sequence of :class:`FaultPlan` faults.

    Every member's seed is re-derived from ``(schedule seed, position, its
    own seed)`` with the same integer mix the plans use, and all members
    share ONE ``events`` audit log — so a multi-fault run carries the same
    deterministic-replay guarantee as a single plan: same schedule + same
    deployment => byte-identical events, in firing order.

    A schedule is a drop-in for a single plan on the batch executors
    (``fire_host`` / ``fire_spmd`` delegate to every *phase-armed* member);
    batch-armed members (``at_batch=``) are consumed by ``StreamExecutor``
    via :meth:`due_at_batch`.
    """

    def __init__(self, faults: Sequence[FaultPlan], seed: int = 0):
        self.seed = seed
        self.faults: List[FaultPlan] = list(faults)
        self.events: List[str] = []
        for i, f in enumerate(self.faults):
            f.seed = (seed * 1000003 + i) * 1000003 + f.seed
            f.events = self.events    # shared, ordered audit log

    @property
    def kinds(self) -> Tuple[str, ...]:
        return tuple(f.kind for f in self.faults)

    @property
    def fired(self) -> bool:
        """True once every member has fired."""
        return all(f.fired for f in self.faults)

    @property
    def fired_count(self) -> int:
        return sum(f.fired for f in self.faults)

    def due_at_batch(self, batch: int) -> List[FaultPlan]:
        """Unfired members armed at stream batch index ``batch``, in order."""
        return [f for f in self.faults
                if not f.fired and f.at_batch == batch]

    def fire_host(self, boundary: int, master, paths: Sequence[str],
                  spes: Sequence[Any] = ()) -> bool:
        fired = False
        for f in self.faults:
            fired = f.fire_host(boundary, master, paths, spes) or fired
        return fired

    def fire_spmd(self, boundary: int, num_devices: int) -> Optional[int]:
        for f in self.faults:
            lost = f.fire_spmd(boundary, num_devices)
            if lost is not None:
                return lost
        return None

    def __repr__(self) -> str:
        arms = [f"{f.kind}@{'batch ' + str(f.at_batch) if f.at_batch is not None else 'phase ' + str(f.phase)}"
                for f in self.faults]
        return f"ChaosSchedule(seed={self.seed}, faults=[{', '.join(arms)}])"


@dataclasses.dataclass
class HopCheckpoint:
    """State of a dataflow at a shuffle-hop boundary, as layout-agnostic
    bytes: the record pytree packed into ``(n, nbytes)`` uint8 rows (the
    exact on-wire/on-disk layout of :class:`~repro.core.records.RecordCodec`)
    plus the validity mask. Because rows are device-order contiguous and a
    shrunken mesh extent always divides the old one
    (:func:`repro.train.elastic.shrink_mesh`), every old per-device shard
    lands whole on one new device at restore — reduce groups and bucket
    segments are never split, which is what makes resume multiset-exact."""

    codec: RecordCodec
    payload: np.ndarray    # (n, codec.nbytes) uint8
    valid: np.ndarray      # (n,) bool
    hop: int
    dropped: int

    @classmethod
    def snapshot(cls, records: Any, valid: Any, hop: int,
                 dropped: int) -> "HopCheckpoint":
        recs = jax.tree.map(np.asarray, records)
        codec = RecordCodec.from_example(recs)
        return cls(codec=codec, payload=codec.encode(recs),
                   valid=np.asarray(valid).reshape(-1).astype(bool),
                   hop=hop, dropped=int(dropped))

    def restore(self, mesh: Mesh, axes: Sequence[str]) -> Tuple[Any, Any]:
        """Decode and re-shard onto ``mesh`` via ``elastic.remesh``; returns
        ``(records, valid)`` device arrays ready to resume hop ``hop``."""
        from repro.train import elastic

        axes = tuple(axes)
        records = self.codec.decode(self.payload)
        spec = P(axes[0]) if len(axes) == 1 else P(axes)
        tree = (records, self.valid)
        specs = jax.tree.map(lambda _: spec, tree)
        return elastic.remesh(tree, mesh, specs)


@dataclasses.dataclass
class StreamCheckpoint:
    """Stream state sealed at a micro-batch boundary: the carry buffer plus
    the in-flight ticket ids of the batch about to be dispatched.

    The carry travels as a :class:`HopCheckpoint` over the FULL padded carry
    buffer (valid and invalid rows alike), *not* a dense compaction: the
    compiled stream function derives its per-device carry capacity from the
    input carry's shape and compacts its output back to the same capacity,
    so keeping the global shape constant across a mesh shrink means exactly
    one recompile — and because per-device slices stay contiguous, restoring
    onto any survivor mesh whose extent divides the old one lands every old
    device's carry whole on the new device that owns its buckets (the same
    layout-agnostic divisor property ``HopCheckpoint`` gives batch hops).

    ``to_bytes``/``from_bytes`` give the checkpoint a byte-deterministic
    durable form for upload into Sector (flat dict-of-array records only —
    every stream pipeline's reduce state in this repo is one).
    """

    step: int
    ticket_ids: Tuple[int, ...]
    carry: Optional[HopCheckpoint]

    MAGIC = b"SCKP1\n"

    @classmethod
    def seal(cls, step: int, tickets: Sequence[Any],
             carry: Optional[Tuple[Any, Any]]) -> "StreamCheckpoint":
        """Seal the boundary before dispatching ``tickets``: ``carry`` is the
        executor's ``(records, valid)`` padded carry pair (or None before the
        first stateful batch)."""
        hc = None
        if carry is not None:
            records, valid = carry
            hc = HopCheckpoint.snapshot(records, valid, hop=int(step),
                                        dropped=0)
        return cls(step=int(step),
                   ticket_ids=tuple(t.req_id for t in tickets), carry=hc)

    def restore_carry(self, mesh: Mesh,
                      axes: Sequence[str]) -> Optional[Tuple[Any, Any]]:
        """Re-shard the padded carry onto ``mesh`` (e.g. the survivor mesh
        after ``lose_device``); None when the stream had no carry yet."""
        if self.carry is None:
            return None
        return self.carry.restore(mesh, axes)

    def to_bytes(self) -> bytes:
        """Byte-deterministic serialization (no timestamps): MAGIC, an
        8-byte little-endian header length, a JSON header, then the raw
        array buffers in header order."""
        import json as _json

        header: dict = {"step": self.step, "tickets": list(self.ticket_ids),
                        "carry": self.carry is not None}
        blobs: List[bytes] = []
        if self.carry is not None:
            recs = self.carry.codec.decode(self.carry.payload)
            if not (isinstance(recs, dict)
                    and all(isinstance(v, np.ndarray) for v in recs.values())):
                raise TypeError(
                    "StreamCheckpoint durability needs flat dict-of-array "
                    f"records, got {jax.tree.structure(recs)}")
            header["hop"] = self.carry.hop
            header["dropped"] = self.carry.dropped
            fields = []
            for name in sorted(recs):
                a = np.ascontiguousarray(recs[name])
                fields.append([name, a.dtype.str, list(a.shape)])
                blobs.append(a.tobytes())
            valid = np.ascontiguousarray(self.carry.valid)
            fields.append(["__valid__", valid.dtype.str, list(valid.shape)])
            blobs.append(valid.tobytes())
            header["fields"] = fields
        head = _json.dumps(header, sort_keys=True).encode()
        out = [self.MAGIC, len(head).to_bytes(8, "little"), head]
        out.extend(blobs)
        return b"".join(out)

    @classmethod
    def from_bytes(cls, data: bytes) -> "StreamCheckpoint":
        import json as _json

        if not data.startswith(cls.MAGIC):
            raise ValueError("not a StreamCheckpoint byte stream")
        off = len(cls.MAGIC)
        hlen = int.from_bytes(data[off:off + 8], "little")
        off += 8
        header = _json.loads(data[off:off + hlen].decode())
        off += hlen
        carry = None
        if header["carry"]:
            arrays = {}
            for name, dtype, shape in header["fields"]:
                n = int(np.prod(shape)) if shape else 1
                nbytes = n * np.dtype(dtype).itemsize
                arrays[name] = np.frombuffer(
                    data[off:off + nbytes], dtype=dtype).reshape(shape)
                off += nbytes
            valid = arrays.pop("__valid__")
            carry = HopCheckpoint.snapshot(arrays, valid, hop=header["hop"],
                                           dropped=header["dropped"])
        return cls(step=header["step"], ticket_ids=tuple(header["tickets"]),
                   carry=carry)
