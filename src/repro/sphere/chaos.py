"""Deterministic fault injection for Sphere dataflows.

The paper's fault-tolerance story (§2.2 lazy re-replication, §3.5.2 SPE
re-pooling) is only worth anything if a *running* job survives it. This
module is the chaos layer that proves it: a :class:`FaultPlan` describes one
failure — which kind, at which phase boundary, against which victim — and
the executors consult it at every boundary. Faults are seeded and replayable:
the same plan against the same deployment kills the same slave / drops the
same bucket / loses the same device every time, so the chaos test matrix in
``tests/test_chaos.py`` is a deterministic property suite, not a flake
generator.

Fault kinds and the recovery path each one exercises:

``kill_slave``   (HostExecutor) — a storage node dies (optionally with its
    disk, ``wipe=True``) and every SPE co-located with it crashes on its next
    segment. Survived by master routing around dead slaves + §3.5.2 segment
    re-pooling + the replication daemon restoring the replica count.

``drop_bucket``  (HostExecutor) — one input file of the target phase rots
    away from *every* slave the master's index lists, while one unlisted
    survivor copy exists (the copy is stashed slave-to-slave, bypassing the
    index — modelling the index going stale while bytes survive, e.g. after
    a partial node recovery). The read fails with
    :class:`~repro.sphere.spe.SegmentLost`; the engine calls
    ``SectorClient.recover``; the master prunes the stale locations, finds
    the survivor by the §2.2 directory scan, re-replicates, and the re-pooled
    segment succeeds.

``lose_device``  (SPMDExecutor) — one device of the mesh is lost at a
    shuffle-hop boundary. Survived by the hop checkpoint (layout-agnostic
    byte rows, the same property ``train/elastic.py`` exploits): the
    executor re-forms the largest usable smaller mesh
    (:func:`repro.train.elastic.shrink_mesh`), re-shards the checkpoint onto
    it (:func:`repro.train.elastic.remesh`) and resumes the interrupted hop.

``none``         — no fault; with ``SPMDExecutor.run(chaos=...)`` it still
    forces the segmented per-hop execution path, which is how the tests
    prove segmented == fused before trusting the recovery runs.

The headline invariant, asserted by ``tests/test_chaos.py``: **the delivered
multiset is unchanged under any single injected failure between stage A and
stage B**, for both executors and both (flat / hierarchical) topologies.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Any, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.records import RecordCodec

HOST_KINDS = ("kill_slave", "drop_bucket")
SPMD_KINDS = ("lose_device",)
KINDS = ("none",) + HOST_KINDS + SPMD_KINDS


@dataclasses.dataclass
class FaultPlan:
    """One injected failure, fully determined by its fields + ``seed``.

    ``phase`` is the phase-boundary index at which the fault fires:
    boundary ``b`` is *before* phase ``b`` runs (0 = before the first
    phase, i.e. against the source files / initial shards; 1 = between the
    first and second phase — "between stage A and stage B").

    ``victim`` pins the target (slave id for ``kill_slave``, global device
    index for ``lose_device``); ``path`` pins the file for ``drop_bucket``.
    When unset, the target is drawn from a ``random.Random(seed)`` over the
    *sorted* candidate set — deterministic per (plan, deployment).
    """

    kind: str = "none"
    phase: int = 1
    victim: Optional[int] = None
    path: Optional[str] = None
    #: ``kill_slave``: also lose the disk (the harsher variant)
    wipe: bool = True
    seed: int = 0
    fired: bool = dataclasses.field(default=False, init=False)
    #: human-readable audit log of what was actually broken
    events: List[str] = dataclasses.field(default_factory=list, init=False)

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; one of {KINDS}")

    def _rng(self) -> random.Random:
        # integer mix, NOT hash(tuple): str hashes vary per-process with
        # PYTHONHASHSEED, and a chaos plan must replay identically anywhere
        mix = 0
        for part in (self.seed, KINDS.index(self.kind), self.phase):
            mix = mix * 1000003 + part
        return random.Random(mix)

    # -- host (Sector/SPE) faults -------------------------------------------
    def fire_host(self, boundary: int, master, paths: Sequence[str],
                  spes: Sequence[Any] = ()) -> bool:
        """Called by :class:`~repro.sphere.dataflow.HostExecutor` at every
        phase boundary with that phase's input ``paths``. Injects the fault
        iff this is the armed boundary; returns whether it fired."""
        if self.fired or boundary != self.phase or self.kind not in HOST_KINDS:
            return False
        if self.kind == "kill_slave":
            self._kill_slave(boundary, master, paths, spes)
        else:
            self._drop_bucket(boundary, master, paths)
        self.fired = True
        return True

    def _kill_slave(self, boundary: int, master, paths: Sequence[str],
                    spes: Sequence[Any]) -> None:
        if self.victim is not None:
            slave = master.slaves[self.victim]
        else:
            holders = set()
            for p in paths:
                meta = master.lookup(p)
                if meta is not None:
                    holders |= meta.locations
            cands = [master.slaves[s] for s in sorted(holders)
                     if s in master.slaves and master.slaves[s].alive]
            if not cands:
                cands = sorted(master.live_slaves(), key=lambda s: s.slave_id)
            if not cands:
                raise RuntimeError("kill_slave: no live slave to kill")
            slave = self._rng().choice(cands)
        slave.kill(wipe=self.wipe)
        crashed = []
        for spe in spes:
            if spe.address == slave.address:
                # its next segment raises IOError -> engine re-pools (§3.5.2)
                spe.fail_after = spe.segments_done
                crashed.append(spe.spe_id)
        self.events.append(
            f"boundary {boundary}: killed slave {slave.slave_id} "
            f"at {slave.address}{' (disk wiped)' if self.wipe else ''}; "
            f"crashed SPEs {crashed}")

    def _drop_bucket(self, boundary: int, master, paths: Sequence[str]) -> None:
        cands = []
        for p in sorted(set(paths)):
            meta = master.lookup(p)
            if meta is None:
                continue
            if any(s in master.slaves and master.slaves[s].has_file(p)
                   for s in meta.locations):
                cands.append(p)
        if self.path is not None:
            path = self.path
        elif cands:
            path = self._rng().choice(cands)
        else:
            raise RuntimeError("drop_bucket: no input file with a live copy")
        meta = master.lookup(path)
        holders = [s for s in sorted(meta.locations)
                   if s in master.slaves and master.slaves[s].has_file(path)]
        data = master.slaves[holders[0]].read_file(path)
        # stash one survivor copy on a slave the index does NOT list, writing
        # slave-to-slave behind the master's back: the index is now fully
        # stale and only the §2.2 scan in recover_file can find the bytes
        hide = [s for s in master.live_slaves()
                if s.slave_id not in meta.locations
                and s.available_bytes() >= meta.size]
        hide.sort(key=lambda s: s.slave_id)
        keep: Optional[int] = None
        if hide:
            stash = self._rng().choice(hide)
            stash.write_file(path, data)
            where = f"stashed unlisted copy on slave {stash.slave_id}"
        else:
            # every live slave is a listed holder: keep one, drop the rest —
            # the index is still stale (pruned holders) and recovery must run
            keep = holders[-1]
            where = f"kept only listed copy on slave {keep}"
        for sid in holders:
            if sid != keep:
                master.slaves[sid].drop_file(path)
        self.events.append(
            f"boundary {boundary}: dropped {path} from listed holders "
            f"{[s for s in holders if s != keep]}; {where}")

    # -- SPMD (device) faults -------------------------------------------------
    def fire_spmd(self, boundary: int, num_devices: int) -> Optional[int]:
        """Called by the SPMD executor at every hop boundary. Returns the
        global index of the lost device when the fault fires, else None."""
        if self.fired or boundary != self.phase or self.kind not in SPMD_KINDS:
            return None
        lost = (self.victim if self.victim is not None
                else self._rng().randrange(num_devices))
        if not 0 <= lost < num_devices:
            raise ValueError(f"victim device {lost} out of range {num_devices}")
        self.fired = True
        self.events.append(
            f"boundary {boundary}: lost device {lost}/{num_devices}")
        return lost


@dataclasses.dataclass
class HopCheckpoint:
    """State of a dataflow at a shuffle-hop boundary, as layout-agnostic
    bytes: the record pytree packed into ``(n, nbytes)`` uint8 rows (the
    exact on-wire/on-disk layout of :class:`~repro.core.records.RecordCodec`)
    plus the validity mask. Because rows are device-order contiguous and a
    shrunken mesh extent always divides the old one
    (:func:`repro.train.elastic.shrink_mesh`), every old per-device shard
    lands whole on one new device at restore — reduce groups and bucket
    segments are never split, which is what makes resume multiset-exact."""

    codec: RecordCodec
    payload: np.ndarray    # (n, codec.nbytes) uint8
    valid: np.ndarray      # (n,) bool
    hop: int
    dropped: int

    @classmethod
    def snapshot(cls, records: Any, valid: Any, hop: int,
                 dropped: int) -> "HopCheckpoint":
        recs = jax.tree.map(np.asarray, records)
        codec = RecordCodec.from_example(recs)
        return cls(codec=codec, payload=codec.encode(recs),
                   valid=np.asarray(valid).reshape(-1).astype(bool),
                   hop=hop, dropped=int(dropped))

    def restore(self, mesh: Mesh, axes: Sequence[str]) -> Tuple[Any, Any]:
        """Decode and re-shard onto ``mesh`` via ``elastic.remesh``; returns
        ``(records, valid)`` device arrays ready to resume hop ``hop``."""
        from repro.train import elastic

        axes = tuple(axes)
        records = self.codec.decode(self.payload)
        spec = P(axes[0]) if len(axes) == 1 else P(axes)
        tree = (records, self.valid)
        specs = jax.tree.map(lambda _: spec, tree)
        return elastic.remesh(tree, mesh, specs)
