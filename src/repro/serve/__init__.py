from repro.serve.engine import Request, ServeEngine, ServeReport

__all__ = ["ServeEngine", "ServeReport", "Request"]
