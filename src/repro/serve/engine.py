"""Batched serving engine: slot-based continuous batching over the registry
models' prefill/decode surface.

The engine mirrors the Sphere client's role (paper §3.4): it orchestrates,
the compiled XLA step is the SPE. Requests are segments; a fixed number of
batch *slots* bounds the working set exactly like the scheduler's segment
capacity clamp; finished slots are refilled from the queue each step
(continuous batching). A request whose UDF (generation) errors is reported,
not retried forever — the paper's data-error contract.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Model
from repro.obs.metrics import REGISTRY
from repro.obs.trace import NULL_TRACER


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray                 # (S,) int32 decoder/prompt tokens
    max_new_tokens: int = 16
    #: enc-dec models: (enc_seq, d_model) frame/patch embeddings (stub
    #: frontend output) to be encoded once at admission
    frames: Optional[np.ndarray] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    #: multi-tenant admission (only read when the engine has a tenant
    #: queue): which tenant the request bills to, and its queue-wait
    #: deadline in engine steps (None = no deadline)
    tenant: str = "default"
    timeout: Optional[float] = None


class ServeReport(list):
    """``run_to_completion`` result: iterates/len()s as the list of finished
    requests (back-compat), plus the work that did NOT finish within
    ``max_steps`` — previously those requests were silently dropped."""

    def __init__(self, done: List[Request], unfinished: List[Request]):
        super().__init__(done)
        self.unfinished = unfinished

    @property
    def completed(self) -> bool:
        return not self.unfinished


class ServeEngine:
    def __init__(self, model: Model, params, batch_slots: int = 4,
                 max_len: int = 256, temperature: float = 0.0, seed: int = 0,
                 tenants=None, trace: Optional[Any] = None):
        """``tenants``: optional :class:`repro.sphere.streaming.TenantQueue`
        (duck-typed). When given, the continuous-batching refill pulls from
        it instead of the plain FIFO: slot refills follow priority classes
        and weighted fair share, queue-waits past a request's deadline
        requeue it (bounded retries), and ``submit`` raises
        :class:`repro.sphere.streaming.QueueFull` as backpressure. Engine
        time is the step counter, so deadlines are in steps.

        ``trace``: a :class:`repro.obs.trace.Tracer`; each engine
        iteration becomes a ``serve.step[i]`` span annotated with active
        slots and tokens emitted."""
        self.model = model
        self.trace = trace if trace is not None else NULL_TRACER
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.queue: deque[Request] = deque()
        self.tenants = tenants
        self.step_count = 0
        self._tickets: Dict[int, object] = {}   # req_id -> Ticket
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.pos = np.zeros((batch_slots,), np.int32)
        self.caches = model.init_caches(batch_slots, max_len)
        self._batch_axes = self._find_batch_axes()
        self.enc_dec = model.cfg.family == "audio"
        if self.enc_dec:
            # per-slot encoder output (cross-attention memory)
            self.enc_out = jnp.zeros(
                (batch_slots, model.cfg.enc_seq, model.cfg.d_model),
                jnp.bfloat16)
        self._decode = jax.jit(
            lambda p, c, b: model.decode_step(p, c, b))

    def _find_batch_axes(self):
        """Per-cache-leaf batch axis, found structurally: the axis whose size
        changes between init_caches(slots) and init_caches(slots+1). Size
        matching is ambiguous (num_layers can equal batch_slots)."""
        a = jax.eval_shape(lambda: self.model.init_caches(self.slots,
                                                          self.max_len))
        b = jax.eval_shape(lambda: self.model.init_caches(self.slots + 1,
                                                          self.max_len))
        axes = []
        for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            diff = [i for i, (x, y) in enumerate(zip(la.shape, lb.shape))
                    if x != y]
            axes.append(diff[0] if diff else None)
        return axes

    def submit(self, req: Request) -> None:
        if self.tenants is not None:
            tk = self.tenants.admit(req.tenant, req, cost=1,
                                    timeout=req.timeout,
                                    now=float(self.step_count))
            self._tickets[req.req_id] = tk
        else:
            self.queue.append(req)

    def _next_request(self) -> Optional[Request]:
        if self.tenants is not None:
            got = self.tenants.acquire(1, now=float(self.step_count))
            return got[0].payload if got else None
        return self.queue.popleft() if self.queue else None

    def _has_pending(self) -> bool:
        return (self.tenants.pending() > 0 if self.tenants is not None
                else bool(self.queue))

    def _prefill_into_slot(self, slot: int, req: Request) -> None:
        """Feed the prompt (all but its final token) through the decode path
        for the slot. The final prompt token is fed by the first ``step()``
        call, whose logits produce the first generated token — feeding the
        whole prompt here would duplicate the last token. Other slots receive
        a benign write at their next position, which the subsequent real
        decode overwrites."""
        if self.enc_dec:
            from repro.models import encdec
            frames = jnp.asarray(req.frames, jnp.bfloat16)[None]
            eo = encdec.encode(self.params, self.model.cfg, frames)[0]
            self.enc_out = self.enc_out.at[slot].set(eo)
        for t, tok in enumerate(req.prompt[:-1]):
            batch = {
                "tokens": jnp.zeros((self.slots, 1), jnp.int32)
                          .at[slot, 0].set(int(tok)),
                "pos": jnp.asarray(self.pos[:, None]).astype(jnp.int32)
                       .at[slot, 0].set(t),
            }
            if self.enc_dec:
                batch["enc_out"] = self.enc_out
            _, self.caches = self._decode(self.params, self.caches, batch)
        self.pos[slot] = len(req.prompt) - 1

    def step(self) -> List[Request]:
        """One engine iteration: refill slots, decode one token for every
        active slot, emit finished requests."""
        tr = self.trace
        with tr.span(f"serve.step[{self.step_count + 1}]") as sp:
            finished = self._step()
            active = sum(r is not None for r in self.active)
            if tr.enabled:
                sp.set(active_slots=active, finished=len(finished))
            if active or finished:
                REGISTRY.counter("serve.steps").inc()
                # every slot active during decode emitted one token,
                # including the ones that finished on it
                REGISTRY.counter("serve.tokens").inc(active + len(finished))
            if finished:
                REGISTRY.counter("serve.finished").inc(len(finished))
        return finished

    def _step(self) -> List[Request]:
        self.step_count += 1
        if self.tenants is not None:
            self.tenants.expire(float(self.step_count))
        # refill
        for s in range(self.slots):
            if self.active[s] is None:
                req = self._next_request()
                if req is None:
                    continue
                self.pos[s] = 0
                self._reset_slot_cache(s)
                self._prefill_into_slot(s, req)
                self.active[s] = req

        if not any(self.active):
            return []

        tokens = np.zeros((self.slots, 1), np.int32)
        for s, req in enumerate(self.active):
            if req is not None:
                last = req.out_tokens[-1] if req.out_tokens else \
                    int(req.prompt[-1])
                tokens[s, 0] = last
        batch = {"tokens": jnp.asarray(tokens),
                 "pos": jnp.asarray(self.pos[:, None])}
        if self.enc_dec:
            batch["enc_out"] = self.enc_out
        logits, self.caches = self._decode(self.params, self.caches, batch)
        logits = np.asarray(logits[:, 0], np.float32)

        finished: List[Request] = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            if self.temperature > 0:
                self.key, sub = jax.random.split(self.key)
                nxt = int(jax.random.categorical(
                    sub, jnp.asarray(logits[s]) / self.temperature))
            else:
                nxt = int(np.argmax(logits[s]))
            req.out_tokens.append(nxt)
            self.pos[s] += 1
            if len(req.out_tokens) >= req.max_new_tokens or \
                    self.pos[s] >= self.max_len - 1:
                req.done = True
                finished.append(req)
                self.active[s] = None
                if self.tenants is not None:
                    tk = self._tickets.pop(req.req_id, None)
                    if tk is not None:
                        self.tenants.complete(tk, now=float(self.step_count))
        return finished

    def _reset_slot_cache(self, slot: int) -> None:
        leaves, treedef = jax.tree.flatten(self.caches)
        out = []
        for leaf, ax in zip(leaves, self._batch_axes):
            if ax is None:
                out.append(leaf)
                continue
            idx = [slice(None)] * leaf.ndim
            idx[ax] = slot
            # the only int32 cache leaves are position maps; empty = -1
            fill = -1 if leaf.dtype == jnp.int32 else 0
            out.append(leaf.at[tuple(idx)].set(fill))
        self.caches = jax.tree.unflatten(treedef, out)

    def run_to_completion(self, max_steps: int = 10_000) -> ServeReport:
        """Step until queue and slots drain, or ``max_steps``. The report
        lists finished requests (it IS that list) *and* whatever was still
        queued or mid-generation when the step budget ran out — exhausting
        ``max_steps`` used to silently drop that in-flight work."""
        done: List[Request] = []
        for _ in range(max_steps):
            done.extend(self.step())
            if not self._has_pending() and not any(self.active):
                break
        unfinished = [r for r in self.active if r is not None]
        if self.tenants is not None:
            unfinished += [tk.payload for tk in self.tenants.pending_items()]
        else:
            unfinished += list(self.queue)
        return ServeReport(done, unfinished)
