"""Nested-span tracer with Perfetto export (paper §4's per-stage timing).

The paper's evaluation lives on per-stage wall-clock breakdowns; this module
is the repo's way to produce them without pulling in an external tracing
stack. Design rules:

- **Explicit clock injection.** ``Tracer(clock=...)`` takes any zero-arg
  callable returning a monotonic float — ``time.perf_counter`` by default,
  a virtual counter in tests (the same discipline as ``TenantQueue``'s
  ``now=`` and ``ReplicationDaemon``'s ``clock=``), so span durations are
  deterministic under test.
- **Nested spans via a per-thread stack.** ``with tracer.span("x"): ...``
  parents to whatever span is open on the *current thread*; the buffer is
  shared and lock-protected, so SPE worker threads can trace concurrently.
- **Spans are cheap and final-on-exit.** A span is appended to the buffer
  once, when it closes; ``Span.set(**attrs)`` may add attributes while it
  is open (e.g. a drop count known only after execution).
- **Tracks.** ``tracer.fork("host")`` returns a tracer writing to the SAME
  buffer under a different track name — one Perfetto file can hold the SPMD
  and host executors side by side as separate threads.

Exports: :meth:`Tracer.to_perfetto` writes Chrome/Perfetto ``trace_event``
JSON (open in https://ui.perfetto.dev or chrome://tracing);
:meth:`Tracer.flame` renders an aggregated plain-text flame summary.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = ["Span", "TraceBuffer", "Tracer", "NullTracer", "NULL_TRACER"]


@dataclasses.dataclass
class Span:
    """One closed (or still-open) span. ``start``/``end`` are in the
    tracer's clock units (seconds under the default clock)."""

    name: str
    start: float
    end: Optional[float] = None
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    span_id: int = 0
    parent_id: Optional[int] = None
    track: str = "main"

    @property
    def duration(self) -> Optional[float]:
        return None if self.end is None else self.end - self.start

    def set(self, **attrs: Any) -> "Span":
        """Attach attributes to the open span (chainable)."""
        self.attrs.update(attrs)
        return self


@dataclasses.dataclass
class _Event:
    """An instant marker (Perfetto ``ph: "i"``) — e.g. a retry."""

    name: str
    ts: float
    attrs: Dict[str, Any]
    parent_id: Optional[int]
    track: str


class TraceBuffer:
    """Thread-safe append-only store of closed spans and instant events.
    Shared between a tracer and its :meth:`Tracer.fork` children."""

    def __init__(self):
        self._lock = threading.Lock()
        self._spans: List[Span] = []
        self._events: List[_Event] = []
        self._ids = itertools.count(1)

    def next_id(self) -> int:
        return next(self._ids)

    def add_span(self, span: Span) -> None:
        with self._lock:
            self._spans.append(span)

    def add_event(self, event: _Event) -> None:
        with self._lock:
            self._events.append(event)

    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._spans)

    def events(self) -> List[_Event]:
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)


def _json_safe(v: Any) -> Any:
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _json_safe(x) for k, x in v.items()}
    try:                          # numpy / jax scalars
        return v.item()
    except (AttributeError, ValueError):
        return str(v)


class Tracer:
    """Span tracer (see module docstring). ``enabled`` distinguishes a real
    tracer from :data:`NULL_TRACER` so hot paths can skip work (device
    syncs, attribute computation) that only matters when tracing."""

    enabled = True

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 buffer: Optional[TraceBuffer] = None, track: str = "main"):
        self.clock = clock if clock is not None else time.perf_counter
        self.buffer = buffer if buffer is not None else TraceBuffer()
        self.track = track
        self._tls = threading.local()

    # -- recording -----------------------------------------------------------
    def _stack(self) -> List[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def span(self, name: str, **attrs: Any) -> "_SpanContext":
        return _SpanContext(self, name, attrs)

    def event(self, name: str, **attrs: Any) -> None:
        """Record an instant marker under the currently open span."""
        stack = self._stack()
        parent = stack[-1].span_id if stack else None
        self.buffer.add_event(_Event(
            name=name, ts=self.clock(),
            attrs={k: _json_safe(v) for k, v in attrs.items()},
            parent_id=parent, track=self.track))

    def fork(self, track: str) -> "Tracer":
        """A tracer sharing this buffer and clock under another track —
        renders as a separate thread row in Perfetto."""
        return Tracer(clock=self.clock, buffer=self.buffer, track=track)

    # -- export --------------------------------------------------------------
    def _tracks(self) -> List[str]:
        seen: List[str] = []
        for sp in self.buffer.spans():
            if sp.track not in seen:
                seen.append(sp.track)
        for ev in self.buffer.events():
            if ev.track not in seen:
                seen.append(ev.track)
        return seen

    def to_perfetto(self, path: Optional[str] = None) -> Any:
        """Chrome/Perfetto ``trace_event`` JSON. With ``path``, writes the
        file and returns the path; otherwise returns the dict."""
        spans = self.buffer.spans()
        events = self.buffer.events()
        t0 = min([s.start for s in spans] + [e.ts for e in events],
                 default=0.0)
        tids = {t: i for i, t in enumerate(self._tracks())}
        out: List[Dict[str, Any]] = [
            {"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
             "args": {"name": track}}
            for track, tid in tids.items()
        ]
        for sp in spans:
            end = sp.end if sp.end is not None else sp.start
            out.append({
                "name": sp.name, "cat": sp.track, "ph": "X",
                "ts": (sp.start - t0) * 1e6, "dur": (end - sp.start) * 1e6,
                "pid": 0, "tid": tids[sp.track],
                "args": {k: _json_safe(v) for k, v in sp.attrs.items()},
            })
        for ev in events:
            out.append({
                "name": ev.name, "cat": ev.track, "ph": "i", "s": "t",
                "ts": (ev.ts - t0) * 1e6, "pid": 0, "tid": tids[ev.track],
                "args": dict(ev.attrs),
            })
        out.sort(key=lambda e: (e["ph"] != "M", e.get("ts", 0.0)))
        payload = {"traceEvents": out, "displayTimeUnit": "ms"}
        if path is None:
            return payload
        with open(path, "w") as f:
            json.dump(payload, f, indent=1)
        return path

    def flame(self) -> str:
        """Aggregated plain-text flame summary: one line per distinct span
        path (``a/b/c``), sorted by total time; ``self`` excludes child
        span time."""
        spans = self.buffer.spans()
        by_id = {s.span_id: s for s in spans}
        child_time: Dict[int, float] = {}
        for s in spans:
            if s.parent_id is not None and s.duration is not None:
                child_time[s.parent_id] = (child_time.get(s.parent_id, 0.0)
                                           + s.duration)

        def path(s: Span) -> str:
            parts = [s.name]
            seen = {s.span_id}
            cur = s
            while cur.parent_id is not None and cur.parent_id in by_id:
                cur = by_id[cur.parent_id]
                if cur.span_id in seen:    # defensive: no cycles
                    break
                seen.add(cur.span_id)
                parts.append(cur.name)
            parts.append(s.track)
            return "/".join(reversed(parts))

        agg: Dict[str, Tuple[float, float, int]] = {}
        for s in spans:
            dur = s.duration or 0.0
            self_t = dur - child_time.get(s.span_id, 0.0)
            p = path(s)
            tot, slf, cnt = agg.get(p, (0.0, 0.0, 0))
            agg[p] = (tot + dur, slf + self_t, cnt + 1)
        lines = [f"{'total_ms':>10} {'self_ms':>10} {'count':>6}  path"]
        for p, (tot, slf, cnt) in sorted(agg.items(),
                                         key=lambda kv: -kv[1][0]):
            lines.append(f"{tot * 1e3:10.3f} {slf * 1e3:10.3f} {cnt:6d}  {p}")
        return "\n".join(lines)


class _SpanContext:
    """Context manager for one span: opens on ``__enter__``, pushes onto the
    thread's stack, appends to the buffer on ``__exit__``."""

    __slots__ = ("_tracer", "_name", "_attrs", "_span")

    def __init__(self, tracer: Tracer, name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs
        self._span: Optional[Span] = None

    def __enter__(self) -> Span:
        tr = self._tracer
        stack = tr._stack()
        self._span = Span(
            name=self._name, start=tr.clock(), attrs=dict(self._attrs),
            span_id=tr.buffer.next_id(),
            parent_id=stack[-1].span_id if stack else None, track=tr.track)
        stack.append(self._span)
        return self._span

    def __exit__(self, exc_type, exc, tb) -> bool:
        tr = self._tracer
        sp = self._span
        stack = tr._stack()
        if stack and stack[-1] is sp:
            stack.pop()
        sp.end = tr.clock()
        if exc_type is not None:
            sp.attrs.setdefault("error", f"{exc_type.__name__}: {exc}")
        tr.buffer.add_span(sp)
        return False


class _NullSpan:
    __slots__ = ()
    name = ""
    attrs: Dict[str, Any] = {}

    def set(self, **attrs: Any) -> "_NullSpan":
        return self


class _NullContext:
    __slots__ = ()
    _SPAN = _NullSpan()

    def __enter__(self) -> _NullSpan:
        return self._SPAN

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


class NullTracer:
    """Do-nothing tracer: executors use it when no trace is requested so
    the hot path has no branches beyond one attribute check. Falsy, so
    ``trace or NULL_TRACER`` composes."""

    enabled = False
    _CTX = _NullContext()

    def __bool__(self) -> bool:
        return False

    def span(self, name: str, **attrs: Any) -> _NullContext:
        return self._CTX

    def event(self, name: str, **attrs: Any) -> None:
        pass

    def fork(self, track: str) -> "NullTracer":
        return self


NULL_TRACER = NullTracer()
