"""Process-wide metrics registry: counters, gauges, fixed-bucket histograms.

Unifies the accounting that used to live in scattered result fields —
shuffle wire bytes and collective counts, partition/sort drops, host
retries/recoveries/data-errors, SPMD compile-cache hit/miss/evictions,
per-tenant queue latency — behind one ``snapshot()`` / ``to_json()`` API.

Conventions (documented in docs/OBSERVABILITY.md):

- Names are dotted, ``<subsystem>.<noun>``: ``spmd.shuffle.wire_bytes``,
  ``host.retries``, ``tenant.latency``. Label sets render Prometheus-style
  into the key: ``tenant.latency{tenant="batch"}``.
- Histograms use **fixed bucket boundaries** (powers of two by default), so
  the reported percentiles are deterministic functions of the observation
  multiset — a percentile is the smallest bucket upper bound covering the
  quantile, never an interpolation that shifts with sample order.
- One process-wide default registry (:data:`REGISTRY`); executors publish
  there unless handed their own. ``reset()`` exists for tests.

Everything is lock-protected and dependency-free.
"""

from __future__ import annotations

import bisect
import json
import math
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
           "DEFAULT_BUCKETS", "MS_BUCKETS"]

#: default histogram boundaries: powers of two from ~1µs to 64s (seconds
#: scale) — wide enough for latencies and deterministic for percentiles.
DEFAULT_BUCKETS: Tuple[float, ...] = tuple(2.0 ** e for e in range(-20, 7))

#: millisecond-scale boundaries for retry/backoff-delay histograms
#: (``host.backoff_ms``, ``tenant.backoff_ms``, ``sector.recover.backoff_ms``):
#: a leading 0.0 bound gives zero-delay retries their own bucket, then powers
#: of two from ~1µs to ~131s expressed in ms.
MS_BUCKETS: Tuple[float, ...] = (0.0,) + tuple(2.0 ** e for e in range(-10, 18))


class Counter:
    """Monotonic float counter."""

    kind = "counter"

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> Dict[str, Any]:
        return {"type": self.kind, "value": self._value}


class Gauge:
    """Last-write-wins value."""

    kind = "gauge"

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> Dict[str, Any]:
        return {"type": self.kind, "value": self._value}


class Histogram:
    """Fixed-boundary histogram with deterministic percentiles.

    ``bounds`` are bucket *upper* bounds; one implicit overflow bucket
    (+inf) catches the rest. :meth:`percentile` returns the smallest upper
    bound whose cumulative count covers the quantile (``inf`` if only the
    overflow bucket does) — a pure function of the observation multiset."""

    kind = "histogram"

    def __init__(self, bounds: Sequence[float] = DEFAULT_BUCKETS):
        b = tuple(sorted(float(x) for x in bounds))
        if not b:
            raise ValueError("histogram needs at least one bucket bound")
        self.bounds = b
        self._lock = threading.Lock()
        self._counts = [0] * (len(b) + 1)
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def percentile(self, q: float) -> float:
        """Deterministic quantile: the smallest bucket upper bound covering
        ``q`` percent of observations (0 when empty)."""
        if not 0 <= q <= 100:
            raise ValueError(f"percentile must be in [0, 100], got {q}")
        with self._lock:
            total = self._count
            if total == 0:
                return 0.0
            need = q / 100.0 * total
            cum = 0
            for i, c in enumerate(self._counts):
                cum += c
                if cum >= need and cum > 0:
                    return (self.bounds[i] if i < len(self.bounds)
                            else math.inf)
            return math.inf

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            counts = list(self._counts)
            total, s = self._count, self._sum
        snap = {"type": self.kind, "count": total, "sum": s,
                "buckets": {("inf" if i == len(self.bounds)
                             else repr(self.bounds[i])): c
                            for i, c in enumerate(counts) if c}}
        snap["p50"] = self.percentile(50)
        snap["p99"] = self.percentile(99)
        return snap


def _key(name: str, labels: Dict[str, Any]) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Create-or-fetch registry of named instruments (see module
    docstring). ``snapshot()`` returns a key-sorted plain dict, so its JSON
    form is stable across runs with the same event multiset."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, labels: Dict[str, Any], cls, *args):
        key = _key(name, labels)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = cls(*args)
            elif not isinstance(m, cls):
                raise ValueError(f"metric {key!r} already registered as "
                                 f"{m.kind}, not {cls.kind}")
            return m

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._get(name, labels, Counter)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._get(name, labels, Gauge)

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None,
                  **labels: Any) -> Histogram:
        return self._get(name, labels, Histogram,
                         DEFAULT_BUCKETS if bounds is None else bounds)

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            items = sorted(self._metrics.items())
        return {k: m.snapshot() for k, m in items}

    def to_json(self, path: Optional[str] = None, indent: int = 2) -> Any:
        snap = self.snapshot()
        if path is None:
            return json.dumps(snap, indent=indent, sort_keys=True)
        with open(path, "w") as f:
            json.dump(snap, f, indent=indent, sort_keys=True)
        return path

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


#: the process-wide default registry every instrumented component uses
#: unless constructed with an explicit one.
REGISTRY = MetricsRegistry()
