"""Observability for the Sphere repro: span tracing + metrics registry.

- :mod:`repro.obs.trace` — zero-dependency nested-span tracer with explicit
  clock injection (the same virtual-clock discipline as ``TenantQueue`` /
  ``ReplicationDaemon``), Chrome/Perfetto ``trace_event`` export and a
  plain-text flame summary.
- :mod:`repro.obs.metrics` — process-wide registry of counters, gauges and
  fixed-bucket histograms behind one ``snapshot()`` / ``to_json()`` API.

Both executors accept a tracer (``Dataflow.run(executor, data, trace=...)``)
and publish into the default registry; see docs/OBSERVABILITY.md.
"""

from repro.obs.metrics import REGISTRY, MetricsRegistry
from repro.obs.trace import NULL_TRACER, Span, TraceBuffer, Tracer

__all__ = ["Tracer", "TraceBuffer", "Span", "NULL_TRACER",
           "MetricsRegistry", "REGISTRY"]
