"""Trainer: builds the jitted, sharded train step (grad accumulation, AdamW,
metrics) for any registry model on any mesh.

Distribution recipe (DESIGN.md §5): batch over ("pod","data"); weights over
"model" per the registry param specs; optimizer moments additionally ZeRO-1
sharded over "data". Buffers are donated so params/opt update in place.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.registry import Model
from repro.train.optimizer import (AdamWConfig, adamw_update, init_opt_state,
                                   zero1_specs)


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: int = 0


def make_state_shardings(model: Model, mesh: Mesh, param_specs,
                         zero1: bool = True, master: bool = False):
    """NamedShardings for params and optimizer state."""
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs,
                           is_leaf=lambda x: isinstance(x, P))
    params_shape = jax.eval_shape(lambda k: model.init(k)[0],
                                  jax.random.PRNGKey(0))
    if zero1 and "data" in mesh.shape:
        mspec = zero1_specs(param_specs, params_shape,
                            data_axes=("data",),
                            mesh_shape=dict(mesh.shape))
    else:
        mspec = param_specs
    m_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), mspec,
                           is_leaf=lambda x: isinstance(x, P))
    opt_shard = {"m": m_shard, "v": m_shard,
                 "step": NamedSharding(mesh, P())}
    if master:
        opt_shard["master"] = m_shard   # fp32 master, ZeRO-1 sharded
    return p_shard, opt_shard


def build_train_step(model: Model, opt_cfg: AdamWConfig, mesh: Optional[Mesh],
                     dp_axes: Sequence[str] = ("data",),
                     accum_steps: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With accum_steps > 1 the batch's leading axis must be divisible; micro
    batches run under lax.scan with gradient accumulation (fp32).
    """

    def loss_fn(params, batch):
        loss, metrics = model.train_loss(params, batch, mesh=mesh,
                                         dp_axes=tuple(dp_axes))
        return loss, metrics

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def micro(b):
                return jax.tree.map(
                    lambda x: x.reshape((accum_steps, -1) + x.shape[1:]), b)

            micro_batches = micro(batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                acc, _ = carry
                loss, metrics, grads = grads_of(params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / accum_steps,
                    acc, grads)
                return (acc, loss), None

            (grads, loss), _ = jax.lax.scan(
                body, (zero_g, jnp.zeros(())), micro_batches)
            metrics = {}
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, **opt_metrics, loss=loss)
        return new_params, new_opt, metrics

    return train_step


def jit_train_step(model: Model, opt_cfg: AdamWConfig, mesh: Mesh,
                   param_specs, batch_specs: Dict[str, P],
                   dp_axes: Sequence[str] = ("data",),
                   accum_steps: int = 1, zero1: bool = True,
                   donate: bool = True):
    p_shard, opt_shard = make_state_shardings(model, mesh, param_specs, zero1)
    b_shard = {k: NamedSharding(mesh, s) for k, s in batch_specs.items()}
    step = build_train_step(model, opt_cfg, mesh, dp_axes, accum_steps)
    jitted = jax.jit(
        step,
        in_shardings=(p_shard, opt_shard, b_shard),
        out_shardings=(p_shard, opt_shard, None),
        donate_argnums=(0, 1) if donate else (),
    )
    return jitted, (p_shard, opt_shard, b_shard)


def init_train_state(model: Model, key, mesh: Optional[Mesh] = None,
                     param_specs=None, zero1: bool = True):
    """Materialize params + optimizer state (sharded when mesh given)."""
    if mesh is None:
        params, _ = model.init(key)
        return params, init_opt_state(params)
    p_shard, opt_shard = make_state_shardings(model, mesh, param_specs, zero1)
    params = jax.jit(lambda k: model.init(k)[0], out_shardings=p_shard)(key)
    opt = jax.jit(init_opt_state, out_shardings=opt_shard)(params)
    return params, opt
