"""AdamW in pure JAX with ZeRO-1-style optimizer-state sharding.

ZeRO-1 via GSPMD: the first- and second-moment pytrees reuse the parameter
PartitionSpecs, then the largest still-replicated dimension of each state
leaf is additionally sharded over the ``data`` axis. XLA then materializes
the reduce-scatter / all-gather pattern of ZeRO-1 automatically.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup -> cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decayed = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, decayed)


def init_opt_state(params, master: bool = False) -> Dict[str, Any]:
    """AdamW moments (+ optional fp32 master weights for bf16-param
    training). The master copy is ZeRO-1 sharded like the moments."""
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    out = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if master:
        out["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return out


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, opt_state):
    """One AdamW step with global-norm clipping. Returns (params, opt_state,
    metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    has_master = "master" in opt_state

    def upd(p, g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / bc1
        vh = v2 / bc2
        base = master if master is not None else p.astype(jnp.float32)
        new_master = base - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                  + cfg.weight_decay * base)
        return new_master.astype(p.dtype), m2, v2, new_master

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    flat_w = jax.tree.leaves(opt_state["master"]) if has_master \
        else [None] * len(flat_p)
    out = [upd(p, g, m, v, w) for p, g, m, v, w
           in zip(flat_p, flat_g, flat_m, flat_v, flat_w)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    if has_master:
        new_state["master"] = jax.tree.unflatten(
            tdef, [o[3] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics


def zero1_specs(param_specs, params_shape, data_axes: Tuple[str, ...] = ("data",),
                mesh_shape: Optional[Dict[str, int]] = None):
    """ZeRO-1: derive optimizer-moment PartitionSpecs from parameter specs by
    sharding the largest replicated dim over the data axes (when divisible).

    param_specs / params_shape: matching pytrees of PartitionSpec and
    ShapeDtypeStruct (or arrays).
    """
    dsize = 1
    if mesh_shape:
        for a in data_axes:
            dsize *= mesh_shape.get(a, 1)

    def one(spec: P, arr) -> P:
        shape = arr.shape
        if dsize <= 1 or not shape:
            return spec
        entries = list(spec) + [None] * (len(shape) - len(spec))
        # pick the largest dim currently replicated and divisible
        cands = [(shape[i], i) for i, e in enumerate(entries)
                 if e is None and shape[i] % dsize == 0]
        if not cands:
            return spec
        _, idx = max(cands)
        entries[idx] = data_axes if len(data_axes) > 1 else data_axes[0]
        return P(*entries)

    return jax.tree.map(one, param_specs, params_shape,
                        is_leaf=lambda x: isinstance(x, P))
