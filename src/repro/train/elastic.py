"""Elastic scaling: re-shard training state onto a different mesh.

Node loss on a real cluster shrinks the healthy device set; because Sector
checkpoints are device-layout-agnostic byte slices, restart is:

  1. replication daemon has kept >= R copies of every checkpoint slice;
  2. surviving hosts form a new (smaller or larger) mesh;
  3. ``remesh`` device_puts the restored state with the same PartitionSpecs
     over the new mesh (GSPMD handles any axis-size change that still
     divides the tensors — specs are symbolic, not size-bound).

The same path implements scale-UP when capacity returns.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shardings_for(mesh: Mesh, specs):
    def fix(s: P) -> P:
        # drop axes the new mesh no longer has (e.g. "pod" after pod loss)
        entries = []
        for e in s:
            if e is None:
                entries.append(None)
            elif isinstance(e, (tuple, list)):
                kept = tuple(a for a in e if a in mesh.shape)
                entries.append(kept if kept else None)
            else:
                entries.append(e if e in mesh.shape else None)
        return P(*entries)

    return jax.tree.map(lambda s: NamedSharding(mesh, fix(s)), specs,
                        is_leaf=lambda x: isinstance(x, P))


def remesh(tree, mesh: Mesh, specs):
    """device_put every leaf onto ``mesh`` with (axis-filtered) ``specs``."""
    shard = shardings_for(mesh, specs)
    flat_t, tdef = jax.tree.flatten(tree)
    flat_s = jax.tree.leaves(shard, is_leaf=lambda x: hasattr(x, "spec"))
    return jax.tree.unflatten(
        tdef, [jax.device_put(t, s) for t, s in zip(flat_t, flat_s)])
