"""Elastic scaling: re-shard training state onto a different mesh.

Node loss on a real cluster shrinks the healthy device set; because Sector
checkpoints are device-layout-agnostic byte slices, restart is:

  1. replication daemon has kept >= R copies of every checkpoint slice;
  2. surviving hosts form a new (smaller or larger) mesh;
  3. ``remesh`` device_puts the restored state with the same PartitionSpecs
     over the new mesh (GSPMD handles any axis-size change that still
     divides the tensors — specs are symbolic, not size-bound).

The same path implements scale-UP when capacity returns.
"""

from __future__ import annotations

import math
from typing import Any, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shardings_for(mesh: Mesh, specs):
    def fix(s: P) -> P:
        # drop axes the new mesh no longer has (e.g. "pod" after pod loss)
        entries = []
        for e in s:
            if e is None:
                entries.append(None)
            elif isinstance(e, (tuple, list)):
                kept = tuple(a for a in e if a in mesh.shape)
                entries.append(kept if kept else None)
            else:
                entries.append(e if e in mesh.shape else None)
        return P(*entries)

    return jax.tree.map(lambda s: NamedSharding(mesh, fix(s)), specs,
                        is_leaf=lambda x: isinstance(x, P))


def remesh(tree, mesh: Mesh, specs):
    """device_put every leaf onto ``mesh`` with (axis-filtered) ``specs``."""
    shard = shardings_for(mesh, specs)
    flat_t, tdef = jax.tree.flatten(tree)
    flat_s = jax.tree.leaves(shard, is_leaf=lambda x: hasattr(x, "spec"))
    return jax.tree.unflatten(
        tdef, [jax.device_put(t, s) for t, s in zip(flat_t, flat_s)])


def shrink_mesh(mesh: Mesh, axes: Sequence[str],
                lost_device: "int | Sequence[int]",
                num_buckets: int) -> Mesh:
    """Re-form the largest usable mesh after losing device(s) mid-pipeline.

    ``lost_device`` is the global (row-major over ``axes``) index of the dead
    device — or a sequence of them, for multi-fault chaos schedules that
    lose several devices over a stream's lifetime. The surviving devices
    cannot keep the old shape, so the shuffle axes shrink to the largest
    extent that still

    - divides ``num_buckets`` (bucket ownership stays contiguous),
    - divides the old extent (every old per-device shard lands *whole* on
      one new device when a hop checkpoint is re-sharded, so reduce groups
      and bucket segments are never split across devices), and
    - fits on the surviving devices.

    A flat plan shrinks its single axis; a two-level ``(dc, node)`` plan
    keeps the DC count and shrinks the node axis (a lost node does not make
    a data center disappear). Raises if no smaller extent qualifies (e.g. a
    single-node axis).
    """
    axes = tuple(axes)
    shape = tuple(mesh.shape[a] for a in axes)
    total = math.prod(shape)
    flat = list(np.asarray(mesh.devices).reshape(-1))
    if len(flat) != total:
        raise ValueError(f"mesh has axes {dict(mesh.shape)} beyond the "
                         f"shuffle axes {axes}; cannot shrink")
    if isinstance(lost_device, (int, np.integer)):
        lost = {int(lost_device)}
    else:
        lost = {int(d) for d in lost_device}
    if not lost:
        raise ValueError("shrink_mesh needs at least one lost device")
    for d in lost:
        if not 0 <= d < total:
            raise ValueError(f"lost_device={d} out of range {total}")
    survivors = [d for i, d in enumerate(flat) if i not in lost]
    if len(axes) == 1:
        old = shape[0]
        k = next((k for k in range(old - 1, 0, -1)
                  if old % k == 0 and num_buckets % k == 0
                  and k <= len(survivors)), None)
        new_shape: Tuple[int, ...] = (k,) if k else ()
    else:
        dcs, nodes = shape
        k = next((k for k in range(nodes - 1, 0, -1)
                  if nodes % k == 0 and num_buckets % (dcs * k) == 0
                  and dcs * k <= len(survivors)), None)
        new_shape = (dcs, k) if k else ()
    if not k:
        raise ValueError(
            f"cannot shrink mesh {shape} below the lost device while keeping "
            f"an extent dividing num_buckets={num_buckets}")
    keep = math.prod(new_shape)
    return Mesh(np.array(survivors[:keep]).reshape(new_shape), axes)
