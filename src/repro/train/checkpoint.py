"""Sector-backed checkpointing (fault tolerance for training).

Checkpoints are stored *in Sector* as whole-file slices (paper §2.2): the
serialized state is chunked into ``num_slices`` Sector files plus a JSON
manifest carrying per-slice MD5 checksums (the paper posts MD5s for every
SDSS file). Durability comes from Sector's periodic replication daemon; a
master that lost its metadata recovers the checkpoint index by scanning
slave directories; restore verifies checksums and can re-shard onto a
*different* mesh (elastic restart after losing nodes).

Async mode runs the upload on a background thread so the training loop
overlaps checkpoint IO with compute (write-behind).
"""

from __future__ import annotations

import io
import json
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.sector.client import SectorClient


def _serialize_tree(tree) -> Tuple[bytes, Dict]:
    leaves, treedef = jax.tree.flatten(tree)
    buf = io.BytesIO()
    meta = []
    off = 0
    for leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        data = arr.tobytes()
        meta.append({"shape": list(arr.shape), "dtype": str(arr.dtype),
                     "offset": off, "nbytes": len(data)})
        buf.write(data)
        off += len(data)
    return buf.getvalue(), {"leaves": meta, "treedef": str(treedef)}


def _deserialize_leaves(blob: bytes, meta: Dict) -> List[np.ndarray]:
    out = []
    for m in meta["leaves"]:
        arr = np.frombuffer(
            blob[m["offset"]:m["offset"] + m["nbytes"]],
            dtype=np.dtype(m["dtype"])).reshape(m["shape"])
        out.append(arr)
    return out


class SectorCheckpointer:
    def __init__(self, client: SectorClient, prefix: str = "/ckpt",
                 num_slices: int = 8, keep: int = 3):
        self.client = client
        self.prefix = prefix.rstrip("/")
        self.num_slices = num_slices
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return f"{self.prefix}/step_{step:08d}"

    def save(self, step: int, tree, blocking: bool = True) -> None:
        blob, meta = _serialize_tree(tree)
        if blocking:
            self._upload(step, blob, meta)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._upload, args=(step, blob, meta), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _upload(self, step: int, blob: bytes, meta: Dict) -> None:
        d = self._step_dir(step)
        n = self.num_slices
        size = len(blob)
        per = (size + n - 1) // n if size else 1
        slice_meta = []
        for i in range(n):
            chunk = blob[i * per:(i + 1) * per]
            fm = self.client.upload(f"{d}/slice.{i:05d}", chunk)
            slice_meta.append({"path": fm.path, "md5": fm.md5,
                               "nbytes": len(chunk)})
        manifest = dict(meta, step=step, total_bytes=size, slices=slice_meta)
        self.client.upload(f"{d}/MANIFEST.json",
                           json.dumps(manifest).encode())
        self._gc()

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            d = self._step_dir(s)
            for fm in self.client.ls(d + "/"):
                try:
                    self.client.delete(fm.path)
                except FileNotFoundError:
                    pass

    # -- restore ----------------------------------------------------------------
    def list_steps(self) -> List[int]:
        steps = set()
        for fm in self.client.ls(self.prefix + "/"):
            parts = fm.path[len(self.prefix) + 1:].split("/")
            if parts and parts[0].startswith("step_") and \
                    parts[-1] == "MANIFEST.json":
                steps.add(int(parts[0][5:]))
        return sorted(steps)

    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, int]:
        """Rebuild the pytree (structure taken from ``tree_like``); verify
        every slice MD5; optionally device_put with new ``shardings`` (elastic
        re-mesh). Returns (tree, step)."""
        steps = self.list_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {self.prefix}")
        step = steps[-1] if step is None else step
        d = self._step_dir(step)
        manifest = json.loads(self.client.download(f"{d}/MANIFEST.json"))
        blob = io.BytesIO()
        import hashlib
        for sm in manifest["slices"]:
            chunk = self.client.download(sm["path"])
            if hashlib.md5(chunk).hexdigest() != sm["md5"]:
                raise IOError(f"checksum mismatch on {sm['path']}")
            blob.write(chunk)
        leaves = _deserialize_leaves(blob.getvalue(), manifest)
        _, treedef = jax.tree.flatten(tree_like)
        tree = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            flat_t, tdef = jax.tree.flatten(tree)
            flat_s = jax.tree.leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec"))
            tree = jax.tree.unflatten(
                tdef, [jax.device_put(t, s) for t, s in zip(flat_t, flat_s)])
        return tree, step
