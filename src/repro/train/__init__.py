"""Training substrate: optimizer (AdamW + ZeRO-1 sharding), trainer
(grad-accum, clipping, schedules), Sector-backed checkpointing with periodic
replication and scan-recovery restore, and elastic re-meshing."""
