"""State-space / recurrent blocks: Mamba2 (SSD) for zamba2, mLSTM + sLSTM
for xlstm.

All train/prefill paths are chunk-parallel (quadratic only within a chunk,
linear across chunks via a `lax.scan` over chunk states); decode paths are
O(1)-state recurrent steps — which is why these families run the
``long_500k`` shape that full attention skips.

Cache contracts:
- mamba2: {"ssm": (B,H,P,N) fp32, "conv_x": (B,K-1,d_in),
          "conv_bc": (B,K-1,2N)}
- mLSTM:  {"C": (B,H,P,P) fp32, "n": (B,H,P), "m": (B,H)}
- sLSTM:  {"c","n","h": (B,H,P), "m": (B,H,P)}
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import COMPUTE_DTYPE, dense_init, rms_norm


# =============================== Mamba2 (SSD) ===================================

def mamba2_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    head_p = 64
    n_heads = max(d_in // head_p, 1)
    head_p = d_in // n_heads
    return d_in, n_heads, head_p


def mamba2_init(key, cfg: ModelConfig) -> Tuple[Dict, Dict]:
    """Projections are SPLIT by component (§Perf zamba2 iteration): a fused
    (z,x,B,C,dt) in_proj puts the z/x/B/C/dt slice boundaries inside shards
    of the column-sharded output — measured ~1.8 GB/2-layers of backward
    collective-permutes on zamba2 train_4k. Splitting keeps z/x exactly
    shard-aligned (2*d_in divides the model axis) and replicates the tiny
    B/C/dt projections (d x (2N+H))."""
    d = cfg.d_model
    d_in, H, Pdim = mamba2_dims(cfg)
    N = cfg.ssm_state
    ks = jax.random.split(key, 5)
    params = {
        "in_zx": dense_init(ks[0], d, 2 * d_in),       # [z | x], aligned
        "in_bcdt": dense_init(ks[3], d, 2 * N + H),    # [B | C | dt], small
        "conv_x": jax.random.normal(ks[1], (cfg.conv_kernel, d_in),
                                    jnp.float32) * 0.1,
        "conv_x_b": jnp.zeros((d_in,), jnp.float32),
        "conv_bc": jax.random.normal(ks[4], (cfg.conv_kernel, 2 * N),
                                     jnp.float32) * 0.1,
        "conv_bc_b": jnp.zeros((2 * N,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H)),
        "d_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 1e-2))),
        "norm": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[2], d_in, d, scale=d_in ** -0.5),
    }
    specs = {
        "in_zx": P(None, "model"), "in_bcdt": P(None, None),
        "conv_x": P(None, "model"), "conv_x_b": P("model",),
        "conv_bc": P(None, None), "conv_bc_b": P(None),
        "a_log": P(None), "d_skip": P(None),
        "dt_bias": P(None), "norm": P("model",),
        "out_proj": P("model", None),
    }
    return params, specs


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B,L,C); w: (K,C). state: (B,K-1,C) carry.
    Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1):] if K > 1 else state
    return jax.nn.silu(y), new_state


def mamba2_apply(params, x, cfg: ModelConfig, cache: Dict | None = None):
    """x: (B, L, d). Returns (y (B,L,d), new_cache)."""
    B, L, _ = x.shape
    d_in, H, Pdim = mamba2_dims(cfg)
    N = cfg.ssm_state
    xc = x.astype(COMPUTE_DTYPE)
    zx = xc @ params["in_zx"].astype(COMPUTE_DTYPE)
    z, xi = zx[..., :d_in], zx[..., d_in:]
    bcdt = xc @ params["in_bcdt"].astype(COMPUTE_DTYPE)
    bc, dt_raw = bcdt[..., :2 * N], bcdt[..., 2 * N:]
    conv_x_state = cache["conv_x"] if cache is not None else None
    conv_bc_state = cache["conv_bc"] if cache is not None else None
    xi, new_conv_x = _causal_conv(xi, params["conv_x"].astype(COMPUTE_DTYPE),
                                  params["conv_x_b"].astype(COMPUTE_DTYPE),
                                  conv_x_state)
    bc, new_conv_bc = _causal_conv(bc,
                                   params["conv_bc"].astype(COMPUTE_DTYPE),
                                   params["conv_bc_b"].astype(COMPUTE_DTYPE),
                                   conv_bc_state)
    xs = xi.reshape(B, L, H, Pdim)
    Bs = bc[..., :N]
    Cs = bc[..., N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])                  # (B,L,H)
    A = -jnp.exp(params["a_log"])                              # (H,) negative

    ssm_state = cache["ssm"] if cache is not None else None
    if L == 1 and cache is not None:
        y, new_ssm = _ssd_step(xs[:, 0], Bs[:, 0], Cs[:, 0], dt[:, 0], A,
                               params["d_skip"], ssm_state)
        y = y[:, None]
    else:
        y, new_ssm = _ssd_chunked(xs, Bs, Cs, dt, A, params["d_skip"],
                                  cfg.chunk_size, ssm_state)
    y = y.reshape(B, L, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(COMPUTE_DTYPE),
                 params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"].astype(COMPUTE_DTYPE)
    new_cache = ({"ssm": new_ssm, "conv_x": new_conv_x,
                  "conv_bc": new_conv_bc}
                 if cache is not None else None)
    return out, new_cache


def _ssd_step(x, Bv, Cv, dt, A, d_skip, state):
    """One decode step. x: (B,H,P); Bv/Cv: (B,N); dt: (B,H); state (B,H,P,N)."""
    decay = jnp.exp(dt * A)                                    # (B,H)
    dx = dt[..., None] * x.astype(jnp.float32)                 # (B,H,P)
    upd = dx[..., None] * Bv[:, None, None, :].astype(jnp.float32)
    state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, Cv.astype(jnp.float32))
    y = y + d_skip[None, :, None] * x.astype(jnp.float32)
    return y.astype(COMPUTE_DTYPE), state


def _ssd_chunked(xs, Bs, Cs, dt, A, d_skip, Q, init_state=None):
    """Chunked SSD (Mamba2). xs: (B,L,H,P); Bs/Cs: (B,L,N); dt: (B,L,H).
    Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    B, L, H, Pdim = xs.shape
    N = Bs.shape[-1]
    pad = (-L) % Q
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bs = jnp.pad(Bs, ((0, 0), (0, pad), (0, 0)))
        Cs = jnp.pad(Cs, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Lp = L + pad
    C = Lp // Q

    def resh(t, trailing):
        return t.reshape((B, C, Q) + trailing)

    xs_c = resh(xs, (H, Pdim)).astype(jnp.float32)
    Bs_c = resh(Bs, (N,)).astype(jnp.float32)
    Cs_c = resh(Cs, (N,)).astype(jnp.float32)
    dt_c = resh(dt, (H,)).astype(jnp.float32)

    a = dt_c * A                                               # (B,C,Q,H)
    cum_a = jnp.cumsum(a, axis=2)
    # intra-chunk: decay[t,s] = exp(cum_a[t] - cum_a[s]) for t >= s
    diff = cum_a[:, :, :, None, :] - cum_a[:, :, None, :, :]   # (B,C,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bctn,bcsn->bcts", Cs_c, Bs_c)             # (B,C,Q,Q)
    w = cb[..., None] * decay * dt_c[:, :, None, :, :]         # (B,C,Q,Q,H)
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", w, xs_c)

    # per-chunk state contribution: S_c = sum_s exp(cumQ - cum_a[s]) dt_s B_s x_s
    decay_out = jnp.exp(cum_a[:, :, -1:, :] - cum_a)           # (B,C,Q,H)
    sx = xs_c * (dt_c * decay_out)[..., None]                  # (B,C,Q,H,P)
    s_local = jnp.einsum("bcqhp,bcqn->bchpn", sx, Bs_c)        # (B,C,H,P,N)
    chunk_decay = jnp.exp(cum_a[:, :, -1, :])                  # (B,C,H)

    def scan_fn(carry, inp):
        s_loc, cd = inp                                        # (B,H,P,N),(B,H)
        new = carry * cd[..., None, None] + s_loc
        return new, carry                                      # emit state BEFORE chunk

    init = (jnp.zeros((B, H, Pdim, N), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))
    final_state, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(s_local, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)              # (B,C,H,P,N)

    # inter-chunk: y_t += C_t . (exp(cum_a[t]) * S_prev)
    c_decay = jnp.exp(cum_a)                                   # (B,C,Q,H)
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", Cs_c, prev_states) \
        * c_decay[..., None]
    y = y_intra + y_inter + d_skip[None, None, None, :, None] * xs_c
    y = y.reshape(B, Lp, H, Pdim)[:, :L]
    return y.astype(COMPUTE_DTYPE), final_state


def mamba2_init_cache(cfg: ModelConfig, batch: int) -> Dict:
    d_in, H, Pdim = mamba2_dims(cfg)
    N = cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, H, Pdim, N), jnp.float32),
        "conv_x": jnp.zeros((batch, cfg.conv_kernel - 1, d_in),
                            COMPUTE_DTYPE),
        "conv_bc": jnp.zeros((batch, cfg.conv_kernel - 1, 2 * N),
                             COMPUTE_DTYPE),
    }


# ================================= mLSTM ========================================

def mlstm_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads or cfg.n_heads
    Pdim = d_in // H
    return d_in, H, Pdim


def mlstm_init(key, cfg: ModelConfig) -> Tuple[Dict, Dict]:
    d = cfg.d_model
    d_in, H, Pdim = mlstm_dims(cfg)
    ks = jax.random.split(key, 6)
    params = {
        "up_proj": dense_init(ks[0], d, 2 * d_in),     # [z, x]
        "conv_w": jax.random.normal(ks[1], (cfg.conv_kernel, d_in),
                                    jnp.float32) * 0.1,
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "wqkv": dense_init(ks[2], d_in, 3 * d_in),
        "wif": dense_init(ks[3], d_in, 2 * H, scale=0.02),
        "if_bias": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]),
        "norm": jnp.ones((d_in,), jnp.float32),
        "down_proj": dense_init(ks[4], d_in, d, scale=d_in ** -0.5),
    }
    specs = {
        "up_proj": P(None, "model"), "conv_w": P(None, "model"),
        "conv_b": P("model",), "wqkv": P("model", None), "wif": P("model", None),
        "if_bias": P(None), "norm": P("model",), "down_proj": P("model", None),
    }
    return params, specs


def _mlstm_chunked(q, k, v, log_i, log_f, Q: int, init_state=None):
    """Stabilized chunk-parallel mLSTM (flash-linear-attention style).

    q,k,v: (B,L,H,P); log_i/log_f: (B,L,H). Quadratic only within chunks of
    length Q; a scan carries the stabilized matrix state across chunks.

    Derivation (DESIGN.md §4 / xLSTM eq. stabilization): with local
    cumulative log-forget b[t] and local running max of (log_i[s] - b[s]),
    m_t = max(m_prev + b[t], b[t] + localmax[t]); the inter-chunk
    contribution decays by exp(b[t] + m_prev - m_t) and intra-chunk weights
    are exp(b[t] - b[s] + log_i[s] - m_t).

    Returns (y (B,L,H,P), state dict {C,n,m}).
    """
    B, L, H, Pd = q.shape
    pad = (-L) % Q
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    Lp = L + pad
    C = Lp // Q

    def resh(t, trail):
        return jnp.moveaxis(t.reshape((B, C, Q) + trail), 1, 0)

    qc = resh(q.astype(jnp.float32) * (Pd ** -0.5), (H, Pd))   # (C,B,Q,H,P)
    kc = resh(k.astype(jnp.float32), (H, Pd))
    vc = resh(v.astype(jnp.float32), (H, Pd))
    lic = resh(log_i.astype(jnp.float32), (H,))                # (C,B,Q,H)
    lfc = resh(log_f.astype(jnp.float32), (H,))

    if init_state is None:
        init_state = {
            "C": jnp.zeros((B, H, Pd, Pd), jnp.float32),
            "n": jnp.zeros((B, H, Pd), jnp.float32),
            "m": jnp.full((B, H), -1e30, jnp.float32),
        }

    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def body(carry, inp):
        Cm, nv, m_prev = carry["C"], carry["n"], carry["m"]
        qq, kk, vv, li, lf = inp
        b = jnp.cumsum(lf, axis=1)                             # (B,Q,H)
        g = li - b                                             # (B,Q,H)
        localmax = jax.lax.cummax(g, axis=1)
        m_t = jnp.maximum(m_prev[:, None] + b, b + localmax)   # (B,Q,H)
        inter_decay = jnp.exp(b + m_prev[:, None] - m_t)       # (B,Q,H)
        # intra weights: (B,Q,Q,H) for t >= s
        dlog = b[:, :, None, :] - b[:, None, :, :] + li[:, None, :, :] \
            - m_t[:, :, None, :]
        w = jnp.where(tri[None, :, :, None], jnp.exp(dlog), 0.0)
        s = jnp.einsum("bthp,bshp->btsh", qq, kk)
        sw = s * w
        y_intra = jnp.einsum("btsh,bshp->bthp", sw, vv)
        # state layout: Cm[p, n] = sum_s v_p k_n — contract q against the
        # *key* index n, producing the value index p.
        y_inter = jnp.einsum("bthn,bhpn->bthp", qq, Cm) * inter_decay[..., None]
        n_intra = jnp.sum(sw, axis=2)                          # scalar part via k
        n_inter = jnp.einsum("bthp,bhp->bth", qq, nv) * inter_decay
        den = jnp.maximum(jnp.abs(n_intra + n_inter), jnp.exp(-m_t))
        y = (y_intra + y_inter) / den[..., None]

        # end-of-chunk state
        m_end = m_t[:, -1]                                     # (B,H)
        b_end = b[:, -1]                                       # (B,H)
        carry_decay = jnp.exp(b_end + m_prev - m_end)          # (B,H)
        upd_w = jnp.exp(b_end[:, None] - b + li - m_end[:, None])  # (B,Q,H)
        C_new = Cm * carry_decay[..., None, None] + jnp.einsum(
            "bshp,bshn->bhpn", vv * upd_w[..., None], kk)
        n_new = nv * carry_decay[..., None] + jnp.einsum(
            "bsh,bshp->bhp", upd_w, kk)
        return ({"C": C_new, "n": n_new, "m": m_end},
                y.astype(COMPUTE_DTYPE))

    final, ys = jax.lax.scan(body, init_state, (qc, kc, vc, lic, lfc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Lp, H, Pd)[:, :L]
    return y, final


def _mlstm_step(q, k, v, log_i, log_f, cache):
    """Recurrent step. q,k,v: (B,H,P); log_i/log_f: (B,H)."""
    C, n, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(log_f + m, log_i)
    f_eff = jnp.exp(log_f + m - m_new)
    i_eff = jnp.exp(log_i - m_new)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C = C * f_eff[..., None, None] + i_eff[..., None, None] \
        * (vf[..., :, None] * kf[..., None, :])               # (B,H,P,P)
    n = n * f_eff[..., None] + i_eff[..., None] * kf
    qf = q.astype(jnp.float32) * (q.shape[-1] ** -0.5)
    num = jnp.einsum("bhpq,bhq->bhp", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n, qf)),
                      jnp.exp(-m_new))
    y = num / den[..., None]
    return y.astype(COMPUTE_DTYPE), {"C": C, "n": n, "m": m_new}


def mlstm_apply(params, x, cfg: ModelConfig, cache: Dict | None = None):
    B, L, _ = x.shape
    d_in, H, Pdim = mlstm_dims(cfg)
    up = x.astype(COMPUTE_DTYPE) @ params["up_proj"].astype(COMPUTE_DTYPE)
    z, xi = up[..., :d_in], up[..., d_in:]
    conv_state = cache["conv"] if cache is not None else None
    xi, new_conv = _causal_conv(xi, params["conv_w"].astype(COMPUTE_DTYPE),
                                params["conv_b"].astype(COMPUTE_DTYPE),
                                conv_state)
    qkv = xi @ params["wqkv"].astype(COMPUTE_DTYPE)
    q, k, v = [t.reshape(B, L, H, Pdim) for t in jnp.split(qkv, 3, axis=-1)]
    gates = (xi @ params["wif"].astype(COMPUTE_DTYPE)).astype(jnp.float32) \
        + params["if_bias"]
    log_i = jnp.minimum(gates[..., :H], 15.0)   # exponential input gate (capped)
    log_f = jax.nn.log_sigmoid(gates[..., H:])

    if L == 1 and cache is not None:
        y, new_rec = _mlstm_step(q[:, 0], k[:, 0], v[:, 0],
                                 log_i[:, 0], log_f[:, 0],
                                 {k_: cache[k_] for k_ in ("C", "n", "m")})
        y = y[:, None]
    else:
        init = ({k_: cache[k_] for k_ in ("C", "n", "m")}
                if cache is not None else None)
        y, new_rec = _mlstm_chunked(q, k, v, log_i, log_f, cfg.chunk_size,
                                    init)
        if cache is None:
            new_rec = None

    y = y.reshape(B, L, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(COMPUTE_DTYPE),
                 params["norm"], cfg.norm_eps)
    out = y @ params["down_proj"].astype(COMPUTE_DTYPE)
    new_cache = None
    if cache is not None:
        new_cache = dict(new_rec or {}, conv=new_conv)
    return out, new_cache


def mlstm_init_cache(cfg: ModelConfig, batch: int) -> Dict:
    d_in, H, Pdim = mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, Pdim, Pdim), jnp.float32),
        "n": jnp.zeros((batch, H, Pdim), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, d_in), COMPUTE_DTYPE),
    }


# ================================= sLSTM ========================================

def slstm_init(key, cfg: ModelConfig) -> Tuple[Dict, Dict]:
    d = cfg.d_model
    H = cfg.ssm_heads or cfg.n_heads
    Pdim = d // H
    ks = jax.random.split(key, 3)
    params = {
        # gates i,f,z,o from input
        "w_gates": dense_init(ks[0], d, 4 * d),
        # recurrent per-head block-diagonal weights (H, P, 4P)
        "r_gates": jax.random.normal(ks[1], (H, Pdim, 4 * Pdim), jnp.float32)
                   * (Pdim ** -0.5),
        "gate_bias": jnp.concatenate(
            [jnp.zeros((d,)), 3.0 * jnp.ones((d,)), jnp.zeros((2 * d,))]),
        "norm": jnp.ones((d,), jnp.float32),
        "out_proj": dense_init(ks[2], d, d, scale=d ** -0.5),
    }
    specs = {"w_gates": P(None, "model"), "r_gates": P(None, None, None),
             "gate_bias": P(None), "norm": P(None),
             "out_proj": P(None, "model")}
    return params, specs


def slstm_apply(params, x, cfg: ModelConfig, cache: Dict | None = None):
    """Sequential scan over time (sLSTM has true recurrence; no parallel form
    exists — DESIGN.md notes this). x: (B,L,d)."""
    B, L, d = x.shape
    H = cfg.ssm_heads or cfg.n_heads
    Pd = d // H
    wx = (x.astype(COMPUTE_DTYPE) @ params["w_gates"].astype(COMPUTE_DTYPE)
          ).astype(jnp.float32) + params["gate_bias"]           # (B,L,4d)
    wx = wx.reshape(B, L, 4, H, Pd)

    if cache is None:
        state = {
            "c": jnp.zeros((B, H, Pd), jnp.float32),
            "n": jnp.ones((B, H, Pd), jnp.float32),
            "h": jnp.zeros((B, H, Pd), jnp.float32),
            "m": jnp.zeros((B, H, Pd), jnp.float32),
        }
    else:
        state = cache

    r = params["r_gates"]                                       # (H,P,4P)

    def step(st, wxt):
        rh = jnp.einsum("bhp,hpq->bhq", st["h"], r).reshape(B, H, 4, Pd)
        rh = jnp.moveaxis(rh, 2, 0)                             # (4,B,H,P)
        pre_i = wxt[:, 0] + rh[0]
        pre_f = wxt[:, 1] + rh[1]
        pre_z = wxt[:, 2] + rh[2]
        pre_o = wxt[:, 3] + rh[3]
        m_new = jnp.maximum(pre_f + st["m"], pre_i)
        i_g = jnp.exp(pre_i - m_new)
        f_g = jnp.exp(pre_f + st["m"] - m_new)
        z_g = jnp.tanh(pre_z)
        o_g = jax.nn.sigmoid(pre_o)
        c = f_g * st["c"] + i_g * z_g
        n = f_g * st["n"] + i_g
        h = o_g * c / jnp.maximum(n, 1.0)
        return {"c": c, "n": n, "h": h, "m": m_new}, h

    wx_t = jnp.moveaxis(wx, 1, 0)                               # (L,B,4,H,P)
    state, hs = jax.lax.scan(step, state, wx_t)
    y = jnp.moveaxis(hs, 0, 1).reshape(B, L, d)                 # (B,L,d)
    y = rms_norm(y.astype(COMPUTE_DTYPE), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"].astype(COMPUTE_DTYPE)
    new_cache = state if cache is not None else None
    return out, new_cache


def slstm_init_cache(cfg: ModelConfig, batch: int) -> Dict:
    H = cfg.ssm_heads or cfg.n_heads
    Pd = cfg.d_model // H
    return {
        "c": jnp.zeros((batch, H, Pd), jnp.float32),
        "n": jnp.ones((batch, H, Pd), jnp.float32),
        "h": jnp.zeros((batch, H, Pd), jnp.float32),
        "m": jnp.zeros((batch, H, Pd), jnp.float32),
    }
