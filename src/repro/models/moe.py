"""Mixture-of-Experts with Sphere bucket-shuffle dispatch.

The paper's bucket shuffle (§3.2) *is* expert dispatch: record = token,
bucket = expert, capacity factor = the scheduler's segment-size clamp
(§3.5.1), dropped-on-overflow = the same bounded-skew contract. The
``sphere`` implementation routes tokens through
:func:`repro.core.shuffle.sphere_shuffle` / ``sphere_combine`` over the
``model`` mesh axis (expert parallelism); the ``dense`` implementation is the
einsum/one-hot dispatch baseline (Switch-Transformer style) used for small
token counts (decode) and as the paper-technique-ablation baseline.

Experts are zero-padded to a multiple of the expert-parallel axis (qwen2-moe:
60 -> 64); the router never selects padding experts.
"""

from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map

from repro.configs.base import ModelConfig
from repro.core.shuffle import ShufflePlan
from repro.kernels.ops import partition_pack
from repro.models.layers import COMPUTE_DTYPE, dense_init


def padded_experts(cfg: ModelConfig, tp: int = 16) -> int:
    e = cfg.num_experts
    return ((e + tp - 1) // tp) * tp


def moe_init(key, cfg: ModelConfig, tp: int = 16) -> Tuple[Dict, Dict]:
    e_pad = padded_experts(cfg, tp)
    d, f = cfg.d_model, cfg.expert_d_ff
    ks = jax.random.split(key, 7)

    def experts(k):
        w = jax.random.normal(k, (e_pad, d, f), jnp.float32) * (d ** -0.5)
        return w.at[cfg.num_experts:].set(0.0)

    params = {
        "router": dense_init(ks[0], d, cfg.num_experts, scale=0.02),
        "w_gate": experts(ks[1]),
        "w_up": experts(ks[2]),
        "w_down": jax.random.normal(ks[3], (e_pad, f, d), jnp.float32)
                  * (f ** -0.5),
    }
    specs = {
        "router": P(None, None),
        "w_gate": P("model", None, None),
        "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }
    if cfg.n_shared_experts:
        fs = cfg.shared_d_ff * cfg.n_shared_experts
        params["ws_gate"] = dense_init(ks[4], d, fs)
        params["ws_up"] = dense_init(ks[5], d, fs)
        params["ws_down"] = dense_init(ks[6], fs, d, scale=fs ** -0.5)
        params["shared_gate"] = dense_init(ks[4], d, 1, scale=0.02)
        specs.update({"ws_gate": P(None, "model"), "ws_up": P(None, "model"),
                      "ws_down": P("model", None), "shared_gate": P(None, None)})
    return params, specs


def _route(params, x_flat, cfg: ModelConfig):
    """Router: top-k expert ids + renormalized probs (fp32)."""
    logits = (x_flat.astype(jnp.float32) @ params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # load-balance aux (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i, cfg.num_experts, dtype=jnp.float32), 1),
        axis=0) / cfg.top_k
    aux = cfg.num_experts * jnp.sum(me * ce)
    return top_i.astype(jnp.int32), top_p.astype(jnp.float32), aux


def _expert_ffn(w_gate, w_up, w_down, xe):
    """xe: (E_loc, C, d) tokens grouped per local expert."""
    xe = xe.astype(COMPUTE_DTYPE)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate.astype(COMPUTE_DTYPE)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, w_up.astype(COMPUTE_DTYPE))
    return jnp.einsum("ecf,efd->ecd", h, w_down.astype(COMPUTE_DTYPE))


def _shared_ffn(params, x):
    x = x.astype(COMPUTE_DTYPE)
    h = jax.nn.silu(x @ params["ws_gate"].astype(COMPUTE_DTYPE))
    h = h * (x @ params["ws_up"].astype(COMPUTE_DTYPE))
    out = h @ params["ws_down"].astype(COMPUTE_DTYPE)
    g = jax.nn.sigmoid((x @ params["shared_gate"].astype(COMPUTE_DTYPE))
                       .astype(jnp.float32))
    return out * g.astype(COMPUTE_DTYPE)


# -- sphere (bucket shuffle) dispatch ----------------------------------------------

def _moe_sphere_local(params_local, x_local, cfg: ModelConfig,
                      plan: ShufflePlan):
    """Runs inside shard_map. x_local: (b, s_loc, d) — tokens sharded over
    the expert-parallel axes so every rank contributes distinct tokens. The
    plan decides the wire pattern: flat all_to_all over one axis, or the
    two-level (dc, node) WAN shuffle for cross-data-center expert
    parallelism."""
    b, s_loc, d = x_local.shape
    n = b * s_loc
    x_flat = x_local.reshape(n, d)
    top_i, top_p, aux = _route(params_local, x_flat, cfg)

    k = cfg.top_k
    ep = plan.num_devices
    # records: token replicated k times, carrying its routing prob.
    # bf16 on the wire: halves the all-to-all bytes (§Perf H4); the prob
    # column round-trips bf16 with ~3 decimal digits — enough for combine
    # weighting (top-k probs are O(0.1)).
    rec = jnp.concatenate(
        [jnp.repeat(x_flat, k, axis=0).astype(COMPUTE_DTYPE),
         top_p.reshape(n * k, 1).astype(COMPUTE_DTYPE)], axis=1)
    buckets = top_i.reshape(n * k)
    num_buckets = plan.num_buckets
    res = plan.shuffle(rec, buckets)

    # local regroup (stage C of the shuffle, on-device): received rows ->
    # (E_loc, C2, d) per local expert, via the same fused O(n)
    # partition/pack the send path uses (no sort in the dispatch hot loop)
    e_loc = num_buckets // ep
    me = plan.device_index()
    flat = res.data.reshape(-1, d + 1)
    fvalid = res.valid.reshape(-1)
    fbucket = res.bucket.reshape(-1) - me * e_loc       # local expert idx
    n_recv = flat.shape[0]
    c2 = int(n_recv / e_loc * cfg.capacity_factor) + 1
    dest = jnp.where(fvalid, fbucket, e_loc)            # invalid -> overflow
    (grouped,), in_rng, origin, _ = partition_pack(
        [flat], dest, e_loc, c2, use_pallas=plan.use_pallas)
    xe, pe = grouped[..., :d], grouped[..., d]

    ye = _expert_ffn(params_local["w_gate"], params_local["w_up"],
                     params_local["w_down"], xe)
    ye = ye * pe[..., None].astype(COMPUTE_DTYPE)       # weight by router prob
    ye = ye * in_rng[..., None].astype(COMPUTE_DTYPE)

    # inverse regroup: back to the received-row layout (origin = the source
    # row of each (expert, slot), the exact inverse gather)
    back = jnp.zeros((n_recv + 1, d), COMPUTE_DTYPE)
    scatter_rows = jnp.where(in_rng, origin, n_recv)
    back = back.at[scatter_rows.reshape(-1)].set(
        ye.reshape(-1, d), mode="drop")[:n_recv]
    processed = back.reshape(res.data.shape[0], -1, d)

    # combine back to the n*k record rows (src_pos indexes the k-duplicated
    # record array), then sum each token's k expert contributions
    combined, _ = plan.combine(processed, res, n * k)
    out = combined.reshape(n, k, d).sum(axis=1).reshape(b, s_loc, d)
    for a in plan.pmean_axes():
        aux = jax.lax.pmean(aux, a)
    dropped = res.dropped
    return out, aux, dropped


def moe_apply_sphere(params, x, cfg: ModelConfig, mesh: Mesh,
                     dp_axes: Sequence[str], tp_axis: str = "model",
                     ep_axes: Optional[Sequence[str]] = None,
                     chunks: int = 1):
    """x: (B, S, d) with S divisible by the tp axis size.

    ``ep_axes=(dc_axis, node_axis)`` spreads the experts over *both* axes —
    wide-area expert parallelism, with tokens crossing the DC boundary via
    the hierarchical two-level shuffle (batch shards over the dc axis,
    sequence over the node axis). ``chunks=W`` pipelines the dispatch
    shuffle: the token stream splits into W chunks whose partition/pack
    overlaps the previous chunk's all_to_all (send-buffer memory drops ~W×).
    """
    b, s, d = x.shape
    k = cfg.top_k
    if ep_axes is not None:
        ep_axes = tuple(ep_axes)
        ep = math.prod(mesh.shape[a] for a in ep_axes)
        n_local = (b // mesh.shape[ep_axes[0]]) * (s // mesh.shape[ep_axes[1]])
        x_spec = P(ep_axes[0], ep_axes[1], None)
        w_spec = P(ep_axes, None, None)
    else:
        ep_axes = (tp_axis,)
        ep = mesh.shape[tp_axis]
        dp = tuple(dp_axes)
        n_local = (b // math.prod(mesh.shape[a] for a in dp)) * (s // ep)
        x_spec = P(dp, tp_axis, None)
        w_spec = P(tp_axis, None, None)
    plan = ShufflePlan.for_mesh(mesh, padded_experts(cfg, ep), n_local * k,
                                cfg.capacity_factor, ep_axes, chunks=chunks)

    def body(p, xin):
        out, aux, dropped = _moe_sphere_local(p, xin, cfg, plan)
        return out, aux, dropped

    routed = {k: params[k] for k in ("router", "w_gate", "w_up", "w_down")}
    param_specs = {"router": P(None, None), "w_gate": w_spec,
                   "w_up": w_spec, "w_down": w_spec}

    out, aux, dropped = shard_map(
        body, mesh=mesh,
        in_specs=(param_specs, x_spec),
        out_specs=(x_spec, P(), P()),
        check_vma=False,
    )(routed, x)
    shared = _shared_ffn(params, x) if cfg.n_shared_experts else 0.0
    return out + shared, {"moe_aux": aux, "moe_dropped": dropped}


# -- dense (einsum one-hot) dispatch ------------------------------------------------

def moe_apply_dense(params, x, cfg: ModelConfig):
    """Switch-style capacity dispatch with one-hot einsums; no shard_map.
    Used for decode (tiny token counts) and as the non-paper baseline."""
    b, s, d = x.shape
    n = b * s
    x_flat = x.reshape(n, d)
    top_i, top_p, aux = _route(params, x_flat, cfg)
    e_pad = params["w_gate"].shape[0]
    k = cfg.top_k
    cap = max(int(n * k / cfg.num_experts * cfg.capacity_factor), 1)

    oh = jax.nn.one_hot(top_i, e_pad, dtype=jnp.float32)       # (n, k, E)
    # position of each (token, slot) within its expert
    pos = jnp.cumsum(oh.reshape(n * k, e_pad), axis=0) - 1.0   # (n*k, E)
    pos = jnp.sum(pos.reshape(n, k, e_pad) * oh, axis=-1)      # (n, k)
    keep = pos < cap
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
    disp = jnp.einsum("nke,nkc->nkec", oh, pos_oh) * keep[..., None, None]
    dispatch = jnp.sum(disp, axis=1)                           # (n, E, C)
    xe = jnp.einsum("nec,nd->ecd", dispatch, x_flat.astype(jnp.float32))
    ye = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"], xe)
    comb = jnp.einsum("nkec,nk->nec", disp, top_p)
    out = jnp.einsum("nec,ecd->nd", comb, ye.astype(jnp.float32))
    dropped = jnp.sum(1.0 - keep.astype(jnp.float32))
    out = out.reshape(b, s, d).astype(COMPUTE_DTYPE)
    if cfg.n_shared_experts:
        out = out + _shared_ffn(params, x)
    return out, {"moe_aux": aux, "moe_dropped": dropped}


def moe_apply(params, x, cfg: ModelConfig, mesh: Optional[Mesh] = None,
              dp_axes: Sequence[str] = ("data",), tp_axis: str = "model",
              ep_axes: Optional[Sequence[str]] = None, chunks: int = 1):
    """Select implementation: sphere bucket shuffle when the sequence can be
    sharded over the expert axis, dense einsum otherwise. ``ep_axes``
    requests wide-area (two-level) expert parallelism over a (dc, node)
    axis pair — see :func:`moe_apply_sphere`.

    Like the flat gate below, ``ep_axes`` is a preference, not a demand:
    when the mesh lacks the axes or the batch/sequence don't divide them,
    this falls back to the flat or dense path silently (decode shapes hit
    this constantly). Call :func:`moe_apply_sphere` directly to get a hard
    error instead."""
    if (ep_axes is not None and mesh is not None and len(ep_axes) == 2
            and all(a in mesh.shape for a in ep_axes)):
        dcs, nodes = (mesh.shape[a] for a in ep_axes)
        if (cfg.moe_impl == "sphere" and x.shape[0] % dcs == 0
                and x.shape[1] % nodes == 0 and dcs * nodes > 1):
            return moe_apply_sphere(params, x, cfg, mesh, dp_axes, tp_axis,
                                    ep_axes=ep_axes, chunks=chunks)
    use_sphere = (
        cfg.moe_impl == "sphere" and mesh is not None
        and tp_axis in mesh.shape and x.shape[1] % mesh.shape[tp_axis] == 0
        and mesh.shape[tp_axis] > 1
    )
    if use_sphere:
        return moe_apply_sphere(params, x, cfg, mesh, dp_axes, tp_axis,
                                chunks=chunks)
    return moe_apply_dense(params, x, cfg)
