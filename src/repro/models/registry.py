"""Arch registry: build(config) -> Model bundle.

The bundle exposes a uniform surface for the trainer, server and dry-run:

  init(key)                 -> (params, param_specs)
  train_loss(params, batch) -> (loss, metrics)           [kind=train]
  prefill(params, batch)    -> (logits, caches)          [kind=prefill]
  decode_step(params, caches, batch) -> (logits, caches) [kind=decode]
  init_caches(batch, max_len)
  input_specs(shape)        -> dict of ShapeDtypeStructs (+ batch sharding)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec, SHAPES
from repro.models import encdec, transformer
from repro.models.layers import COMPUTE_DTYPE


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Callable
    train_loss: Callable            # (params, batch, mesh, dp_axes)
    prefill: Callable               # (params, batch, caches, mesh, dp_axes)
    decode_step: Callable           # (params, caches, batch, mesh, dp_axes)
    init_caches: Callable           # (batch, max_len)
    input_specs: Callable           # (shape_name) -> dict of SDS
    batch_specs: Callable           # (shape_name, dp) -> dict of PartitionSpec
    cache_specs: Callable           # (shape_name, dp) -> pytree of P


def _token_sds(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def _dp(b: int, dp_axes) -> Any:
    """Batch-dim sharding entry: the dp axes when the batch is shardable."""
    return None if b <= 1 else (tuple(dp_axes) if len(dp_axes) > 1
                                else dp_axes[0])


def _kv_spec(cfg: ModelConfig, tp: int = 16):
    return "model" if cfg.n_kv_heads % tp == 0 else None


def _layer_cache_spec(cfg: ModelConfig, kind: str, b, dp_axes,
                      shard_t: bool = False):
    """PartitionSpec dict for one layer's cache."""
    bs = _dp(b, dp_axes)
    tspec = (tuple(dp_axes) if len(dp_axes) > 1 else dp_axes[0]) \
        if shard_t else None
    if kind in ("dense", "moe", "shared_attn"):
        if cfg.attn_type == "mla":
            return {"ckv": P(bs, tspec, None), "k_rope": P(bs, tspec, None),
                    "pos": P(bs, tspec)}
        kv = _kv_spec(cfg)
        return {"k": P(bs, tspec, kv, None), "v": P(bs, tspec, kv, None),
                "pos": P(bs, tspec)}
    if kind == "mamba":
        from repro.models.ssm import mamba2_dims
        _, h, _ = mamba2_dims(cfg)
        hs = "model" if h % 16 == 0 else None
        return {"ssm": P(bs, hs, None, None),
                "conv_x": P(bs, None, "model"),
                "conv_bc": P(bs, None, None)}
    if kind == "mlstm":
        from repro.models.ssm import mlstm_dims
        _, h, _ = mlstm_dims(cfg)
        hs = "model" if h % 16 == 0 else None
        return {"C": P(bs, hs, None, None), "n": P(bs, hs, None),
                "m": P(bs, hs), "conv": P(bs, None, "model")}
    if kind == "slstm":
        h = cfg.ssm_heads or cfg.n_heads
        hs = "model" if h % 16 == 0 else None
        return {k: P(bs, hs, None) for k in ("c", "n", "h", "m")}
    raise ValueError(kind)


def build(cfg: ModelConfig) -> Model:
    if cfg.family == "audio":
        return _build_encdec(cfg)
    return _build_lm(cfg)


# -- decoder-only LMs (incl. moe / ssm / hybrid / vlm) ----------------------------

def _build_lm(cfg: ModelConfig) -> Model:
    def init(key):
        return transformer.init_params(key, cfg)

    def train_loss(params, batch, mesh=None, dp_axes=("data",)):
        return transformer.train_loss(params, cfg, batch, mesh, dp_axes)

    def prefill(params, batch, caches, mesh=None, dp_axes=("data",)):
        # q_pos covers img_tokens + text (built inside lm_forward from the
        # full embedded length); only next-token logits are materialized.
        logits, caches, _ = transformer.lm_forward(
            params, cfg, batch["tokens"], q_pos=None, caches=caches,
            mesh=mesh, dp_axes=dp_axes,
            img_embeds=batch.get("img_embeds"), last_only=True)
        return logits, caches

    def decode_step(params, caches, batch, mesh=None, dp_axes=("data",)):
        tokens = batch["tokens"]                       # (B, 1)
        q_pos = batch["pos"]                           # (B, 1) int32
        logits, caches, _ = transformer.lm_forward(
            params, cfg, tokens, q_pos=q_pos, caches=caches, mesh=mesh,
            dp_axes=dp_axes)
        return logits, caches

    def init_caches(batch, max_len):
        return transformer.init_caches(cfg, batch, max_len)

    def input_specs(shape_name: str) -> Dict[str, Any]:
        sp = SHAPES[shape_name]
        b = sp.global_batch
        if sp.kind == "train":
            s = sp.seq_len
            out = {"tokens": _token_sds(b, s), "labels": _token_sds(b, s)}
            if cfg.family == "vlm":
                s_text = s - cfg.img_tokens
                out = {"tokens": _token_sds(b, s_text),
                       "labels": _token_sds(b, s_text),
                       "img_embeds": jax.ShapeDtypeStruct(
                           (b, cfg.img_tokens, cfg.d_model), COMPUTE_DTYPE)}
            return out
        if sp.kind == "prefill":
            out = {"tokens": _token_sds(b, sp.seq_len)}
            if cfg.family == "vlm":
                out = {"tokens": _token_sds(b, sp.seq_len - cfg.img_tokens),
                       "img_embeds": jax.ShapeDtypeStruct(
                           (b, cfg.img_tokens, cfg.d_model), COMPUTE_DTYPE)}
            return out
        # decode: one new token against a cache of seq_len
        return {"tokens": _token_sds(b, 1),
                "pos": jax.ShapeDtypeStruct((b, 1), jnp.int32)}

    def batch_specs(shape_name: str, dp=("pod", "data")) -> Dict[str, Any]:
        sp = SHAPES[shape_name]
        bs = _dp(sp.global_batch, dp)
        specs = {"tokens": P(bs, None), "labels": P(bs, None),
                 "pos": P(bs, None), "img_embeds": P(bs, None, None)}
        return {k: specs[k] for k in input_specs(shape_name)}

    def cache_specs(shape_name: str, dp=("pod", "data")):
        sp = SHAPES[shape_name]
        b = sp.global_batch
        shard_t = (b == 1 and cfg.attn_type != "swa")  # long-context: shard T
        pattern = transformer.layer_pattern(cfg)
        from repro.models.transformer import _shared_attn_points
        shared_pts = _shared_attn_points(cfg)
        homogeneous = cfg.scan_layers and len(set(pattern)) == 1 \
            and pattern[0] in ("dense", "moe") and not shared_pts
        if homogeneous:
            one = _layer_cache_spec(cfg, pattern[0], b, dp, shard_t)
            return jax.tree.map(lambda s: P(*((None,) + tuple(s))), one,
                                is_leaf=lambda x: isinstance(x, P))
        specs = [_layer_cache_spec(cfg, k, b, dp, shard_t) for k in pattern]
        for _ in shared_pts:
            specs.append(_layer_cache_spec(cfg, "shared_attn", b, dp, shard_t))
        return specs

    return Model(cfg, init, train_loss, prefill, decode_step, init_caches,
                 input_specs, batch_specs, cache_specs)


# -- whisper (enc-dec) --------------------------------------------------------------

def _build_encdec(cfg: ModelConfig) -> Model:
    def init(key):
        return encdec.init_params(key, cfg)

    def train_loss(params, batch, mesh=None, dp_axes=("data",)):
        return encdec.train_loss(params, cfg, batch, mesh, dp_axes)

    def prefill(params, batch, caches, mesh=None, dp_axes=("data",)):
        enc_out = encdec.encode(params, cfg, batch["frames"])
        logits, caches = encdec.decode_stack(params, cfg, batch["tokens"],
                                             enc_out, caches=caches)
        return logits, caches

    def decode_step(params, caches, batch, mesh=None, dp_axes=("data",)):
        # enc_out recomputed from stub frames would be wasteful; serve path
        # carries it in the batch.
        logits, caches = encdec.decode_stack(
            params, cfg, batch["tokens"], batch["enc_out"],
            q_pos=batch["pos"], caches=caches)
        return logits, caches

    def init_caches(batch, max_len):
        return encdec.init_caches(cfg, batch, max_len)

    def input_specs(shape_name: str) -> Dict[str, Any]:
        sp = SHAPES[shape_name]
        b = sp.global_batch
        frames = jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model),
                                      COMPUTE_DTYPE)
        if sp.kind == "train":
            return {"frames": frames,
                    "tokens": _token_sds(b, sp.seq_len),
                    "labels": _token_sds(b, sp.seq_len)}
        if sp.kind == "prefill":
            return {"frames": frames, "tokens": _token_sds(b, sp.seq_len)}
        return {"tokens": _token_sds(b, 1),
                "pos": jax.ShapeDtypeStruct((b, 1), jnp.int32),
                "enc_out": jax.ShapeDtypeStruct(
                    (b, cfg.enc_seq, cfg.d_model), COMPUTE_DTYPE)}

    def batch_specs(shape_name: str, dp=("pod", "data")) -> Dict[str, Any]:
        sp = SHAPES[shape_name]
        bs = _dp(sp.global_batch, dp)
        specs = {"frames": P(bs, None, None), "tokens": P(bs, None),
                 "labels": P(bs, None), "pos": P(bs, None),
                 "enc_out": P(bs, None, None)}
        return {k: specs[k] for k in input_specs(shape_name)}

    def cache_specs(shape_name: str, dp=("pod", "data")):
        sp = SHAPES[shape_name]
        one = _layer_cache_spec(cfg, "dense", sp.global_batch, dp)
        return jax.tree.map(lambda s: P(*((None,) + tuple(s))), one,
                            is_leaf=lambda x: isinstance(x, P))

    return Model(cfg, init, train_loss, prefill, decode_step, init_caches,
                 input_specs, batch_specs, cache_specs)
