"""Attention variants: GQA/MQA (full causal), sliding-window (SWA), MLA
(multi-head latent attention), cross-attention — with KV-cache decode paths.

Cache contracts:
- GQA:  {"k","v": (B, T_cache, KV, hd), "pos": (B, T_cache) int32}  where
  ``pos`` holds the absolute position stored in each slot (-1 = empty). SWA
  uses a **ring buffer** of T_cache = window slots, so a 500k-context danube
  cache is O(window), not O(seq).
- MLA:  {"ckv": (B, T, kv_rank), "k_rope": (B, T, rope_dim), "pos": (B, T)}
  — the latent cache, (kv_rank + rope_dim) per position instead of
  2*H*hd; ``absorb=True`` additionally computes scores in latent space
  (weight absorption) so decode never materializes per-head K/V.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import COMPUTE_DTYPE, apply_rope, dense_init, rms_norm

NEG_INF = -1e30


# -- init -----------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig) -> Tuple[Dict, Dict]:
    if cfg.attn_type == "mla":
        return _mla_init(key, cfg)
    hd = cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "wq": dense_init(k1, cfg.d_model, cfg.n_heads * hd),
        "wk": dense_init(k2, cfg.d_model, cfg.n_kv_heads * hd),
        "wv": dense_init(k3, cfg.d_model, cfg.n_kv_heads * hd),
        "wo": dense_init(k4, cfg.n_heads * hd, cfg.d_model,
                         scale=(cfg.n_heads * hd) ** -0.5),
    }
    # Measured sharding rules (EXPERIMENTS.md §Perf H2/H3, train_4k
    # collective seconds on the 16x16 mesh):
    #   KV % tp == 0            -> full head sharding (clean TP).
    #   KV == 1,  H % tp == 0   -> q head-sharded, kv REPLICATED (granite:
    #                              23.8 -> 17.6; split-dim kv makes XLA
    #                              shard the score contraction).
    #   1<KV<tp,  H % tp == 0   -> kv SPLIT-DIM column sharding (tinyllama
    #                              2.0s / qwen3 1.9s; replicated kv + sharded
    #                              q factorizes scores over (KV,G) and the
    #                              backward full-remats: 103 GB/dev f32).
    #   H % tp != 0             -> replicate all, SEQUENCE-PARALLEL in apply
    #                              (internvl2 prefill: 58.3 -> 0.79s).
    tp = cfg.tp_size
    if cfg.n_heads % tp == 0:
        qs = "model"
        kvs = None if cfg.n_kv_heads == 1 else "model"
        wos = "model"
    else:
        qs = kvs = wos = None
    specs = {"wq": P(None, qs), "wk": P(None, kvs),
             "wv": P(None, kvs), "wo": P(wos, None)}
    if cfg.qk_norm:
        params["q_norm"] = jnp.ones((hd,), jnp.float32)
        params["k_norm"] = jnp.ones((hd,), jnp.float32)
        specs["q_norm"] = P(None)
        specs["k_norm"] = P(None)
    return params, specs


def _mla_init(key, cfg: ModelConfig) -> Tuple[Dict, Dict]:
    ks = jax.random.split(key, 7)
    H = cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    params = {
        "wq_down": dense_init(ks[0], cfg.d_model, cfg.q_lora_rank),
        "q_norm": jnp.ones((cfg.q_lora_rank,), jnp.float32),
        "wq_up": dense_init(ks[1], cfg.q_lora_rank, H * qd),
        "wkv_down": dense_init(ks[2], cfg.d_model,
                               cfg.kv_lora_rank + cfg.qk_rope_dim),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,), jnp.float32),
        "wk_up": dense_init(ks[3], cfg.kv_lora_rank, H * cfg.qk_nope_dim),
        "wv_up": dense_init(ks[4], cfg.kv_lora_rank, H * cfg.v_head_dim),
        "wo": dense_init(ks[5], H * cfg.v_head_dim, cfg.d_model,
                         scale=(H * cfg.v_head_dim) ** -0.5),
    }
    # MLA: split-dim column sharding measured BETTER than seq-parallel
    # (minicpm3 train_4k: 8.8s vs 14.2s) — the latent contraction keeps the
    # score partial-sums small (kv_lora_rank, not S x T).
    specs = {
        "wq_down": P(None, None), "q_norm": P(None),
        "wq_up": P(None, "model"),
        "wkv_down": P(None, None), "kv_norm": P(None),
        "wk_up": P(None, "model"), "wv_up": P(None, "model"),
        "wo": P("model", None),
    }
    return params, specs


def heads_shardable(cfg: ModelConfig) -> bool:
    """True when apply() should NOT insert sequence-parallel constraints
    (weights carry head/split-dim sharding instead)."""
    return cfg.n_heads % cfg.tp_size == 0


def _seq_shard(t, mesh, dp_axes):
    """Sequence-parallel constraint for indivisible-head attention: shard the
    q/score/out chain over S on the model axis (weights replicated, compute
    still fully parallel — over sequence instead of heads)."""
    if mesh is None or t.shape[1] <= 1:
        return t
    bs = (tuple(dp_axes) if len(dp_axes) > 1 else dp_axes[0]) \
        if t.shape[0] > 1 else None
    spec = P(bs, "model", *([None] * (t.ndim - 2)))
    return jax.lax.with_sharding_constraint(
        t, jax.sharding.NamedSharding(mesh, spec))


# -- shared score/combine core ----------------------------------------------------

def _sdpa(q, k, v, q_pos, kv_pos, *, causal: bool, window: Optional[int],
          scale: float, extra_score=None):
    """q: (B,S,H,hd); k,v: (B,T,KV,*); q_pos (B,S); kv_pos (B,T).
    Grouped-query attention with fp32 softmax; masks built from positions so
    the same code serves train/prefill/ring-buffer decode."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg.astype(COMPUTE_DTYPE),
                        k.astype(COMPUTE_DTYPE),
                        preferred_element_type=jnp.float32) * scale
    if extra_score is not None:
        scores = scores + extra_score  # MLA rope-part scores (B,1|KV,G,S,T)
    mask = kv_pos[:, None, :] >= 0                        # slot occupied
    if causal:
        mask = mask & (kv_pos[:, None, :] <= q_pos[:, :, None])
    if window is not None:
        mask = mask & (kv_pos[:, None, :] > q_pos[:, :, None] - window)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(COMPUTE_DTYPE)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(COMPUTE_DTYPE),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, H, v.shape[-1]).astype(COMPUTE_DTYPE)


def _cache_update(cache: Dict, new_k, new_v, q_pos):
    """Write new entries into the (possibly ring) cache. new_k/new_v:
    (B, S_new, KV, hd); q_pos: (B, S_new) absolute positions."""
    T = cache["k"].shape[1]
    slots = q_pos % T
    b_idx = jnp.arange(new_k.shape[0])[:, None]
    k = cache["k"].at[b_idx, slots].set(new_k.astype(cache["k"].dtype))
    v = cache["v"].at[b_idx, slots].set(new_v.astype(cache["v"].dtype))
    pos = cache["pos"].at[b_idx, slots].set(q_pos.astype(jnp.int32))
    return {"k": k, "v": v, "pos": pos}


# -- GQA / SWA ---------------------------------------------------------------------

def attn_apply(params, x, cfg: ModelConfig, q_pos,
               cache: Optional[Dict] = None, causal: bool = True,
               cross_kv: Optional[Tuple] = None, rope: bool = True,
               mesh=None, dp_axes=("data",)):
    """Self- or cross-attention over x (B,S,d).

    - training/prefill: cache=None -> keys/values from x itself.
    - decode: cache given -> append then attend over the cache.
    - cross: cross_kv=(k,v,kv_pos) precomputed from the encoder.
    """
    B, S, _ = x.shape
    hd = cfg.hd
    x = x.astype(COMPUTE_DTYPE)
    q = (x @ params["wq"].astype(COMPUTE_DTYPE)).reshape(B, S, cfg.n_heads, hd)
    if cross_kv is None:
        k = (x @ params["wk"].astype(COMPUTE_DTYPE)).reshape(B, S, cfg.n_kv_heads, hd)
        v = (x @ params["wv"].astype(COMPUTE_DTYPE)).reshape(B, S, cfg.n_kv_heads, hd)
        if cfg.qk_norm:
            q = rms_norm(q, params["q_norm"], cfg.norm_eps)
            k = rms_norm(k, params["k_norm"], cfg.norm_eps)
        if rope:
            q = apply_rope(q, q_pos, cfg.rope_theta)
            k = apply_rope(k, q_pos, cfg.rope_theta)
    else:
        if cfg.qk_norm:
            q = rms_norm(q, params["q_norm"], cfg.norm_eps)

    window = cfg.window if cfg.attn_type == "swa" else None
    scale = hd ** -0.5
    if not heads_shardable(cfg):
        q = _seq_shard(q, mesh, dp_axes)

    if cross_kv is not None:
        ck, cv, ckv_pos = cross_kv
        out = _sdpa(q, ck, cv, q_pos, ckv_pos, causal=False, window=None,
                    scale=scale)
        new_cache = cache
    elif cache is None:
        out = _sdpa(q, k, v, q_pos, q_pos, causal=causal, window=window,
                    scale=scale)
        new_cache = None
    else:
        new_cache = _cache_update(cache, k, v, q_pos)
        out = _sdpa(q, new_cache["k"], new_cache["v"], q_pos,
                    new_cache["pos"], causal=causal, window=window,
                    scale=scale)
    if not heads_shardable(cfg):
        out = _seq_shard(out, mesh, dp_axes)
    out = out.reshape(B, S, cfg.n_heads * hd) @ params["wo"].astype(COMPUTE_DTYPE)
    return out, new_cache


def init_cache_gqa(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=COMPUTE_DTYPE) -> Dict:
    T = min(max_len, cfg.window) if cfg.attn_type == "swa" else max_len
    return {
        "k": jnp.zeros((batch, T, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((batch, T, cfg.n_kv_heads, cfg.hd), dtype),
        "pos": jnp.full((batch, T), -1, jnp.int32),
    }


# -- MLA -----------------------------------------------------------------------------

def mla_apply(params, x, cfg: ModelConfig, q_pos,
              cache: Optional[Dict] = None, absorb: bool = False,
              mesh=None, dp_axes=("data",)):
    """DeepSeek-V2-style multi-head latent attention (MiniCPM3).

    The KV cache is the compressed latent (ckv, k_rope). ``absorb=False``
    materializes per-head K/V from the latent (paper-faithful baseline);
    ``absorb=True`` folds wk_up/wv_up into the query/output (decode
    optimization — scores computed directly in latent space)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope_d, vh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    x = x.astype(COMPUTE_DTYPE)

    cq = rms_norm(x @ params["wq_down"].astype(COMPUTE_DTYPE), params["q_norm"],
                  cfg.norm_eps)
    q = (cq @ params["wq_up"].astype(COMPUTE_DTYPE)).reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, q_pos, cfg.rope_theta)

    ckv_full = x @ params["wkv_down"].astype(COMPUTE_DTYPE)
    ckv = rms_norm(ckv_full[..., :cfg.kv_lora_rank], params["kv_norm"],
                   cfg.norm_eps)
    k_rope = ckv_full[..., cfg.kv_lora_rank:].reshape(B, S, 1, rope_d)
    k_rope = apply_rope(k_rope, q_pos, cfg.rope_theta)

    if cache is not None:
        T = cache["ckv"].shape[1]
        slots = q_pos % T
        b_idx = jnp.arange(B)[:, None]
        cache = {
            "ckv": cache["ckv"].at[b_idx, slots].set(
                ckv.astype(cache["ckv"].dtype)),
            "k_rope": cache["k_rope"].at[b_idx, slots].set(
                k_rope[:, :, 0].astype(cache["k_rope"].dtype)),
            "pos": cache["pos"].at[b_idx, slots].set(q_pos.astype(jnp.int32)),
        }
        ckv_t = cache["ckv"].astype(COMPUTE_DTYPE)
        k_rope_t = cache["k_rope"][:, :, None].astype(COMPUTE_DTYPE)
        kv_pos = cache["pos"]
    else:
        ckv_t, k_rope_t, kv_pos = ckv, k_rope, q_pos

    scale = (nope + rope_d) ** -0.5
    # rope-part scores (shared single kv head)
    s_rope = jnp.einsum("bshr,btkr->bkst", q_rope.astype(COMPUTE_DTYPE),
                        k_rope_t, preferred_element_type=jnp.float32)

    if absorb:
        # f32 operands: XLA:CPU's DotThunk rejects bf16xbf16->f32 for these
        # contraction patterns; on TPU the f32 upcast is the flash-style
        # accumulator anyway.
        wk = params["wk_up"].astype(jnp.float32).reshape(cfg.kv_lora_rank, H, nope)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32), wk)
        s_nope = jnp.einsum("bshr,btr->bhst", q_lat, ckv_t.astype(jnp.float32))
        scores = (s_nope + s_rope) * scale          # (B,H,S,T)
        mask = (kv_pos[:, None, :] >= 0) & (kv_pos[:, None, :] <= q_pos[:, :, None])
        scores = jnp.where(mask[:, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", probs, ckv_t.astype(jnp.float32))
        wv = params["wv_up"].astype(jnp.float32).reshape(cfg.kv_lora_rank, H, vh)
        out = jnp.einsum("bshr,rhv->bshv", o_lat, wv).astype(COMPUTE_DTYPE)
    else:
        T = ckv_t.shape[1]
        k_nope = (ckv_t @ params["wk_up"].astype(COMPUTE_DTYPE)).reshape(
            B, T, H, nope)
        val = (ckv_t @ params["wv_up"].astype(COMPUTE_DTYPE)).reshape(B, T, H, vh)
        s_nope = jnp.einsum("bshn,bthn->bhst", q_nope.astype(COMPUTE_DTYPE),
                            k_nope, preferred_element_type=jnp.float32)
        scores = (s_nope + s_rope) * scale
        mask = (kv_pos[:, None, :] >= 0) & (kv_pos[:, None, :] <= q_pos[:, :, None])
        scores = jnp.where(mask[:, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(COMPUTE_DTYPE)
        out = jnp.einsum("bhst,bthv->bshv", probs, val,
                         preferred_element_type=jnp.float32).astype(COMPUTE_DTYPE)

    out = out.reshape(B, S, H * vh) @ params["wo"].astype(COMPUTE_DTYPE)
    return out, cache


def init_cache_mla(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=COMPUTE_DTYPE) -> Dict:
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
    }
