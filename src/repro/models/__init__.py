"""Model zoo: the assigned architectures as composable JAX modules.

Everything is a pure pytree-of-arrays + functional apply (no framework
dependency). ``registry.build(config)`` returns a :class:`Model` bundle with
``init / train_loss / prefill / decode_step / init_cache / param_specs``.
"""

from repro.models.registry import build, Model

__all__ = ["build", "Model"]
