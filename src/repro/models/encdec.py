"""Whisper-style encoder-decoder (audio stub frontend).

The conv frontend is a STUB per the assignment: inputs are precomputed frame
embeddings (B, enc_seq, d_model). Positions are sinusoidal on both sides
(whisper uses sinusoidal-encoder/learned-decoder; we use computed sinusoids
on the decoder as well so the parameter shapes are decode-length-independent
— noted in DESIGN.md).

Decoder blocks: causal self-attention (cached at decode) + cross-attention
over the encoder output (K/V precomputed once at prefill) + MLP.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (COMPUTE_DTYPE, dense_init, embed_init,
                                 embed_lookup, lm_logits, mlp_apply, mlp_init,
                                 rms_norm, sinusoid_positions, softmax_xent)


def _enc_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    a, aspec = attn.attn_init(k1, cfg)
    m, mspec = mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_gated)
    return ({"ln1": jnp.ones((cfg.d_model,)), "attn": a,
             "ln2": jnp.ones((cfg.d_model,)), "mlp": m},
            {"ln1": P(None), "attn": aspec, "ln2": P(None), "mlp": mspec})


def _dec_block_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    a, aspec = attn.attn_init(k1, cfg)
    x_, xspec = attn.attn_init(k2, cfg)
    m, mspec = mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.mlp_gated)
    return ({"ln1": jnp.ones((cfg.d_model,)), "self_attn": a,
             "ln_x": jnp.ones((cfg.d_model,)), "cross_attn": x_,
             "ln2": jnp.ones((cfg.d_model,)), "mlp": m},
            {"ln1": P(None), "self_attn": aspec, "ln_x": P(None),
             "cross_attn": xspec, "ln2": P(None), "mlp": mspec})


def init_params(key, cfg: ModelConfig) -> Tuple[Dict, Dict]:
    keys = jax.random.split(key, 4)
    emb, emb_spec = embed_init(keys[0], cfg.vocab, cfg.d_model)
    enc = [_enc_block_init(k, cfg)
           for k in jax.random.split(keys[1], cfg.enc_layers)]
    dec = [_dec_block_init(k, cfg)
           for k in jax.random.split(keys[2], cfg.num_layers)]
    params = {
        "embed": emb,
        "enc_blocks": jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[p for p, _ in enc]),
        "dec_blocks": jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[p for p, _ in dec]),
        "enc_ln": jnp.ones((cfg.d_model,)),
        "final_ln": jnp.ones((cfg.d_model,)),
    }
    addl = lambda s: P(*((None,) + tuple(s)))
    specs = {
        "embed": emb_spec,
        "enc_blocks": jax.tree.map(addl, enc[0][1],
                                   is_leaf=lambda x: isinstance(x, P)),
        "dec_blocks": jax.tree.map(addl, dec[0][1],
                                   is_leaf=lambda x: isinstance(x, P)),
        "enc_ln": P(None), "final_ln": P(None),
    }
    return params, specs


def encode(params, cfg: ModelConfig, frames):
    """frames: (B, T_enc, d) stub embeddings -> encoder hidden (B, T_enc, d)."""
    B, T, _ = frames.shape
    x = frames.astype(COMPUTE_DTYPE) \
        + sinusoid_positions(T, cfg.d_model).astype(COMPUTE_DTYPE)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def body(h, bp):
        a, _ = attn.attn_apply(bp["attn"],
                               rms_norm(h, bp["ln1"], cfg.norm_eps),
                               cfg, pos, causal=False, rope=False)
        h = h + a
        f = mlp_apply(bp["mlp"], rms_norm(h, bp["ln2"], cfg.norm_eps),
                      cfg.mlp_gated)
        return h + f, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rms_norm(x, params["enc_ln"], cfg.norm_eps)


def _cross_kv(bp, cfg, enc_out):
    B, T, _ = enc_out.shape
    hd = cfg.hd
    k = (enc_out @ bp["cross_attn"]["wk"].astype(COMPUTE_DTYPE)
         ).reshape(B, T, cfg.n_kv_heads, hd)
    v = (enc_out @ bp["cross_attn"]["wv"].astype(COMPUTE_DTYPE)
         ).reshape(B, T, cfg.n_kv_heads, hd)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    return k, v, pos


def decode_stack(params, cfg: ModelConfig, tokens, enc_out, q_pos=None,
                 caches=None):
    """Decoder over tokens; enc_out precomputed. caches: stacked self-attn
    caches (decode) or None (teacher forcing)."""
    B, S = tokens.shape
    x = embed_lookup(params["embed"], tokens)
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = x + _sinusoid_at(q_pos, cfg.d_model).astype(COMPUTE_DTYPE)

    def body(h, xs):
        bp, c = xs
        a, new_c = attn.attn_apply(
            bp["self_attn"], rms_norm(h, bp["ln1"], cfg.norm_eps), cfg,
            q_pos, cache=c, causal=True, rope=False)
        h = h + a
        ck = _cross_kv(bp, cfg, enc_out)
        xa, _ = attn.attn_apply(
            bp["cross_attn"], rms_norm(h, bp["ln_x"], cfg.norm_eps), cfg,
            q_pos, cross_kv=ck, rope=False)
        h = h + xa
        f = mlp_apply(bp["mlp"], rms_norm(h, bp["ln2"], cfg.norm_eps),
                      cfg.mlp_gated)
        return h + f, new_c

    if cfg.remat:
        body = jax.checkpoint(body)
    x, new_caches = jax.lax.scan(body, x, (params["dec_blocks"], caches))
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x, cfg.logit_cap, cfg.vocab)
    return logits, (new_caches if caches is not None else None)


def _sinusoid_at(q_pos, d_model):
    """Sinusoid embedding evaluated at arbitrary positions (B,S)."""
    pos = q_pos.astype(jnp.float32)[..., None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)
    inv = jnp.exp(-dim * (jnp.log(10000.0) / (d_model // 2 - 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def train_loss(params, cfg: ModelConfig, batch, mesh=None, dp_axes=("data",)):
    enc_out = encode(params, cfg, batch["frames"])
    logits, _ = decode_stack(params, cfg, batch["tokens"], enc_out)
    loss = softmax_xent(logits, batch["labels"], batch.get("loss_mask"))
    return loss, {"loss": loss}


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    caches = [attn.init_cache_gqa(cfg, batch, max_len)
              for _ in range(cfg.num_layers)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
