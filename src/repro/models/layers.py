"""Shared building blocks: norms, RoPE, MLPs, embeddings, init helpers.

Convention: parameters are nested dicts of fp32 arrays; forward casts to
bf16 for matmuls (MXU) and keeps norms/softmax accumulation in fp32.
Each init helper returns ``(params, specs)`` where ``specs`` mirrors the
params pytree with ``PartitionSpec`` leaves ("model"-axis tensor parallel).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

COMPUTE_DTYPE = jnp.bfloat16


def dense_init(key, d_in: int, d_out: int, scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    return w


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(COMPUTE_DTYPE)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(seq: int, d_model: int) -> jnp.ndarray:
    """Whisper-style sinusoidal embeddings (encoder)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-dim * (jnp.log(10000.0) / (d_model // 2 - 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# -- MLP -----------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, gated: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    if gated:
        params = {
            "w_gate": dense_init(k1, d_model, d_ff),
            "w_up": dense_init(k2, d_model, d_ff),
            "w_down": dense_init(k3, d_ff, d_model, scale=d_ff ** -0.5),
        }
        specs = {"w_gate": P(None, "model"), "w_up": P(None, "model"),
                 "w_down": P("model", None)}
    else:
        params = {
            "w_up": dense_init(k2, d_model, d_ff),
            "w_down": dense_init(k3, d_ff, d_model, scale=d_ff ** -0.5),
        }
        specs = {"w_up": P(None, "model"), "w_down": P("model", None)}
    return params, specs


def mlp_apply(params, x: jnp.ndarray, gated: bool = True) -> jnp.ndarray:
    x = x.astype(COMPUTE_DTYPE)
    up = x @ params["w_up"].astype(COMPUTE_DTYPE)
    if gated:
        gate = x @ params["w_gate"].astype(COMPUTE_DTYPE)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    return h @ params["w_down"].astype(COMPUTE_DTYPE)


# -- embeddings ------------------------------------------------------------------

VOCAB_PAD = 128  # lane-aligned AND divisible by the model axis (16)


def padded_vocab(vocab: int) -> int:
    return (vocab + VOCAB_PAD - 1) // VOCAB_PAD * VOCAB_PAD


def embed_init(key, vocab: int, d_model: int):
    """Embedding table padded to a multiple of 128 rows so the vocab axis
    shards evenly over the model axis (published vocabs like 73448/51865/
    151655 are not divisible by 16). Padding rows are zero and their logits
    are masked to -inf in :func:`lm_logits`."""
    v_pad = padded_vocab(vocab)
    emb = jax.random.normal(key, (v_pad, d_model), jnp.float32) \
        * (d_model ** -0.5)
    emb = emb.at[vocab:].set(0.0)
    return emb, P("model", None)  # vocab-sharded


def embed_lookup(emb: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(emb.astype(COMPUTE_DTYPE), tokens, axis=0)


def lm_logits(emb: jnp.ndarray, x: jnp.ndarray, cap: float = 0.0,
              vocab: Optional[int] = None) -> jnp.ndarray:
    """Tied-embedding readout; fp32 logits (padded-vocab sharded). Padding
    columns are masked to -1e30 so softmax/argmax never see them."""
    logits = (x.astype(COMPUTE_DTYPE) @ emb.astype(COMPUTE_DTYPE).T).astype(jnp.float32)
    if cap > 0.0:
        logits = cap * jnp.tanh(logits / cap)
    if vocab is not None and vocab < emb.shape[0]:
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(col < vocab, logits, -1e30)
    return logits


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean cross-entropy over (optionally masked) positions; fp32."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)
