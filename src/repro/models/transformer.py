"""Decoder-only LM assembly: block patterns, scan-over-layers, remat, caches.

A *block pattern* maps each layer to a kind:
  dense  — attention (gqa/swa/mla) + MLP
  moe    — attention + MoE FFN (sphere-shuffle dispatch)
  mamba  — Mamba2 SSD block (zamba2)
  shared_attn — zamba2's weight-shared transformer block (applied between
                mamba blocks; weights stored once)
  mlstm / slstm — xLSTM blocks

Homogeneous stacks (all dense / all moe) are scanned with stacked params
(compile time ~O(1) in depth); heterogeneous stacks run as Python loops.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.layers import (COMPUTE_DTYPE, embed_init, embed_lookup,
                                 lm_logits, mlp_apply, mlp_init, rms_norm,
                                 softmax_xent)


def layer_pattern(cfg: ModelConfig) -> List[str]:
    if cfg.family == "moe":
        return ["moe"] * cfg.num_layers
    if cfg.family == "ssm":        # xlstm
        return ["slstm" if cfg.slstm_every and (i + 1) % cfg.slstm_every == 0
                else "mlstm" for i in range(cfg.num_layers)]
    if cfg.family == "hybrid":     # zamba2
        return ["mamba"] * cfg.num_layers
    return ["dense"] * cfg.num_layers


def _shared_attn_points(cfg: ModelConfig) -> List[int]:
    if cfg.family != "hybrid" or not cfg.attn_every:
        return []
    return [i for i in range(cfg.num_layers)
            if (i + 1) % cfg.attn_every == 0]


# -- init -----------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, kind: str):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind in ("dense", "moe", "shared_attn"):
        a_params, a_specs = attn.attn_init(k1, cfg) if cfg.attn_type != "mla" \
            else attn.attn_init(k1, cfg)
        params = {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
                  "attn": a_params,
                  "ln2": jnp.ones((cfg.d_model,), jnp.float32)}
        specs = {"ln1": P(None), "attn": a_specs, "ln2": P(None)}
        if kind == "moe":
            m_params, m_specs = moe_mod.moe_init(k2, cfg)
            params["moe"] = m_params
            specs["moe"] = m_specs
        else:
            d_ff = cfg.d_ff
            m_params, m_specs = mlp_init(k2, cfg.d_model, d_ff, cfg.mlp_gated)
            params["mlp"] = m_params
            specs["mlp"] = m_specs
        return params, specs
    if kind == "mamba":
        p, s = ssm.mamba2_init(k1, cfg)
        return ({"ln1": jnp.ones((cfg.d_model,), jnp.float32), "mamba": p},
                {"ln1": P(None), "mamba": s})
    if kind == "mlstm":
        p, s = ssm.mlstm_init(k1, cfg)
        return ({"ln1": jnp.ones((cfg.d_model,), jnp.float32), "cell": p},
                {"ln1": P(None), "cell": s})
    if kind == "slstm":
        p, s = ssm.slstm_init(k1, cfg)
        return ({"ln1": jnp.ones((cfg.d_model,), jnp.float32), "cell": p},
                {"ln1": P(None), "cell": s})
    raise ValueError(kind)


def init_params(key, cfg: ModelConfig) -> Tuple[Dict, Dict]:
    pattern = layer_pattern(cfg)
    keys = jax.random.split(key, cfg.num_layers + 3)
    emb, emb_spec = embed_init(keys[0], cfg.vocab, cfg.d_model)
    params: Dict[str, Any] = {"embed": emb,
                              "final_ln": jnp.ones((cfg.d_model,), jnp.float32)}
    specs: Dict[str, Any] = {"embed": emb_spec, "final_ln": P(None)}

    homogeneous = cfg.scan_layers and len(set(pattern)) == 1 \
        and pattern[0] in ("dense", "moe")
    if homogeneous:
        def one(k):
            return _block_init(k, cfg, pattern[0])
        stacked = [one(keys[i + 1]) for i in range(cfg.num_layers)]
        params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                        *[p for p, _ in stacked])
        specs["blocks"] = jax.tree.map(
            lambda s: P(*((None,) + tuple(s))), stacked[0][1],
            is_leaf=lambda x: isinstance(x, P))
    else:
        blocks, bspecs = [], []
        for i, kind in enumerate(pattern):
            p, s = _block_init(keys[i + 1], cfg, kind)
            blocks.append(p)
            bspecs.append(s)
        params["blocks"] = blocks
        specs["blocks"] = bspecs

    if _shared_attn_points(cfg):
        p, s = _block_init(keys[-1], cfg, "shared_attn")
        params["shared_attn"] = p
        specs["shared_attn"] = s
    if cfg.family == "vlm":
        # stub frontend projection for patch embeddings
        params["img_proj"] = jax.random.normal(
            keys[-2], (cfg.d_model, cfg.d_model), jnp.float32) \
            * (cfg.d_model ** -0.5)
        specs["img_proj"] = P(None, None)
    return params, specs


# -- block apply -------------------------------------------------------------------

def _constrain_residual(t, mesh, dp_axes):
    """REFUTED optimization (kept as a no-op for the record; EXPERIMENTS.md
    §Perf H1): pinning TP branch outputs to (dp, None, None) was hypothesized
    to force the model-axis all-reduce into bf16 at the block boundary.
    Measured: no change on dense archs (granite 17.6s -> 17.6s) and a 65x
    REGRESSION on MoE (qwen3 1.9s -> 122s) because the constraint fights the
    expert-parallel shard_map's (dp, "model", None) sequence sharding."""
    return t


def _attn_block(params, x, cfg: ModelConfig, q_pos, cache, mesh, dp_axes):
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    if cfg.attn_type == "mla":
        a, new_cache = attn.mla_apply(params["attn"], h, cfg, q_pos, cache,
                                      mesh=mesh, dp_axes=dp_axes)
    else:
        a, new_cache = attn.attn_apply(params["attn"], h, cfg, q_pos, cache,
                                       mesh=mesh, dp_axes=dp_axes)
    a = _constrain_residual(a, mesh, dp_axes)
    x = x + a * cfg.residual_scale
    h = rms_norm(x, params["ln2"], cfg.norm_eps)
    aux = {}
    if "moe" in params:
        f, aux = moe_mod.moe_apply(params["moe"], h, cfg, mesh, dp_axes)
    else:
        f = mlp_apply(params["mlp"], h, cfg.mlp_gated)
    f = _constrain_residual(f, mesh, dp_axes)
    x = x + f * cfg.residual_scale
    return x, new_cache, aux


def _apply_block(params, x, *, cfg: ModelConfig, kind: str, q_pos, cache,
                 mesh, dp_axes):
    if kind in ("dense", "moe", "shared_attn"):
        return _attn_block(params, x, cfg, q_pos, cache, mesh, dp_axes)
    if kind == "mamba":
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        y, new_cache = ssm.mamba2_apply(params["mamba"], h, cfg, cache)
        return x + _constrain_residual(y, mesh, dp_axes), new_cache, {}
    if kind == "mlstm":
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        y, new_cache = ssm.mlstm_apply(params["cell"], h, cfg, cache)
        return x + _constrain_residual(y, mesh, dp_axes), new_cache, {}
    if kind == "slstm":
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        y, new_cache = ssm.slstm_apply(params["cell"], h, cfg, cache)
        return x + _constrain_residual(y, mesh, dp_axes), new_cache, {}
    raise ValueError(kind)


def forward(params, cfg: ModelConfig, x, q_pos,
            caches: Optional[List] = None,
            mesh: Optional[Mesh] = None,
            dp_axes: Sequence[str] = ("data",)):
    """Run the block stack over embeddings x (B,S,d).

    Returns (hidden (B,S,d), new_caches, aux dict)."""
    pattern = layer_pattern(cfg)
    shared_pts = set(_shared_attn_points(cfg))
    aux_total: Dict[str, Any] = {}
    homogeneous = cfg.scan_layers and len(set(pattern)) == 1 \
        and pattern[0] in ("dense", "moe") and not shared_pts
    decode = caches is not None

    if homogeneous:
        blocks = params["blocks"]
        kind = pattern[0]

        def body(carry, xs):
            h = carry
            bp, c = xs
            h2, new_c, aux = _apply_block(bp, h, cfg=cfg, kind=kind,
                                          q_pos=q_pos, cache=c, mesh=mesh,
                                          dp_axes=dp_axes)
            out_aux = jnp.stack([aux.get("moe_aux", jnp.zeros(())),
                                 jnp.asarray(aux.get("moe_dropped", 0),
                                             jnp.float32)]) \
                if kind == "moe" else jnp.zeros((2,))
            return h2, (new_c, out_aux)

        if cfg.remat:
            body = jax.checkpoint(body)
        layer_caches = caches if decode else _none_stack(cfg.num_layers)
        x, (new_caches, auxs) = jax.lax.scan(body, x, (blocks, layer_caches))
        if pattern[0] == "moe":
            aux_total["moe_aux"] = jnp.mean(auxs[:, 0])
            aux_total["moe_dropped"] = jnp.sum(auxs[:, 1])
        new_caches = new_caches if decode else None
    else:
        new_caches = [] if decode else None
        # heterogeneous loop (xlstm / zamba2 / non-scanned).
        # shared-attn caches: one PER APPLICATION POINT (weights are shared
        # but each point sees different activations), appended after the
        # per-layer caches in application order.
        shared_caches_out = []
        n_shared_seen = 0

        def make_fn(kind_):
            base = functools.partial(_apply_block, cfg=cfg, kind=kind_,
                                     mesh=mesh, dp_axes=dp_axes)
            fn_ = lambda p, h, q, c: base(p, h, q_pos=q, cache=c)
            return jax.checkpoint(fn_) if cfg.remat else fn_

        for i, kind in enumerate(pattern):
            if i in shared_pts:
                c = caches[cfg.num_layers + n_shared_seen] if decode else None
                n_shared_seen += 1
                x, sc, _ = make_fn("shared_attn")(params["shared_attn"], x,
                                                  q_pos, c)
                if decode:
                    shared_caches_out.append(sc)
            c = caches[i] if decode else None
            x, new_c, aux = make_fn(kind)(params["blocks"][i], x, q_pos, c)
            for k2, v in aux.items():
                aux_total[k2] = aux_total.get(k2, 0.0) + v
            if decode:
                new_caches.append(new_c)
        if decode:
            new_caches.extend(shared_caches_out)

    return x, new_caches, aux_total


def _none_stack(n: int):
    return None


# -- caches ---------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Per-layer cache pytree matching forward()'s expectations."""
    pattern = layer_pattern(cfg)
    shared_pts = _shared_attn_points(cfg)
    homogeneous = cfg.scan_layers and len(set(pattern)) == 1 \
        and pattern[0] in ("dense", "moe") and not shared_pts

    def one(kind: str):
        if kind in ("dense", "moe", "shared_attn"):
            if cfg.attn_type == "mla":
                return attn.init_cache_mla(cfg, batch, max_len)
            return attn.init_cache_gqa(cfg, batch, max_len)
        if kind == "mamba":
            return ssm.mamba2_init_cache(cfg, batch)
        if kind == "mlstm":
            return ssm.mlstm_init_cache(cfg, batch)
        if kind == "slstm":
            return ssm.slstm_init_cache(cfg, batch)
        raise ValueError(kind)

    if homogeneous:
        caches = [one(pattern[0]) for _ in range(cfg.num_layers)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    caches = [one(k) for k in pattern]
    for _pt in shared_pts:          # one cache per shared-attn application
        caches.append(one("shared_attn"))
    return caches


# -- top level -----------------------------------------------------------------

def embed_inputs(params, cfg: ModelConfig, tokens, img_embeds=None):
    x = embed_lookup(params["embed"], tokens)
    if cfg.family == "vlm" and img_embeds is not None:
        img = img_embeds.astype(COMPUTE_DTYPE) \
            @ params["img_proj"].astype(COMPUTE_DTYPE)
        x = jnp.concatenate([img, x], axis=1)
    return x


def lm_forward(params, cfg: ModelConfig, tokens, q_pos=None,
               caches=None, mesh=None, dp_axes=("data",), img_embeds=None,
               last_only=False):
    B, S = tokens.shape
    x = embed_inputs(params, cfg, tokens, img_embeds)
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                 (B, x.shape[1]))
    x, new_caches, aux = forward(params, cfg, x, q_pos, caches, mesh, dp_axes)
    if last_only:          # serving prefill: only the next-token logits
        x = x[:, -1:]
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x, cfg.logit_cap, cfg.vocab)
    return logits, new_caches, aux


def train_loss(params, cfg: ModelConfig, batch, mesh=None, dp_axes=("data",),
               aux_weight: float = 0.01):
    tokens = batch["tokens"]
    labels = batch["labels"]
    img = batch.get("img_embeds")
    logits, _, aux = lm_forward(params, cfg, tokens, mesh=mesh,
                                dp_axes=dp_axes, img_embeds=img)
    if cfg.family == "vlm" and img is not None:
        logits = logits[:, img.shape[1]:]           # loss on text positions
    loss = softmax_xent(logits, labels, batch.get("loss_mask"))
    if "moe_aux" in aux:
        loss = loss + aux_weight * aux["moe_aux"]
    metrics = dict(aux, loss=loss)
    return loss, metrics
