"""Version-compatibility shims over the jax API surface we use.

The codebase is written against the modern spelling (``jax.shard_map`` with
``check_vma``, ``jax.lax.axis_size``, ``jax.make_mesh(..., axis_types=...)``);
this module maps those onto older releases (the CPU CI container ships jax
0.4.x, where shard_map lives in ``jax.experimental.shard_map`` and the
replication check is called ``check_rep``). Import from here, not from jax
directly, for any of these symbols.
"""

from __future__ import annotations

import functools
import inspect

import jax

try:  # jax >= 0.6: top-level export, check_vma kwarg
    from jax import shard_map as _shard_map_impl
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_VMA_KWARG = ("check_vma" if "check_vma"
              in inspect.signature(_shard_map_impl).parameters
              else "check_rep")


def shard_map(f=None, *, mesh, in_specs, out_specs, check_vma=True):
    """``jax.shard_map`` with the replication-check kwarg renamed as needed."""
    kwargs = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs,
              _VMA_KWARG: check_vma}
    if f is None:
        return functools.partial(_shard_map_impl, **kwargs)
    return _shard_map_impl(f, **kwargs)


def axis_size(axis_name) -> int:
    """Static size of a named mesh axis, callable inside shard_map.

    ``psum`` of a Python constant is evaluated statically (it is just
    ``size * x``), so this returns a concrete int on every jax we support.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def make_mesh(axis_shapes, axis_names, *, explicit: bool = False):
    """``jax.make_mesh`` ignoring ``axis_types`` on jaxes that predate it."""
    params = inspect.signature(jax.make_mesh).parameters
    if "axis_types" in params:
        from jax.sharding import AxisType
        kind = AxisType.Explicit if explicit else AxisType.Auto
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(kind,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)
