"""Sector slave node (paper §2.1-2.2).

A slave stores Sector slices as *whole files* in its native filesystem — never
split into blocks. All metadata the system needs is therefore recoverable by
scanning the slave's data directory (``scan()``), which is how the master
rebuilds its index after a restart.

Slaves only accept commands from the master object; clients never touch a
slave directly (the master hands the client a slave reference for an
exclusive data connection, which here is the ``read_file``/``write_file``
call surface used by :class:`repro.sector.client.SectorClient` under master
coordination).
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import shutil
from typing import Dict, Optional

from repro.sector.topology import NodeAddress


@dataclasses.dataclass
class LocalFileInfo:
    path: str          # sector path (e.g. "/sdss/SDSS1.dat")
    size: int
    md5: str


def _md5(data: bytes) -> str:
    return hashlib.md5(data).hexdigest()


class SlaveNode:
    """One storage node, backed by a real directory on the local filesystem."""

    def __init__(self, slave_id: int, address: NodeAddress, root: str, ip: str,
                 capacity_bytes: int = 1 << 40):
        self.slave_id = slave_id
        self.address = address
        self.root = root
        self.ip = ip
        self.capacity_bytes = capacity_bytes
        self.alive = True
        #: bumped on every restart — lets failure-detector audit logs tell
        #: one incarnation of a flapping node from the next.
        self.incarnation = 0
        #: number of in-flight services; the master prefers non-busy slaves.
        self.active_services = 0
        os.makedirs(root, exist_ok=True)

    # -- local path mapping ------------------------------------------------
    def _local(self, sector_path: str) -> str:
        rel = sector_path.lstrip("/")
        return os.path.join(self.root, rel)

    # -- storage primitives (master-coordinated) ---------------------------
    def write_file(self, sector_path: str, data: bytes) -> LocalFileInfo:
        if not self.alive:
            raise IOError(f"slave {self.slave_id} is down")
        if self.used_bytes() + len(data) > self.capacity_bytes:
            raise IOError(f"slave {self.slave_id} out of capacity")
        local = self._local(sector_path)
        os.makedirs(os.path.dirname(local), exist_ok=True)
        tmp = local + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, local)  # atomic publish, never a torn slice
        return LocalFileInfo(sector_path, len(data), _md5(data))

    def read_file(self, sector_path: str) -> bytes:
        if not self.alive:
            raise IOError(f"slave {self.slave_id} is down")
        with open(self._local(sector_path), "rb") as f:
            return f.read()

    def delete_file(self, sector_path: str) -> None:
        if not self.alive:
            raise IOError(f"slave {self.slave_id} is down")
        local = self._local(sector_path)
        if os.path.exists(local):
            os.remove(local)

    def has_file(self, sector_path: str) -> bool:
        return self.alive and os.path.exists(self._local(sector_path))

    # -- introspection ------------------------------------------------------
    def scan(self) -> Dict[str, LocalFileInfo]:
        """Recover all slice metadata by scanning the data directory.

        This is the paper's key argument for whole-file slices: the master can
        rebuild its entire index from slave scans alone.
        """
        if not self.alive:
            raise IOError(f"slave {self.slave_id} is down")
        out: Dict[str, LocalFileInfo] = {}
        for dirpath, _dirnames, filenames in os.walk(self.root):
            for name in filenames:
                if name.endswith(".tmp"):
                    continue
                local = os.path.join(dirpath, name)
                sector_path = "/" + os.path.relpath(local, self.root).replace(os.sep, "/")
                with open(local, "rb") as f:
                    data = f.read()
                out[sector_path] = LocalFileInfo(sector_path, len(data), _md5(data))
        return out

    def used_bytes(self) -> int:
        total = 0
        for dirpath, _dirnames, filenames in os.walk(self.root):
            for name in filenames:
                total += os.path.getsize(os.path.join(dirpath, name))
        return total

    def available_bytes(self) -> int:
        return self.capacity_bytes - self.used_bytes()

    # -- failure injection ----------------------------------------------------
    def drop_file(self, sector_path: str) -> None:
        """Silently lose one local file WITHOUT master coordination — the
        fault-injection twin of :meth:`delete_file`. Models bit-rot / a lost
        disk sector / a partially-failed move: the master's index still lists
        this slave as a replica holder, so the next coordinated read here
        fails and the data plane must recover (see
        :meth:`repro.sector.master.Master.recover_file`)."""
        local = self._local(sector_path)
        if os.path.exists(local):
            os.remove(local)

    def kill(self, wipe: bool = False) -> None:
        """Simulate node failure. ``wipe=True`` models disk loss as well."""
        self.alive = False
        if wipe:
            shutil.rmtree(self.root, ignore_errors=True)
            os.makedirs(self.root, exist_ok=True)

    def restart(self) -> None:
        self.alive = True
        self.incarnation += 1
