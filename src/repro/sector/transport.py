"""Transport model (paper §2.4).

The paper uses GMP (UDP messaging) + UDT (high-throughput wide-area transfer)
and reports Terasort/SDSS numbers bounded by disk IO or the WAN. On the TPU
target the fabric hierarchy is ICI (intra-pod, lossless, ~50 GB/s/link) and
DCN (inter-pod); disks become the checkpoint/dataset path.

``TransferSimulator`` assigns each (src, dst) pair a link class from the
topology distance and computes transfer times for the SDSS-distribution and
Terasort benchmarks. It also models the paper's key UDT property: throughput
over high-BDP paths does not collapse with distance (vs TCP, which we model
with a distance penalty) — this is what made wide-area Sector feasible.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.sector.topology import (
    DIST_CROSS_POD, DIST_SAME_NODE, DIST_SAME_POD, DIST_SAME_RACK,
    NodeAddress, distance,
)


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """Bandwidth in bytes/s and one-way latency in seconds."""
    bandwidth: float
    latency: float


#: Default link table, TPU-flavoured but with the paper's hierarchy:
#: node-local disk, intra-rack (1 GE in the paper -> ICI here), intra-pod
#: (10 GE -> ICI), cross-pod (wide area 10 GE -> DCN).
DEFAULT_LINKS: Dict[int, LinkSpec] = {
    DIST_SAME_NODE: LinkSpec(bandwidth=819e9, latency=1e-7),   # HBM-resident
    DIST_SAME_RACK: LinkSpec(bandwidth=50e9, latency=1e-6),    # ICI link
    DIST_SAME_POD: LinkSpec(bandwidth=50e9, latency=4e-6),     # ICI multi-hop
    DIST_CROSS_POD: LinkSpec(bandwidth=12.5e9, latency=500e-6),  # DCN
}

#: Paper-era link table (Open Cloud Testbed): 1 GE in-rack, 10 GE between
#: racks/sites, 4 GB/s local disk-ish memory path, ~50 MB/s single disk.
PAPER_LINKS: Dict[int, LinkSpec] = {
    DIST_SAME_NODE: LinkSpec(bandwidth=4e9, latency=1e-6),
    DIST_SAME_RACK: LinkSpec(bandwidth=125e6, latency=100e-6),   # 1 GE
    DIST_SAME_POD: LinkSpec(bandwidth=1.25e9, latency=1e-3),     # 10 GE
    DIST_CROSS_POD: LinkSpec(bandwidth=1.25e9, latency=30e-3),   # 10 GE WAN
}

PAPER_DISK_BW = 50e6  # ~single 1TB SATA disk of a Dell 1435 (paper Fig 4 note)


class TransferSimulator:
    """Computes transfer times and aggregates benchmark statistics."""

    def __init__(self, links: Optional[Dict[int, LinkSpec]] = None,
                 protocol: str = "udt", disk_bw: Optional[float] = None):
        self.links = dict(links or DEFAULT_LINKS)
        assert protocol in ("udt", "tcp")
        self.protocol = protocol
        self.disk_bw = disk_bw
        self.bytes_moved = 0.0
        self.time_busy = 0.0

    def link_for(self, src: NodeAddress, dst: NodeAddress) -> LinkSpec:
        return self.links[distance(src, dst)]

    def effective_bandwidth(self, src: NodeAddress, dst: NodeAddress) -> float:
        """UDT sustains the pipe; TCP throughput degrades with RTT (modelled
        as BW / (1 + rtt/25ms) — a coarse fit to 2008-era TCP on long fat
        pipes, cf. the UDT paper [11]). Disk bandwidth caps everything when
        configured (paper Fig 4: 'the bottleneck is the disk IO speed')."""
        link = self.link_for(src, dst)
        bw = link.bandwidth
        if self.protocol == "tcp":
            rtt = 2 * link.latency
            bw = bw / (1.0 + rtt / 25e-3)
        if self.disk_bw is not None:
            bw = min(bw, self.disk_bw)
        return bw

    def transfer_time(self, src: NodeAddress, dst: NodeAddress, nbytes: int) -> float:
        link = self.link_for(src, dst)
        # rendezvous setup: one RTT of the master-coordinated handshake
        t = 2 * link.latency + nbytes / self.effective_bandwidth(src, dst)
        self.bytes_moved += nbytes
        self.time_busy += t
        return t
