"""Sector master server (paper §2.1-2.2).

The master maintains the metadata index (file -> size/checksum/locations),
tracks slave liveness/load/space, verifies slaves against the security
server's IP allow-list, coordinates every client-slave transfer, and runs the
*periodic* replication check: if a file has fewer than ``replication_factor``
live copies, a new copy is created on a topology-spread slave. Replication is
lazy/periodic — the paper's contrast with GFS/HDFS at-write replication, and
the reason Table 1 compares Hadoop at replication factors 1 and 3.

``block_mode`` emulates a Hadoop-style block-based store (files chunked into
fixed blocks, each block replicated independently) so the benchmarks can
compare against the paper's baseline design point.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.sector.security import AccessDenied, SecurityServer
from repro.sector.slave import SlaveNode
from repro.sector.topology import NodeAddress, distance, spread_choice


@dataclasses.dataclass
class FileMeta:
    path: str
    size: int
    md5: str
    #: slave ids currently holding a (believed-live) copy
    locations: Set[int]


class Master:
    """Metadata + coordination. One per deployment (the paper supports
    multiple masters sharing a security server; we model one)."""

    def __init__(
        self,
        security: SecurityServer,
        replication_factor: int = 3,
        block_mode: bool = False,
        block_size: int = 64 << 20,
    ) -> None:
        self.security = security
        self.replication_factor = replication_factor
        self.block_mode = block_mode
        self.block_size = block_size
        self.slaves: Dict[int, SlaveNode] = {}
        self.index: Dict[str, FileMeta] = {}
        self.stats = {"replications": 0, "lost_files": 0, "transfers": 0,
                      "recoveries": 0}

    # -- slave membership ---------------------------------------------------
    def register_slave(self, slave: SlaveNode) -> None:
        """Admit a slave iff the security server allows its IP (paper §2.3)."""
        if not self.security.verify_slave(slave.ip):
            raise AccessDenied(f"slave ip {slave.ip} not on the allow-list")
        self.slaves[slave.slave_id] = slave
        # absorb anything already on its disk (scan-based metadata recovery)
        for path, info in slave.scan().items():
            meta = self.index.get(path)
            if meta is None:
                self.index[path] = FileMeta(path, info.size, info.md5, {slave.slave_id})
            else:
                meta.locations.add(slave.slave_id)

    def live_slaves(self) -> List[SlaveNode]:
        return [s for s in self.slaves.values() if s.alive]

    def mark_slave_down(self, slave_id: int) -> None:
        """Heartbeat loss (declared by a :class:`FailureDetector`): drop the
        slave from every file's location set."""
        for meta in self.index.values():
            meta.locations.discard(slave_id)

    # -- metadata recovery ----------------------------------------------------
    def recover_from_scan(self) -> None:
        """Rebuild the entire index from slave directory scans (paper §2.2:
        'Sector can recover all the metadata it requires by simply scanning
        the data directories on each slave').

        Replica conflicts (same path, different md5) are resolved by
        *majority vote across all live holders*, not by scan order: the
        winning md5 is the one with the most holders, ties broken
        deterministically by the lexicographically smallest md5. Losing
        copies are deleted from their slaves."""
        self.index.clear()
        # two passes: collect every live scan first, THEN vote per path — a
        # single streaming pass would crown whichever copy was scanned first
        infos: Dict[int, Dict[str, "LocalFileInfo"]] = {
            sid: slave.scan() for sid, slave in self.slaves.items()
            if slave.alive}
        by_path: Dict[str, Dict[str, List[int]]] = {}
        for sid, scan in infos.items():
            for path, info in scan.items():
                by_path.setdefault(path, {}).setdefault(info.md5, []).append(sid)
        for path, groups in sorted(by_path.items()):
            win = min(groups, key=lambda md5: (-len(groups[md5]), md5))
            holders = groups[win]
            info = infos[holders[0]][path]
            self.index[path] = FileMeta(path, info.size, win, set(holders))
            for md5, sids in groups.items():
                if md5 != win:
                    for sid in sids:
                        self.slaves[sid].delete_file(path)

    # -- placement policy -----------------------------------------------------
    def _placement_candidates(self, size: int, exclude: Set[int]) -> List[SlaveNode]:
        return [
            s for s in self.live_slaves()
            if s.slave_id not in exclude and s.available_bytes() >= size
        ]

    def choose_upload_slave(self, size: int, client_addr: Optional[NodeAddress] = None
                            ) -> SlaveNode:
        """Pick the initial slave for an upload: close to the client, not busy,
        with space (paper: 'choose a slave ... close to the client and not
        busy with other services')."""
        cands = self._placement_candidates(size, exclude=set())
        if not cands:
            raise IOError("no slave with sufficient space")

        def key(s: SlaveNode) -> Tuple:
            d = distance(client_addr, s.address) if client_addr else 0
            return (d, s.active_services, -s.available_bytes(), s.slave_id)

        return min(cands, key=key)

    def choose_download_slave(self, path: str, client_addr: Optional[NodeAddress] = None
                              ) -> SlaveNode:
        meta = self._meta_or_raise(path)
        cands = [self.slaves[sid] for sid in meta.locations
                 if sid in self.slaves and self.slaves[sid].alive]
        if not cands:
            raise IOError(f"no live replica of {path}")

        def key(s: SlaveNode) -> Tuple:
            d = distance(client_addr, s.address) if client_addr else 0
            return (d, s.active_services, s.slave_id)

        return min(cands, key=key)

    # -- file operations (always master-coordinated) ----------------------------
    def _meta_or_raise(self, path: str) -> FileMeta:
        meta = self.index.get(path)
        if meta is None:
            raise FileNotFoundError(path)
        return meta

    def upload(self, session_id: int, path: str, data: bytes,
               client_addr: Optional[NodeAddress] = None) -> FileMeta:
        self.security.check_access(session_id, path, "w")
        if self.block_mode and len(data) > self.block_size:
            return self._upload_blocks(path, data, client_addr)
        slave = self.choose_upload_slave(len(data), client_addr)
        slave.active_services += 1
        try:
            info = slave.write_file(path, data)
        finally:
            slave.active_services -= 1
        meta = FileMeta(path, info.size, info.md5, {slave.slave_id})
        self.index[path] = meta
        self.stats["transfers"] += 1
        return meta

    def _upload_blocks(self, path: str, data: bytes,
                       client_addr: Optional[NodeAddress]) -> FileMeta:
        """Hadoop-style block-mode: chunk + replicate-at-write. The client must
        then touch many slaves to read the file back — the contrast the paper
        draws with whole-file slices."""
        first_meta: Optional[FileMeta] = None
        nblocks = (len(data) + self.block_size - 1) // self.block_size
        for b in range(nblocks):
            chunk = data[b * self.block_size:(b + 1) * self.block_size]
            bpath = f"{path}.blk{b:05d}"
            meta = None
            # replicate at write time (HDFS behaviour)
            exclude: Set[int] = set()
            for _copy in range(self.replication_factor):
                cands = self._placement_candidates(len(chunk), exclude)
                if not cands:
                    break
                existing = [self.slaves[s].address for s in exclude]
                addr = spread_choice([c.address for c in cands], existing)
                slave = next(c for c in cands if c.address == addr)
                info = slave.write_file(bpath, chunk)
                exclude.add(slave.slave_id)
                if meta is None:
                    meta = FileMeta(bpath, info.size, info.md5, set())
                meta.locations.add(slave.slave_id)
                self.stats["transfers"] += 1
            assert meta is not None
            self.index[bpath] = meta
            if first_meta is None:
                first_meta = meta
        manifest = FileMeta(path, len(data), "", set())
        self.index[path] = manifest
        return manifest

    def download(self, session_id: int, path: str,
                 client_addr: Optional[NodeAddress] = None) -> bytes:
        self.security.check_access(session_id, path, "r")
        meta = self._meta_or_raise(path)
        if self.block_mode and not meta.locations:  # block manifest
            nblocks = (meta.size + self.block_size - 1) // self.block_size
            parts = []
            for b in range(nblocks):
                parts.append(self._download_one(f"{path}.blk{b:05d}", client_addr))
            return b"".join(parts)
        return self._download_one(path, client_addr)

    def _download_one(self, path: str, client_addr: Optional[NodeAddress]) -> bytes:
        slave = self.choose_download_slave(path, client_addr)
        slave.active_services += 1
        try:
            data = slave.read_file(path)
        finally:
            slave.active_services -= 1
        self.stats["transfers"] += 1
        return data

    def delete(self, session_id: int, path: str) -> None:
        self.security.check_access(session_id, path, "w")
        meta = self._meta_or_raise(path)
        for sid in list(meta.locations):
            slave = self.slaves.get(sid)
            if slave is not None and slave.alive:
                slave.delete_file(path)
        del self.index[path]

    def lookup(self, path: str) -> Optional[FileMeta]:
        return self.index.get(path)

    def list_dir(self, prefix: str) -> List[FileMeta]:
        return [m for p, m in sorted(self.index.items()) if p.startswith(prefix)]

    def locations_of(self, path: str) -> List[NodeAddress]:
        meta = self._meta_or_raise(path)
        return [self.slaves[s].address for s in sorted(meta.locations)
                if s in self.slaves and self.slaves[s].alive]

    # -- mid-job recovery -----------------------------------------------------
    def _live_holders(self, meta: FileMeta) -> List[int]:
        return [s for s in sorted(meta.locations)
                if s in self.slaves and self.slaves[s].alive
                and self.slaves[s].has_file(meta.path)]

    def _replicate_once(self, meta: FileMeta) -> bool:
        """Create at most one new topology-spread copy of ``meta`` from a
        live holder. Returns True iff a copy was made."""
        live = self._live_holders(meta)
        if not live:
            return False
        cands = self._placement_candidates(meta.size, exclude=set(live))
        if not cands:
            return False
        existing = [self.slaves[s].address for s in live]
        addr = spread_choice([c.address for c in cands], existing)
        dst = next(c for c in cands if c.address == addr)
        data = self.slaves[live[0]].read_file(meta.path)
        dst.write_file(meta.path, data)
        meta.locations.add(dst.slave_id)
        self.stats["replications"] += 1
        return True

    def recover_file(self, path: str) -> FileMeta:
        """Restore a file whose index locations went stale mid-job (paper
        §3.5.2 meets §2.2): prune locations that no longer actually hold the
        bytes, fall back to a directory scan of every live slave (the §2.2
        scan-based metadata recovery — a copy may survive on a slave the
        index lost track of), then re-replicate from a surviving copy back
        toward the replication factor. Raises IOError when no live copy
        exists anywhere (the data is truly lost)."""
        meta = self._meta_or_raise(path)
        good = set(self._live_holders(meta))
        if not good:
            good = {sid for sid, s in self.slaves.items()
                    if s.alive and s.has_file(path)}
        stale = meta.locations != good
        meta.locations = good
        if not good:
            self.stats["lost_files"] += 1
            raise IOError(f"no surviving replica of {path}")
        made = 0
        while (len(self._live_holders(meta)) < self.replication_factor
               and self._replicate_once(meta)):
            made += 1
        if stale or made:
            self.stats["recoveries"] += 1
        return meta


class FailureDetector:
    """Heartbeat-driven failure detection with an injectable clock.

    State machine per slave (documented in docs/ARCHITECTURE.md)::

        alive --no beat > suspect_after--> suspect
        suspect --no beat > down_after----> down      (locations pruned,
                                                       reported to the caller)
        down --beat resumes---------------> rejoined  (re-absorbed via the
                                                       §2.2 scan path, then
                                                       alive again)

    ``tick(now)`` is one detection pass: polling ``slave.alive`` stands in
    for "a heartbeat message arrived since the last tick" — every state
    decision is made from the recorded per-slave last-heartbeat timestamp
    against ``now``, never from the flag itself, so detection latency is an
    explicit, clock-injected property (virtual clocks in tests, wall time in
    production). A gap exceeding ``down_after`` outright skips the suspect
    hop. Returns the list of slave ids newly declared down this pass.

    This replaces the retired manual ``Master.heartbeat_sweep``: an
    *instant* detector (``suspect_after=down_after=0``) reproduces it
    exactly, which is what :class:`ReplicationDaemon` builds when not handed
    a shared detector.
    """

    ALIVE, SUSPECT, DOWN = "alive", "suspect", "down"

    def __init__(self, master: Master, suspect_after: float = 5.0,
                 down_after: float = 15.0, clock=time.time):
        if down_after < suspect_after:
            raise ValueError(
                f"down_after ({down_after}) must be >= suspect_after "
                f"({suspect_after})")
        self.master = master
        self.suspect_after = suspect_after
        self.down_after = down_after
        self.clock = clock
        self.last_beat: Dict[int, float] = {}
        self.state: Dict[int, str] = {}
        #: human-readable transition log (mirrors the chaos audit-log style)
        self.events: List[str] = []
        self.stats = {"suspected": 0, "downed": 0, "rejoined": 0}

    def believes_alive(self, slave_id: int) -> bool:
        """The detector's *belief* — suspect still counts as alive (lazy
        replication must not storm on a transient); only ``down`` does not.
        A slave never yet observed falls back to its actual flag."""
        st = self.state.get(slave_id)
        if st is None:
            s = self.master.slaves.get(slave_id)
            return s is not None and s.alive
        return st != self.DOWN

    def tick(self, now: Optional[float] = None) -> List[int]:
        now = self.clock() if now is None else now
        newly_down: List[int] = []
        for sid in sorted(self.master.slaves):
            slave = self.master.slaves[sid]
            st = self.state.get(sid, self.ALIVE)
            if slave.alive:
                self.last_beat[sid] = now
                if st == self.DOWN:
                    # rejoin: re-absorb surviving slices via the §2.2 scan
                    self.master.register_slave(slave)
                    self.stats["rejoined"] += 1
                    self.events.append(
                        f"t={now:g}: slave {sid} rejoined (incarnation "
                        f"{slave.incarnation}); re-absorbed by scan")
                elif st == self.SUSPECT:
                    self.events.append(
                        f"t={now:g}: slave {sid} cleared suspicion")
                self.state[sid] = self.ALIVE
                continue
            # no heartbeat this pass: judge the silence by its age alone
            age = now - self.last_beat.get(sid, -math.inf)
            if st != self.DOWN and age > self.down_after:
                self.state[sid] = self.DOWN
                self.master.mark_slave_down(sid)
                self.stats["downed"] += 1
                newly_down.append(sid)
                self.events.append(
                    f"t={now:g}: slave {sid} down "
                    f"(no heartbeat for {age:g}s)")
            elif st == self.ALIVE and age > self.suspect_after:
                self.state[sid] = self.SUSPECT
                self.stats["suspected"] += 1
                self.events.append(
                    f"t={now:g}: slave {sid} suspected "
                    f"(no heartbeat for {age:g}s)")
        return newly_down


class ReplicationDaemon:
    """Periodic replication check (paper §2.2): for every under-replicated
    file, create a new copy on a topology-spread slave. Run ``tick()`` from
    the training loop / tests; ``run_until_stable()`` iterates to fixpoint.

    ``period`` rate-limits ordinary ticks (the paper's replication is lazy
    and *periodic*, which is what keeps a flapping slave from triggering a
    re-replication storm): a tick arriving sooner than ``period`` seconds
    after the last effective one is a no-op. ``period=0`` keeps the old
    always-run behaviour; ``clock`` is injectable for tests.

    Liveness comes from a :class:`FailureDetector`, ticked at the start of
    every effective pass, and replica counting follows the detector's
    *belief*: a silent-but-not-yet-down slave's copies still count, so the
    daemon never storms ahead of detection. When no detector is passed the
    daemon builds an instant one (``suspect_after=down_after=0``), which
    reproduces the retired manual ``heartbeat_sweep`` exactly.
    """

    def __init__(self, master: Master, period: float = 0.0, clock=time.time,
                 detector: Optional[FailureDetector] = None):
        self.master = master
        self.period = period
        self.clock = clock
        if detector is None:
            detector = FailureDetector(master, suspect_after=0.0,
                                       down_after=0.0, clock=clock)
        self.detector = detector
        self._last: Optional[float] = None

    def under_replicated(self) -> List[FileMeta]:
        m = self.master
        det = self.detector
        return [
            meta for meta in m.index.values()
            if meta.locations and
            len([s for s in meta.locations
                 if det.believes_alive(s)]) < m.replication_factor
        ]

    def tick(self, max_copies: int = 1 << 30, force: bool = False) -> int:
        """One replication pass; returns the number of new copies created.

        Honors ``period`` unless ``force``: a call inside the quiet window
        does nothing (and does not reset the window)."""
        if (not force and self.period > 0 and self._last is not None
                and self.clock() - self._last < self.period):
            return 0
        self._last = self.clock()
        m = self.master
        self.detector.tick()
        created = 0
        for meta in self.under_replicated():
            if created >= max_copies:
                break
            live = [s for s in meta.locations
                    if self.detector.believes_alive(s)]
            if not live:
                m.stats["lost_files"] += 1
                continue
            if m._replicate_once(meta):
                created += 1
        return created

    def run_until_stable(self, max_rounds: int = 64) -> int:
        total = 0
        for _ in range(max_rounds):
            made = self.tick(force=True)
            total += made
            if made == 0:
                break
        return total
