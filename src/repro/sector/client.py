"""Sector client (paper §2.3-2.4).

A client logs on via the security server (through the master), then performs
uploads/downloads; every transfer is master-coordinated and served by a single
slave chosen for topology closeness and low load. Whole-file slices mean a
client touches exactly one slave per file (the paper's contrast with
block-based stores).
"""

from __future__ import annotations

import time
import zlib
from typing import Callable, List, Optional

from repro.core.retry import RetryPolicy
from repro.obs.metrics import MS_BUCKETS, REGISTRY
from repro.sector.master import FileMeta, Master
from repro.sector.topology import NodeAddress


class SectorClient:
    def __init__(self, master: Master, user: str, password: str,
                 client_ip: str = "10.0.0.1",
                 client_addr: Optional[NodeAddress] = None,
                 retry_policy: Optional[RetryPolicy] = None,
                 recover_attempts: int = 1,
                 sleep: Optional[Callable[[float], None]] = None):
        self.master = master
        self.client_addr = client_addr
        #: backoff between :meth:`recover` attempts; the default policy and
        #: ``recover_attempts=1`` keep the legacy fail-fast behaviour
        self.retry_policy = (RetryPolicy() if retry_policy is None
                             else retry_policy)
        self.recover_attempts = max(1, int(recover_attempts))
        self._sleep = time.sleep if sleep is None else sleep
        self._session = master.security.login(user, password, client_ip)

    @property
    def session_id(self) -> int:
        return self._session.session_id

    # -- file API ------------------------------------------------------------
    def upload(self, path: str, data: bytes) -> FileMeta:
        return self.master.upload(self.session_id, path, data, self.client_addr)

    def download(self, path: str) -> bytes:
        return self.master.download(self.session_id, path, self.client_addr)

    def delete(self, path: str) -> None:
        self.master.delete(self.session_id, path)

    def stat(self, path: str) -> Optional[FileMeta]:
        return self.master.lookup(path)

    def recover(self, path: str) -> FileMeta:
        """Mid-job recovery hook (paper §3.5.2): after a failed segment read,
        ask the master to prune stale replica locations, rediscover surviving
        copies by scan, and re-replicate the file back toward the replication
        factor.

        Retries up to ``recover_attempts`` times under ``retry_policy`` —
        a copy may come back mid-backoff (a rejoining slave) — recording
        each delay in the ``sector.recover.backoff_ms`` histogram. Raises
        IOError when every copy is still gone after the last attempt."""
        self.master.security.check_access(self.session_id, path, "r")
        key = zlib.crc32(path.encode())   # deterministic per-path jitter key
        for attempt in range(self.recover_attempts):
            try:
                return self.master.recover_file(path)
            except (IOError, OSError):
                if attempt + 1 >= self.recover_attempts:
                    raise
                d = self.retry_policy.delay(attempt, key=key)
                REGISTRY.histogram("sector.recover.backoff_ms",
                                   bounds=MS_BUCKETS).observe(d * 1e3)
                self._sleep(d)
        raise AssertionError("unreachable")

    def ls(self, prefix: str = "/") -> List[FileMeta]:
        return self.master.list_dir(prefix)

    def upload_dataset(self, prefix: str, slices: List[bytes]) -> List[FileMeta]:
        """Upload a dataset as numbered Sector slices (paper §2.1: 'datasets
        ... are divided into 1 or more separate files, which are called Sector
        Slices')."""
        out = []
        for i, data in enumerate(slices):
            out.append(self.upload(f"{prefix}.{i:05d}", data))
        return out

    def close(self) -> None:
        self.master.security.logout(self.session_id)
