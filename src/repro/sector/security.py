"""Independent security server (paper §2.3).

Maintains user accounts/passwords, per-user file-access ACLs, per-user client
IP allow-lists, and the slave IP allow-list that controls which machines may
join the system. The master consults it to verify clients and slaves; it
issues unique session ids on successful login.

The paper runs this as a separate process over SSL; here it is a separate
*object* with the same interface boundary (the master never reads the user
database directly).
"""

from __future__ import annotations

import dataclasses
import hashlib
import ipaddress
import itertools
from typing import Dict, List, Optional, Sequence, Tuple


class AccessDenied(Exception):
    """Raised when authentication or authorization fails."""


def _hash_password(password: str, salt: str) -> str:
    return hashlib.sha256((salt + ":" + password).encode()).hexdigest()


@dataclasses.dataclass
class UserRecord:
    name: str
    salt: str
    password_hash: str
    #: (path_prefix, mode) pairs; mode is a subset of "rw".
    acls: List[Tuple[str, str]]
    #: CIDR networks the user may connect from (empty = any).
    ip_networks: List[ipaddress.IPv4Network]


@dataclasses.dataclass
class Session:
    session_id: int
    user: str
    client_ip: str


class SecurityServer:
    """User database + slave allow-list + session issuance."""

    def __init__(self) -> None:
        self._users: Dict[str, UserRecord] = {}
        self._slave_networks: List[ipaddress.IPv4Network] = []
        self._session_counter = itertools.count(1)
        self._sessions: Dict[int, Session] = {}

    # -- administration -------------------------------------------------
    def add_user(
        self,
        name: str,
        password: str,
        acls: Sequence[Tuple[str, str]] = (("/", "rw"),),
        ip_ranges: Sequence[str] = (),
    ) -> None:
        salt = hashlib.sha256(name.encode()).hexdigest()[:8]
        self._users[name] = UserRecord(
            name=name,
            salt=salt,
            password_hash=_hash_password(password, salt),
            acls=list(acls),
            ip_networks=[ipaddress.ip_network(r) for r in ip_ranges],
        )

    def allow_slaves(self, *cidrs: str) -> None:
        """Add CIDR ranges to the slave allow-list (paper: 'only computers on
        this list can join as slaves')."""
        self._slave_networks.extend(ipaddress.ip_network(c) for c in cidrs)

    # -- slave verification ---------------------------------------------
    def verify_slave(self, ip: str) -> bool:
        if not self._slave_networks:
            return False  # closed by default: nothing may join
        addr = ipaddress.ip_address(ip)
        return any(addr in net for net in self._slave_networks)

    # -- client login ----------------------------------------------------
    def login(self, user: str, password: str, client_ip: str) -> Session:
        rec = self._users.get(user)
        if rec is None:
            raise AccessDenied(f"unknown user {user!r}")
        if _hash_password(password, rec.salt) != rec.password_hash:
            raise AccessDenied("bad password")
        if rec.ip_networks:
            addr = ipaddress.ip_address(client_ip)
            if not any(addr in net for net in rec.ip_networks):
                raise AccessDenied(f"client ip {client_ip} not allowed for {user}")
        session = Session(next(self._session_counter), user, client_ip)
        self._sessions[session.session_id] = session
        return session

    def logout(self, session_id: int) -> None:
        self._sessions.pop(session_id, None)

    def session(self, session_id: int) -> Optional[Session]:
        return self._sessions.get(session_id)

    # -- authorization ----------------------------------------------------
    def check_access(self, session_id: int, path: str, mode: str) -> None:
        """Raise AccessDenied unless the session's user may access ``path``
        with ``mode`` ('r' or 'w'). Longest matching ACL prefix wins."""
        session = self._sessions.get(session_id)
        if session is None:
            raise AccessDenied("invalid session")
        rec = self._users[session.user]
        best: Optional[Tuple[str, str]] = None
        for prefix, acl_mode in rec.acls:
            if path.startswith(prefix):
                if best is None or len(prefix) > len(best[0]):
                    best = (prefix, acl_mode)
        if best is None or mode not in best[1]:
            raise AccessDenied(f"{session.user} lacks {mode!r} on {path!r}")
