"""Sector: the storage cloud (paper §2).

A file-based distributed storage system: a metadata master, slave nodes that
store whole-file *slices* on their native filesystem, an independent security
server, and a periodic topology-aware replication daemon.

This is an in-process, filesystem-backed implementation: every slave owns a
real directory; the master's metadata index is recoverable by scanning those
directories (the paper's central design argument for whole-file slices).
It backs the training framework's dataset pipeline and checkpoint store.
"""

from repro.sector.topology import NodeAddress, Topology, distance
from repro.sector.security import SecurityServer, AccessDenied
from repro.sector.slave import SlaveNode
from repro.sector.master import (FailureDetector, FileMeta, Master,
                                 ReplicationDaemon)
from repro.sector.client import SectorClient
from repro.sector.transport import LinkSpec, TransferSimulator

__all__ = [
    "NodeAddress", "Topology", "distance",
    "SecurityServer", "AccessDenied",
    "SlaveNode", "Master", "FileMeta", "FailureDetector",
    "ReplicationDaemon", "SectorClient", "LinkSpec", "TransferSimulator",
]
