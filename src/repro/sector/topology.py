"""Hierarchical system topology (paper §2.2).

The paper assumes a manually-specified hierarchical topology: nodes on racks,
racks in data centers, data centers connected by wide-area links. The master
uses it to pick replica locations and to serve clients from nearby slaves.

On the TPU-pod target the hierarchy is host → ICI pod → DCN-connected pods;
we keep the paper's (pod, rack, node) naming with pod = data center.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, List, Sequence


@dataclasses.dataclass(frozen=True, order=True)
class NodeAddress:
    """Position of a node in the hierarchy (data center / rack / node)."""

    pod: int
    rack: int
    node: int

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        return f"pod{self.pod}/rack{self.rack}/node{self.node}"


#: Topology distance classes, small = close (paper: pick close, non-busy slave).
DIST_SAME_NODE = 0
DIST_SAME_RACK = 1
DIST_SAME_POD = 2
DIST_CROSS_POD = 3


def distance(a: NodeAddress, b: NodeAddress) -> int:
    """Hierarchical distance between two nodes."""
    if a.pod != b.pod:
        return DIST_CROSS_POD
    if a.rack != b.rack:
        return DIST_SAME_POD
    if a.node != b.node:
        return DIST_SAME_RACK
    return DIST_SAME_NODE


@dataclasses.dataclass
class Topology:
    """A full cluster topology: ``pods`` data centers, each with ``racks``
    racks of ``nodes_per_rack`` nodes.

    The paper's testbed is 4 racks in 4 locations, 30 compute nodes each; the
    production TPU analogue is 2 pods x 16 "racks" (mesh rows) x 16 nodes.
    """

    pods: int = 1
    racks: int = 4
    nodes_per_rack: int = 30

    def all_addresses(self) -> List[NodeAddress]:
        return [
            NodeAddress(p, r, n)
            for p, r, n in itertools.product(
                range(self.pods), range(self.racks), range(self.nodes_per_rack)
            )
        ]

    @property
    def num_nodes(self) -> int:
        return self.pods * self.racks * self.nodes_per_rack

    def flat_index(self, addr: NodeAddress) -> int:
        return (addr.pod * self.racks + addr.rack) * self.nodes_per_rack + addr.node

    def address_of(self, flat: int) -> NodeAddress:
        node = flat % self.nodes_per_rack
        rack = (flat // self.nodes_per_rack) % self.racks
        pod = flat // (self.nodes_per_rack * self.racks)
        return NodeAddress(pod, rack, node)


def spread_choice(
    candidates: Sequence[NodeAddress],
    existing: Iterable[NodeAddress],
) -> NodeAddress:
    """Choose the candidate that maximizes topology spread from ``existing``.

    Paper §2.2: "The new location of the file copy is based on the topology of
    the slaves' network" — replicas should survive rack/pod failures, so we
    pick the candidate whose *minimum* distance to any existing replica is
    largest (ties broken deterministically by address for reproducibility).
    """
    existing = list(existing)
    if not candidates:
        raise ValueError("no candidate slaves for replica placement")
    if not existing:
        return min(candidates)

    def score(c: NodeAddress) -> tuple:
        dmin = min(distance(c, e) for e in existing)
        return (-dmin, c)

    return min(candidates, key=score)


def group_by_pod(addresses: Iterable[NodeAddress]) -> Dict[int, List[NodeAddress]]:
    out: Dict[int, List[NodeAddress]] = {}
    for a in addresses:
        out.setdefault(a.pod, []).append(a)
    return out
