"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B] — dense, MLA attention.

62L d_model=2560 40H d_ff=6400 vocab=73448; MLA dims from the HF config
(q_lora_rank=768, kv_lora_rank=256, qk_nope=64, qk_rope=32, v_head=64);
depth-scaled residuals (scale_depth=1.4 -> 1.4/sqrt(62) per residual).
"""

import math

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="minicpm3_4b", family="dense",
    num_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab=73_448,
    attn_type="mla",
    q_lora_rank=768, kv_lora_rank=256,
    qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
    residual_scale=1.4 / math.sqrt(62),
    rope_theta=10_000.0,
)

SMOKE_CONFIG = ModelConfig(
    arch_id="minicpm3_4b", family="dense",
    num_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256,
    attn_type="mla",
    q_lora_rank=32, kv_lora_rank=16,
    qk_nope_dim=8, qk_rope_dim=8, v_head_dim=8,
    residual_scale=1.4 / math.sqrt(3),
)
