"""Architecture configs: one module per assigned architecture.

``get_config(arch_id)`` returns the full published config;
``get_smoke_config(arch_id)`` returns a reduced same-family config for CPU
smoke tests (small layers/width/experts/vocab).
"""

from repro.configs.base import ModelConfig, ShapeSpec, SHAPES, get_config, \
    get_smoke_config, ARCH_IDS

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "get_config",
           "get_smoke_config", "ARCH_IDS"]
