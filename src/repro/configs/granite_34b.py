"""Granite-34B-Code [arXiv:2405.04324] — GPT-BigCode arch with MQA.

88L d_model=6144 48H (kv=1) d_ff=24576 vocab=49152, non-gated GELU MLP.
Deviation (DESIGN.md §4): learned absolute positions (ctx 8k) replaced with
RoPE so the 32k shapes are well-defined.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite_34b", family="dense",
    num_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24_576, vocab=49_152,
    attn_type="gqa", mlp_gated=False,
    rope_theta=10_000.0,
)

SMOKE_CONFIG = ModelConfig(
    arch_id="granite_34b", family="dense",
    num_layers=3, d_model=64, n_heads=8, n_kv_heads=1,
    d_ff=256, vocab=256,
    attn_type="gqa", mlp_gated=False,
)
