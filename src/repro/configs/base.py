"""Config schema + registry for the assigned architectures and input shapes."""

from __future__ import annotations

import dataclasses
import importlib
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


#: The assigned input-shape set (identical for every LM-family arch).
SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                       # dense | moe | audio | ssm | vlm | hybrid
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # attention
    attn_type: str = "gqa"            # gqa | mla | swa
    head_dim: Optional[int] = None    # default d_model // n_heads
    window: Optional[int] = None      # sliding-window size (attn_type=swa)
    qk_norm: bool = False             # qwen3-style per-head q/k RMSNorm
    rope_theta: float = 10_000.0

    # MLA (minicpm3 / deepseek-v2 style)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    num_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    moe_impl: str = "sphere"          # sphere (paper bucket shuffle) | dense
    capacity_factor: float = 1.25

    # SSM / xLSTM / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    conv_kernel: int = 4
    chunk_size: int = 256             # SSD / mLSTM chunk length
    slstm_every: int = 0              # xlstm: every k-th block is sLSTM
    attn_every: int = 0               # zamba2: shared attn before every k-th block

    # enc-dec (whisper)
    enc_layers: int = 0
    enc_seq: int = 0                  # encoder frames (stub embeddings)

    # VLM (internvl)
    img_tokens: int = 0               # patch embeddings prepended to text

    # MLP
    mlp_gated: bool = True            # SwiGLU vs plain GELU
    residual_scale: float = 1.0       # minicpm depth-scaled residuals

    # numerics / execution
    tp_size: int = 16                 # production model-axis size; gates
    #                                   head-granular weight sharding
    norm_eps: float = 1e-5
    remat: bool = True
    scan_layers: bool = True
    logit_cap: float = 0.0

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def runnable_shapes(self) -> List[str]:
        """Which assigned shapes this arch runs (long_500k only for archs with
        sub-quadratic / bounded-state attention — see DESIGN.md)."""
        out = ["train_4k", "prefill_32k", "decode_32k"]
        subquad = (self.family in ("ssm", "hybrid")
                   or self.attn_type == "swa")
        if subquad:
            out.append("long_500k")
        return out


ARCH_IDS: Tuple[str, ...] = (
    "minicpm3_4b", "h2o_danube_1_8b", "granite_34b", "tinyllama_1_1b",
    "qwen3_moe_30b_a3b", "qwen2_moe_a2_7b", "whisper_small", "xlstm_125m",
    "internvl2_1b", "zamba2_1_2b",
)


def get_config(arch_id: str) -> ModelConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.SMOKE_CONFIG
