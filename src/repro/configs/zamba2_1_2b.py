"""Zamba2-1.2B [arXiv:2411.15242] — Mamba2 backbone + shared attention block.

38 Mamba2 blocks, d_model=2048, ssm_state=64; one *shared* transformer block
(32H attention + d_ff=8192 MLP, weights reused) applied before every 6th
Mamba block. vocab=32000.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2_1_2b", family="hybrid",
    num_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32_000,
    attn_type="gqa",
    ssm_state=64, ssm_expand=2, conv_kernel=4, chunk_size=256,
    attn_every=6,
    scan_layers=False,
)

SMOKE_CONFIG = ModelConfig(
    arch_id="zamba2_1_2b", family="hybrid",
    num_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256,
    attn_type="gqa",
    ssm_state=16, ssm_expand=2, conv_kernel=4, chunk_size=8,
    attn_every=2,
    scan_layers=False,
)
