"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B] — 60 routed experts top-4
plus 4 shared experts. 24L d_model=2048 16H (MHA kv=16) expert d_ff=1408
vocab=151936. Routed dispatch = Sphere bucket shuffle; shared experts run
dense on every token (4 x 1408 = the HF config's fused 5632 shared FFN).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2_moe_a2_7b", family="moe",
    num_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=0, vocab=151_936,
    attn_type="gqa",
    num_experts=60, top_k=4, expert_d_ff=1408,
    n_shared_experts=4, shared_d_ff=1408,
    rope_theta=1_000_000.0,
)

SMOKE_CONFIG = ModelConfig(
    arch_id="qwen2_moe_a2_7b", family="moe",
    num_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=256,
    attn_type="gqa",
    num_experts=6, top_k=2, expert_d_ff=32,
    n_shared_experts=2, shared_d_ff=32,
)
