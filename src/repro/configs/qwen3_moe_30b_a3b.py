"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — MoE, 128 experts top-8.

48L d_model=2048 32H (GQA kv=4, head_dim=128, q/k-norm) expert d_ff=768
vocab=151936. Expert dispatch uses the Sphere bucket shuffle (DESIGN.md §4).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3_moe_30b_a3b", family="moe",
    num_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=0, vocab=151_936,
    attn_type="gqa", head_dim=128, qk_norm=True,
    num_experts=128, top_k=8, expert_d_ff=768,
    rope_theta=1_000_000.0,
)

SMOKE_CONFIG = ModelConfig(
    arch_id="qwen3_moe_30b_a3b", family="moe",
    num_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=0, vocab=256,
    attn_type="gqa", head_dim=16, qk_norm=True,
    num_experts=8, top_k=2, expert_d_ff=32,
)
