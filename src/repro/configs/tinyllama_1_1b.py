"""TinyLlama-1.1B [arXiv:2401.02385] — llama2-arch small.

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="tinyllama_1_1b", family="dense",
    num_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=5632, vocab=32_000,
    attn_type="gqa",
    rope_theta=10_000.0,
)

SMOKE_CONFIG = ModelConfig(
    arch_id="tinyllama_1_1b", family="dense",
    num_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=160, vocab=256,
    attn_type="gqa",
)
