"""InternVL2-1B [arXiv:2404.16821] — InternViT frontend (STUB) + Qwen2-0.5B LM.

LM backbone: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
The ViT is a stub: ``input_specs()`` provides precomputed patch embeddings
(batch, img_tokens, d_model) prepended to the text sequence; loss is computed
on text positions only.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2_1b", family="vlm",
    num_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab=151_655,
    attn_type="gqa",
    img_tokens=256,
    rope_theta=1_000_000.0,
)

SMOKE_CONFIG = ModelConfig(
    arch_id="internvl2_1b", family="vlm",
    num_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256,
    attn_type="gqa",
    img_tokens=8,
)
