"""Whisper-small [arXiv:2212.04356] — encoder-decoder, audio.

12L enc + 12L dec, d_model=768 12H (MHA) d_ff=3072 vocab=51865, non-gated
GELU. The conv frontend is a STUB: ``input_specs()`` provides precomputed
frame embeddings (batch, enc_seq, d_model). Encoder frames fixed at the
native 1500 (30 s); the assigned seq_len applies to the decoder side.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper_small", family="audio",
    num_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51_865,
    attn_type="gqa", mlp_gated=False,
    enc_layers=12, enc_seq=1500,
)

SMOKE_CONFIG = ModelConfig(
    arch_id="whisper_small", family="audio",
    num_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256,
    attn_type="gqa", mlp_gated=False,
    enc_layers=2, enc_seq=32,
)
