"""xLSTM-125M [arXiv:2405.04517] — sLSTM + mLSTM blocks.

12L d_model=768, 4 heads, vocab=50304, d_ff=0 (the xLSTM block carries its
own up/down projection, expansion 2). Block ratio ~ mLSTM[7:1]sLSTM: every
6th block is sLSTM (2 of 12), the rest mLSTM. mLSTM runs in chunked-parallel
form for train/prefill and recurrent form for decode; sLSTM is sequential
(lax.scan over time) by construction.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="xlstm_125m", family="ssm",
    num_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50_304,
    ssm_expand=2, ssm_heads=4, chunk_size=256,
    slstm_every=6,
    scan_layers=False,
)

SMOKE_CONFIG = ModelConfig(
    arch_id="xlstm_125m", family="ssm",
    num_layers=3, d_model=64, n_heads=2, n_kv_heads=2,
    d_ff=0, vocab=256,
    ssm_expand=2, ssm_heads=2, chunk_size=8,
    slstm_every=3,
    scan_layers=False,
)
