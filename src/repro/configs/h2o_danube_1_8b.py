"""H2O-Danube-1.8B [arXiv:2401.16818] — llama+mistral mix with sliding-window
attention. 24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, window 4096.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="h2o_danube_1_8b", family="dense",
    num_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab=32_000,
    attn_type="swa", window=4096,
    rope_theta=10_000.0,
)

SMOKE_CONFIG = ModelConfig(
    arch_id="h2o_danube_1_8b", family="dense",
    num_layers=3, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=128, vocab=256,
    attn_type="swa", window=16,
)
