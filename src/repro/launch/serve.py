"""Serving driver: bring up a model and run batched requests through the
slot-based continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama_1_1b --smoke \\
      --requests 8 --new-tokens 12
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config, ARCH_IDS
from repro.models import build
from repro.serve import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=args.slots,
                         max_len=args.max_len,
                         temperature=args.temperature)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(4, 12))
        frames = (rng.standard_normal((cfg.enc_seq, cfg.d_model))
                  .astype(np.float32) if cfg.family == "audio" else None)
        engine.submit(Request(i, prompt.astype(np.int32),
                              max_new_tokens=args.new_tokens,
                              frames=frames))
    done = engine.run_to_completion()
    dt = time.time() - t0
    total_new = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {total_new} tokens "
          f"in {dt:.2f}s ({total_new / dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.req_id}: prompt[:4]={r.prompt[:4].tolist()} "
              f"-> out[:8]={r.out_tokens[:8]}")


if __name__ == "__main__":
    main()
