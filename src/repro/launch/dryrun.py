import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract the roofline terms.

This file MUST set XLA_FLAGS before any jax import (device count locks on
first init) — hence the module-level lines above the docstring.

Usage:
  python -m repro.launch.dryrun --arch tinyllama_1_1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out benchmarks/results/dryrun

Outputs one JSON per cell with: memory analysis (XLA + analytic bytes/device),
cost analysis (FLOPs, bytes), per-collective byte counts parsed from the
post-SPMD HLO, and the three roofline terms (compute/memory/collective
seconds) with the dominant bottleneck identified.
"""

import argparse
import json
import re
import sys
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, ARCH_IDS
from repro.launch.mesh import dp_axes_of, make_production_mesh
from repro.models.registry import Model, build
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.trainer import build_train_step, make_state_shardings

# TPU v5e-flavoured hardware model (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link

_COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                   "all-to-all", "collective-permute")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64)"
                       r"\[([0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1}


def _first_shape_bytes(segment: str) -> int:
    m = _SHAPE_RE.search(segment)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo: str) -> Dict[str, int]:
    """Sum result bytes of every collective op in the (post-SPMD) HLO.

    For `-start` async forms the result is a tuple (operands..., outputs...);
    we count the *last* shaped component (the output buffer). all-reduce is
    counted 2x (ring = reduce-scatter + all-gather bytes on the wire).
    """
    out = {k: 0 for k in _COLLECTIVE_OPS}
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(.+?)\s+(" + "|".join(_COLLECTIVE_OPS) +
                      r")(-start)?\(", stripped)
        if not m:
            continue
        result_type, op, _async = m.group(1), m.group(2), m.group(3)
        shapes = _SHAPE_RE.findall(result_type)
        if not shapes:
            continue
        dt, dims = shapes[-1]
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes = n * _DTYPE_BYTES[dt]
        if op == "all-reduce":
            nbytes *= 2
        out[op] += nbytes
    return out


def abstract_params(model: Model, key) -> Tuple[Any, Any]:
    """ShapeDtypeStructs + PartitionSpecs for the params, with NO allocation.
    Specs are static python built alongside init; captured via side channel
    during the abstract trace."""
    box = {}

    def init_only(k):
        p, s = model.init(k)
        box["specs"] = s
        return p

    sds = jax.eval_shape(init_only, key)
    return sds, box["specs"]


def analytic_param_bytes(sds, specs, mesh: Mesh) -> int:
    """Per-device parameter bytes implied by the shardings."""
    total = 0
    for leaf, spec in zip(jax.tree.leaves(sds),
                          jax.tree.leaves(specs,
                                          is_leaf=lambda x: isinstance(x, P))):
        shard = 1
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                shard *= mesh.shape.get(a, 1)
        total += leaf.size * leaf.dtype.itemsize // max(shard, 1)
    return total


def moe_active_fraction(model: Model, sds) -> float:
    """N_active / N for MODEL_FLOPS (6*N_active*D)."""
    cfg = model.cfg
    total = sum(l.size for l in jax.tree.leaves(sds))
    if not cfg.is_moe:
        return 1.0
    flat = jax.tree_util.tree_flatten_with_path(sds)[0]
    expert_sz = sum(l.size for path, l in flat
                    if any(getattr(p, "key", None) in
                           ("w_gate", "w_up", "w_down") for p in path)
                    and any(getattr(p, "key", None) == "moe" for p in path))
    from repro.models.moe import padded_experts
    e_pad = padded_experts(cfg)
    active = total - expert_sz + expert_sz * cfg.top_k / e_pad
    return active / total


def model_flops(model: Model, sds, shape_name: str) -> float:
    sp = SHAPES[shape_name]
    n = sum(l.size for l in jax.tree.leaves(sds))
    n_active = n * moe_active_fraction(model, sds)
    if sp.kind == "train":
        tokens = sp.global_batch * sp.seq_len
        return 6.0 * n_active * tokens
    if sp.kind == "prefill":
        tokens = sp.global_batch * sp.seq_len
        return 2.0 * n_active * tokens
    tokens = sp.global_batch * 1
    return 2.0 * n_active * tokens


def analytic_hbm_bytes(cfg, shape_name: str, mesh: Mesh, n_params: int,
                       zero1: bool = True) -> float:
    """Per-device-per-step HBM traffic model for the TPU target.

    XLA:CPU's "bytes accessed" assumes no fusion (every op round-trips HBM),
    which overstates TPU traffic by >10x; this analytic model is the memory
    roofline term instead (the raw XLA number is still reported). Components
    (see EXPERIMENTS.md §Roofline):

    train:  params fp32 read fwd+bwd (8B/param) + grad write+read (8B)
            + AdamW m/v read+write + p read+write (24B, /data-size with
            ZeRO-1) + activations (remat: ~6 streams of L*T_loc*d bf16)
            + fp32 logits write+read fwd/bwd (16B per token-vocab-shard).
    prefill: params 4B + 2-stream activations + KV-cache write.
    decode:  params 4B (weights-bound) + full KV/state cache read + write.
    """
    sp = SHAPES[shape_name]
    tp = mesh.shape.get("model", 1)
    dsize = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    n_dev = n_params / tp
    b_loc = max(sp.global_batch // dsize, 1)

    if sp.kind == "train":
        t_loc = b_loc * sp.seq_len
        params_traffic = 16 * n_dev + 24 * n_dev / (dsize if zero1 else 1)
        acts = 6.0 * cfg.num_layers * t_loc * cfg.d_model * 2
        logits = 16.0 * t_loc * cfg.vocab / tp
        return params_traffic + acts + logits
    if sp.kind == "prefill":
        t_loc = b_loc * sp.seq_len
        acts = 2.0 * cfg.num_layers * t_loc * cfg.d_model * 2
        cache_w = _cache_bytes_per_device(cfg, sp, mesh)
        return 4 * n_dev + acts + cache_w
    # decode: one token; weights + cache round-trip
    cache = _cache_bytes_per_device(cfg, sp, mesh)
    return 4 * n_dev + cache


def _cache_bytes_per_device(cfg, sp, mesh: Mesh) -> float:
    """Approximate per-device KV/state cache bytes for a full context."""
    tp = mesh.shape.get("model", 1)
    dsize = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    b_loc = max(sp.global_batch // dsize, 1)
    t = sp.seq_len
    if cfg.family == "ssm":                    # xlstm matrix states
        d_in = cfg.ssm_expand * cfg.d_model
        h = max(cfg.ssm_heads or cfg.n_heads, 1)
        per_layer = b_loc * (d_in // h) ** 2 * h * 4
        return cfg.num_layers * per_layer / tp
    if cfg.family == "hybrid":                 # zamba2: ssm + shared attn kv
        d_in = cfg.ssm_expand * cfg.d_model
        h = max(d_in // 64, 1)
        ssm = cfg.num_layers * b_loc * h * 64 * cfg.ssm_state * 4
        n_shared = len([i for i in range(cfg.num_layers)
                        if cfg.attn_every and (i + 1) % cfg.attn_every == 0])
        attn = n_shared * b_loc * t * cfg.n_kv_heads * cfg.hd * 2 * 2
        return (ssm + attn) / tp
    if cfg.attn_type == "mla":                 # latent cache, tp-replicated
        per_layer = b_loc * t * (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
        return cfg.num_layers * per_layer
    t_eff = min(t, cfg.window) if cfg.attn_type == "swa" else t
    kv_shard = tp if cfg.n_kv_heads % tp == 0 else 1
    per_layer = b_loc * t_eff * cfg.n_kv_heads * cfg.hd * 2 * 2 / kv_shard
    layers = cfg.num_layers + (cfg.enc_layers or 0)
    return layers * per_layer


def _lower_and_compile(cfg, shape_name: str, mesh: Mesh, dp,
                       zero1: bool, accum_steps: int = 1,
                       bf16_params: bool = False):
    """Lower+compile one program for ``cfg``; returns (compiled, extras).

    ``bf16_params``: store/compute params in bf16 with an fp32 master copy
    in the (ZeRO-1 sharded) optimizer state — halves weight memory/traffic.
    """
    sp = SHAPES[shape_name]
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    p_sds, p_specs = abstract_params(model, key)
    if bf16_params:
        p_sds = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16), p_sds)
    in_sds = model.input_specs(shape_name)
    b_specs = model.batch_specs(shape_name, dp=dp)
    b_shard = {k: NamedSharding(mesh, s) for k, s in b_specs.items()}

    if sp.kind == "train":
        opt_cfg = AdamWConfig()
        p_shard, opt_shard = make_state_shardings(model, mesh, p_specs,
                                                  zero1=zero1,
                                                  master=bf16_params)
        opt_sds = jax.eval_shape(
            lambda p: init_opt_state(p, master=bf16_params), p_sds)
        step = build_train_step(model, opt_cfg, mesh, dp, accum_steps)
        jitted = jax.jit(step,
                         in_shardings=(p_shard, opt_shard, b_shard),
                         out_shardings=(p_shard, opt_shard, None),
                         donate_argnums=(0, 1))
        with mesh:
            lowered = jitted.lower(p_sds, opt_sds, in_sds)
        opt_specs = jax.tree.map(lambda s: s.spec, opt_shard["m"],
                                 is_leaf=lambda x: hasattr(x, "spec"))
        state_bytes = (analytic_param_bytes(p_sds, p_specs, mesh)
                       + 2 * analytic_param_bytes(opt_sds["m"], opt_specs,
                                                  mesh))
    else:
        p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                               is_leaf=lambda x: isinstance(x, P))
        if sp.kind == "prefill":
            fn = lambda p, b: model.prefill(p, b, None, mesh=mesh,
                                            dp_axes=dp)
            jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
            with mesh:
                lowered = jitted.lower(p_sds, in_sds)
        else:  # decode
            c_specs = model.cache_specs(shape_name, dp=dp)
            c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs,
                                   is_leaf=lambda x: isinstance(x, P))
            c_sds = jax.eval_shape(
                lambda: model.init_caches(sp.global_batch, sp.seq_len))
            fn = lambda p, c, b: model.decode_step(p, c, b, mesh=mesh,
                                                   dp_axes=dp)
            jitted = jax.jit(fn,
                             in_shardings=(p_shard, c_shard, b_shard),
                             out_shardings=(None, c_shard),
                             donate_argnums=(1,))
            with mesh:
                lowered = jitted.lower(p_sds, c_sds, in_sds)
        state_bytes = analytic_param_bytes(p_sds, p_specs, mesh)
    compiled = lowered.compile()
    return compiled, {"state_bytes": state_bytes, "p_sds": p_sds,
                      "model": model}


def _costs_of(compiled) -> Tuple[float, float, Dict[str, int]]:
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def _small_cfg(cfg, n_layers: int):
    import dataclasses as dc
    kw = {"num_layers": n_layers, "scan_layers": False}
    if cfg.enc_layers:
        kw["enc_layers"] = n_layers
    if cfg.attn_every:
        kw["attn_every"] = 0
    if cfg.slstm_every:
        kw["slstm_every"] = 0
    return dc.replace(cfg, **kw)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               zero1: bool = True, accum_steps: int = 1,
               cfg_override=None, bf16_params: bool = False) -> Dict[str, Any]:
    cfg = cfg_override or get_config(arch)
    if shape_name not in cfg.runnable_shapes():
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "skipped": "full attention cannot run long-context decode "
                           "(DESIGN.md §4)"}
    sp = SHAPES[shape_name]
    model = build(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = dp_axes_of(mesh)
    key = jax.random.PRNGKey(0)

    t0 = time.time()
    compiled, extras = _lower_and_compile(cfg, shape_name, mesh, dp, zero1,
                                          accum_steps, bf16_params)
    t_compile = time.time() - t0
    model = extras["model"]
    p_sds = extras["p_sds"]
    state_bytes = extras["state_bytes"]

    try:
        mem = compiled.memory_analysis()
        mem_d = {k: int(getattr(mem, k)) for k in
                 ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes")
                 if hasattr(mem, k)} if mem is not None else {}
    except Exception:
        mem_d = {}

    flops_dev, bytes_dev, coll = _costs_of(compiled)

    # ---- scan-trip-count correction -------------------------------------
    # XLA cost_analysis counts a lax.scan body ONCE regardless of trip
    # count, so scanned stacks under-report flops/bytes/collectives. We
    # recover the true affine cost c(L) = a + b*L by compiling unrolled
    # L=1 and L=2 variants of the same config: b = c2-c1, a = 2*c1-c2.
    uses_scan = cfg.scan_layers and (
        cfg.family in ("dense", "moe", "audio", "vlm"))
    corrected = None
    if uses_scan:
        c1, _ = _lower_and_compile(_small_cfg(cfg, 1), shape_name, mesh, dp,
                                   zero1, accum_steps, bf16_params)
        c2, _ = _lower_and_compile(_small_cfg(cfg, 2), shape_name, mesh, dp,
                                   zero1, accum_steps, bf16_params)
        f1, by1, co1 = _costs_of(c1)
        f2, by2, co2 = _costs_of(c2)
        L = cfg.num_layers
        lin = lambda v1, v2: max((2 * v1 - v2) + (v2 - v1) * L, 0.0)
        corrected = {
            "flops": lin(f1, f2),
            "bytes": lin(by1, by2),
            "collectives": {k: lin(co1[k], co2[k]) for k in coll},
        }
        flops_dev = corrected["flops"]
        bytes_dev = corrected["bytes"]
        coll = {k: int(v) for k, v in corrected["collectives"].items()}

    chips = int(np.prod(list(mesh.shape.values())))
    coll_dev = float(sum(coll.values()))
    mf = model_flops(model, p_sds, shape_name)

    compute_s = flops_dev / PEAK_FLOPS
    n_params = sum(l.size for l in jax.tree.leaves(p_sds))
    hbm_bytes = analytic_hbm_bytes(cfg, shape_name, mesh, n_params,
                                   zero1=zero1)
    memory_s = hbm_bytes / HBM_BW
    collective_s = coll_dev / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "mesh_shape": dict(mesh.shape), "chips": chips,
        "compile_s": round(t_compile, 2),
        "scan_corrected": bool(uses_scan),
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "analytic_hbm_bytes_per_device": hbm_bytes,
        "collective_bytes_per_device": coll,
        "collective_total_per_device": coll_dev,
        "state_bytes_per_device": int(state_bytes),
        "xla_memory_analysis": mem_d,
        "model_flops_global": mf,
        "model_flops_per_device": mf / chips,
        "useful_flops_ratio": (mf / chips) / flops_dev if flops_dev else None,
        "roofline": dict(terms, dominant=dominant,
                         step_time_s=max(terms.values()),
                         mfu_bound=(mf / chips / PEAK_FLOPS)
                         / max(max(terms.values()), 1e-12)),
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--bf16-params", action="store_true",
                    help="bf16 params + fp32 master in optimizer")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                for mp in meshes:
                    cells.append((arch, shape, mp))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape, mp) for mp in meshes]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[skip-cached] {tag}", flush=True)
            continue
        print(f"[lower] {tag} ...", flush=True)
        try:
            res = lower_cell(arch, shape, mp, zero1=not args.no_zero1,
                             bf16_params=args.bf16_params)
        except Exception as e:  # pragma: no cover
            failures += 1
            res = {"arch": arch, "shape": shape,
                   "mesh": "multi" if mp else "single",
                   "error": f"{type(e).__name__}: {e}"}
            print(f"[FAIL] {tag}: {res['error']}", flush=True)
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        if "roofline" in res:
            r = res["roofline"]
            print(f"[ok] {tag} compile={res['compile_s']}s "
                  f"dominant={r['dominant']} step={r['step_time_s']:.4f}s",
                  flush=True)
        elif "skipped" in res:
            print(f"[skipped] {tag}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
