"""Launchers: production mesh construction, the multi-pod dry-run, and the
end-to-end train/serve drivers."""
