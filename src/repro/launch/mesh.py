"""Production mesh construction.

Axes: ``pod`` (DCN-connected pod/data-center — Sector's wide-area dimension),
``data`` (batch parallel), ``model`` (tensor/expert parallel). Functions, not
module constants, so importing never touches jax device state.
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the locally available (possibly virtual) devices."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"need {data * model} devices, have {n}")
    return make_mesh((data, model), ("data", "model"))


def dp_axes_of(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
