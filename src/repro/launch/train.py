"""End-to-end training driver.

Brings up the full stack: a Sector deployment (security server, master,
slaves, replication daemon), a synthetic corpus stored as Sector slices, the
Sphere-scheduled data pipeline, the sharded train step, and Sector-backed
checkpointing with async save + fault-injection restart.

Example (CPU, ~100M model, a few hundred steps):
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama_1_1b \\
      --smoke --steps 200 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config, ARCH_IDS
from repro.data import SectorDataPipeline, synthetic_tokens, \
    upload_token_dataset
from repro.launch.mesh import dp_axes_of, make_host_mesh
from repro.models import build
from repro.sector import (Master, NodeAddress, ReplicationDaemon,
                          SectorClient, SecurityServer, SlaveNode, Topology)
from repro.train.checkpoint import SectorCheckpointer
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import init_train_state, jit_train_step


def make_sector(root: str, num_slaves: int = 4, replication: int = 2):
    sec = SecurityServer()
    sec.add_user("trainer", "pw")
    sec.allow_slaves("10.0.0.0/8")
    master = Master(sec, replication_factor=replication)
    topo = Topology(pods=1, racks=2, nodes_per_rack=(num_slaves + 1) // 2)
    for i in range(num_slaves):
        addr = topo.address_of(i)
        master.register_slave(SlaveNode(
            i, addr, os.path.join(root, f"slave{i}"), ip=f"10.0.0.{i + 1}"))
    client = SectorClient(master, "trainer", "pw",
                          client_addr=NodeAddress(0, 0, 0))
    return master, client, ReplicationDaemon(master)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", type=int, default=1, help="data mesh axis")
    ap.add_argument("--model", type=int, default=1, help="model mesh axis")
    ap.add_argument("--workdir", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build(cfg)
    root = args.workdir or tempfile.mkdtemp(prefix="sector_")
    master, client, daemon = make_sector(root)

    # corpus -> Sector slices
    toks = synthetic_tokens(args.batch * (args.seq + 1) * (args.steps + 8),
                            cfg.vocab)
    upload_token_dataset(client, "/corpus/train", toks, num_slices=8)
    daemon.run_until_stable()
    pipe = SectorDataPipeline(master, client, "/corpus/train",
                              batch=args.batch, seq_len=args.seq)

    mesh = make_host_mesh(args.data, args.model)
    dp = dp_axes_of(mesh)
    key = jax.random.PRNGKey(0)
    _, p_specs = model.init(jax.random.PRNGKey(1))  # small: specs via init
    params, opt = init_train_state(model, key, mesh, p_specs)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20,
                          total_steps=args.steps)
    from jax.sharding import PartitionSpec as P
    b_specs = {"tokens": P(dp[0] if dp else None, None),
               "labels": P(dp[0] if dp else None, None)}
    step_fn, _ = jit_train_step(model, opt_cfg, mesh, p_specs, b_specs,
                                dp_axes=dp or ("data",))

    ckpt = SectorCheckpointer(client, "/ckpt/run0", num_slices=4)
    it = iter(pipe)
    t0 = time.time()
    step = 0
    losses = []
    while step < args.steps:
        try:
            batch = next(it)
        except StopIteration:
            it = iter(pipe)
            continue
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = step_fn(params, opt, batch)
        step += 1
        losses.append(float(metrics["loss"]))
        if step % 10 == 0:
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.time() - t0) / step:.3f}s/step)", flush=True)
        if step % args.ckpt_every == 0:
            ckpt.save(step, {"params": params, "opt": opt}, blocking=False)
            daemon.tick()
    ckpt.wait()
    ckpt.save(args.steps, {"params": params, "opt": opt})
    daemon.run_until_stable()
    print(f"final loss {np.mean(losses[-10:]):.4f} "
          f"(first10 {np.mean(losses[:10]):.4f}); "
          f"checkpoints: {ckpt.list_steps()}")


if __name__ == "__main__":
    main()
