"""Sector-backed data pipeline: dataset slices live in the storage cloud;
segments are scheduled onto hosts with the Sphere locality rules."""

from repro.data.pipeline import SectorDataPipeline, upload_token_dataset
from repro.data.synthetic import synthetic_tokens

__all__ = ["SectorDataPipeline", "upload_token_dataset", "synthetic_tokens"]
