"""Synthetic token corpora for the end-to-end examples and tests.

A Zipf-ish unigram mixture with short-range repetition so a small LM has
learnable structure (loss decreases visibly within a few hundred steps).
"""

from __future__ import annotations

import numpy as np


def synthetic_tokens(num_tokens: int, vocab: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(vocab, size=num_tokens, p=probs).astype(np.int32)
    # inject copy structure: token[i] = token[i-k] for random runs
    n_runs = num_tokens // 64
    starts = rng.integers(8, max(num_tokens - 16, 9), size=n_runs)
    for s in starts:
        L = int(rng.integers(4, 12))
        k = int(rng.integers(1, 8))
        e = min(s + L, num_tokens)
        toks[s:e] = toks[s - k:e - k]
    return toks
