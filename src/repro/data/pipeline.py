"""Training data pipeline on the Sector/Sphere substrate.

Datasets are token arrays stored as Sector slices (int32 little-endian,
whole-file per slice). Batches are assembled per *host* following the Sphere
scheduler: segments are assigned with the locality rules
(:meth:`SegmentScheduler.static_assignment`), reads go through the master so
replica choice/failover is automatic, and a host that dies mid-epoch simply
has its remaining segments re-assigned (the paper's SPE-timeout semantics,
exercised in the tests via ``reassign_lost``).
"""

from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.core.stream import SegmentInfo, SphereStream
from repro.sector.client import SectorClient
from repro.sector.master import Master
from repro.sphere.scheduler import SegmentScheduler, SPEState

RECORD_BYTES = 4  # one int32 token


def upload_token_dataset(client: SectorClient, prefix: str,
                         tokens: np.ndarray, num_slices: int = 8):
    """Store a token corpus as Sector slices (paper §2.1: a dataset is 1+
    files; e.g. the 1.3 TB / 64-file SDSS set)."""
    tokens = tokens.astype(np.int32)
    per = (len(tokens) + num_slices - 1) // num_slices
    metas = []
    for i in range(num_slices):
        chunk = tokens[i * per:(i + 1) * per]
        metas.append(client.upload(f"{prefix}.{i:05d}", chunk.tobytes()))
    return metas


class SectorDataPipeline:
    """Iterates (tokens, labels) batches for one host group.

    ``host_addr``/``host_id``: which SPE this pipeline feeds; with
    ``num_hosts`` > 1 the segment table is partitioned by the scheduler's
    locality-greedy static assignment.
    """

    def __init__(self, master: Master, client: SectorClient, prefix: str,
                 batch: int, seq_len: int, host_id: int = 0,
                 num_hosts: int = 1, seed: int = 0,
                 segment_records: int = 1 << 16):
        self.master = master
        self.client = client
        self.batch = batch
        self.seq_len = seq_len
        self.rng = np.random.default_rng(seed)

        files = [(m.path, m.size // RECORD_BYTES)
                 for m in master.list_dir(prefix)
                 if not m.path.endswith("MANIFEST.json")]
        if not files:
            raise FileNotFoundError(f"no dataset slices under {prefix}")
        total = sum(n for _, n in files)
        self.segments = SphereStream.plan_segments(
            total, RECORD_BYTES, files,
            s_min=RECORD_BYTES, s_max=segment_records * RECORD_BYTES,
            num_spes=num_hosts * 4)

        # locality-aware host assignment (Sphere rules 1-3)
        spes = [SPEState(i, list(master.slaves.values())[
            i % max(len(master.slaves), 1)].address)
            for i in range(num_hosts)]
        locations = {p: master.locations_of(p) for p, _ in files}
        sched = SegmentScheduler(self.segments, spes, locations)
        assignment = sched.static_assignment()
        self.my_segments: List[SegmentInfo] = [
            self.segments[i] for i in assignment.get(host_id, [])]
        self._buffer = np.zeros((0,), np.int32)
        self._cursor = 0

    def _read_segment(self, seg: SegmentInfo) -> np.ndarray:
        data = self.client.download(seg.file_path)
        arr = np.frombuffer(data, np.int32)
        return arr[seg.offset:seg.offset + seg.num_records]

    def reassign_lost(self, lost_segment_indices: Sequence[int]) -> None:
        """Fold segments from a dead host back into this host's queue."""
        self.my_segments.extend(self.segments[i] for i in lost_segment_indices)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        order = self.rng.permutation(len(self.my_segments))
        need = self.batch * (self.seq_len + 1)
        for si in order:
            seg = self.my_segments[si]
            self._buffer = np.concatenate([self._buffer,
                                           self._read_segment(seg)])
            while len(self._buffer) >= need:
                chunk = self._buffer[:need]
                self._buffer = self._buffer[need:]
                block = chunk.reshape(self.batch, self.seq_len + 1)
                yield {"tokens": block[:, :-1].copy(),
                       "labels": block[:, 1:].copy()}
