"""End-to-end LM training on the full Sector/Sphere substrate.

Storage cloud up -> corpus uploaded as Sector slices -> Sphere-scheduled
data pipeline -> sharded train step -> Sector-backed checkpoints with the
replication daemon -> kill a slave mid-run and keep training.

Default config is CPU-sized (a few minutes); ``--hundred-m`` switches to a
~100M-param llama-family model (same code path, hours on CPU, minutes on a
real accelerator).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import _bootstrap

_bootstrap.setup()

import argparse
import dataclasses
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data import (SectorDataPipeline, synthetic_tokens,
                        upload_token_dataset)
from repro.launch.train import make_sector
from repro.models import build
from repro.train.checkpoint import SectorCheckpointer
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.trainer import build_train_step

SMALL = ModelConfig(arch_id="example_lm", family="dense", num_layers=4,
                    d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
                    vocab=2048, attn_type="gqa", scan_layers=False)
HUNDRED_M = ModelConfig(arch_id="example_lm_100m", family="dense",
                        num_layers=12, d_model=768, n_heads=12,
                        n_kv_heads=4, d_ff=2048, vocab=32_000,
                        attn_type="gqa")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--hundred-m", action="store_true")
    args = ap.parse_args()

    cfg = HUNDRED_M if args.hundred_m else SMALL
    model = build(cfg)
    root = tempfile.mkdtemp(prefix="sector_train_")
    master, client, daemon = make_sector(root, num_slaves=4)

    toks = synthetic_tokens(args.batch * (args.seq + 1) * (args.steps + 8),
                            cfg.vocab)
    upload_token_dataset(client, "/corpus/lm", toks, num_slices=8)
    daemon.run_until_stable()
    pipe = SectorDataPipeline(master, client, "/corpus/lm",
                              batch=args.batch, seq_len=args.seq)

    params, _ = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params / 1e6:.1f}M params "
          f"({cfg.num_layers}L d={cfg.d_model})")
    opt = init_opt_state(params)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    step = jax.jit(build_train_step(model, opt_cfg, None))
    ckpt = SectorCheckpointer(client, "/ckpt/example", num_slices=4)

    losses, it, t0, i = [], iter(pipe), time.time(), 0
    while i < args.steps:
        try:
            b = next(it)
        except StopIteration:
            it = iter(pipe)
            continue
        params, opt, m = step(params, opt,
                              {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
        i += 1
        if i == args.steps // 2:
            # mid-run fault injection: a storage slave dies; training and
            # checkpointing continue through the replicas
            victim = list(master.slaves)[0]
            master.slaves[victim].kill()
            daemon.run_until_stable()
            print(f"step {i}: killed slave {victim}; pipeline + ckpt "
                  f"continue via replicas")
        if i % 25 == 0:
            ckpt.save(i, {"params": params, "opt": opt}, blocking=False)
            print(f"step {i:4d} loss {np.mean(losses[-25:]):.4f} "
                  f"({(time.time() - t0) / i:.3f}s/step)")
    ckpt.wait()
    ckpt.save(args.steps, {"params": params, "opt": opt})
    print(f"loss: {np.mean(losses[:20]):.3f} -> {np.mean(losses[-20:]):.3f}; "
          f"checkpoints at {ckpt.list_steps()}")
    assert np.mean(losses[-20:]) < np.mean(losses[:20])


if __name__ == "__main__":
    main()
