"""Streaming wordcount: one compiled pipeline serving three tenants
(paper §3.2 — "Sphere takes streams as inputs and produces streams as
outputs" — run continuously instead of once).

The batch executors run a ``Dataflow`` pipeline one time over one dataset.
Here the SAME stage graph is declared with ``Dataflow.stream_source()`` and
handed to a :class:`~repro.sphere.streaming.StreamExecutor`:

- requests (small record batches) are admitted into a
  :class:`~repro.sphere.streaming.TenantQueue` with weighted fair share
  (free=1, pro=3, enterprise=4) and bounded per-tenant queues;
- every ``step()`` assembles one fixed-shape micro-batch from the fairest
  mix of queued requests and runs the compiled program once — zero
  recompiles after the first batch (watch ``cache_info()``);
- the word counts accumulate across batches in bounded carry state, so the
  final snapshot equals a one-shot batch run over everything submitted.

Run:  PYTHONPATH=src python examples/streaming_wordcount.py
"""

import _bootstrap

_bootstrap.setup(devices=8)

import collections

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mapreduce import default_hash, reduce_by_key_sum
from repro.sphere.dataflow import Dataflow, SPMDExecutor
from repro.sphere.streaming import StreamExecutor, TenantQueue

NUM_BUCKETS = 8
VOCAB = 26
MICRO_BATCH = 8 * 32


def build_pipeline() -> Dataflow:
    def emit(rec):
        return {"key": rec["word"].astype(jnp.int32),
                "value": jnp.ones_like(rec["word"], jnp.int32)}

    def count(rec, valid):
        keys, sums, dropped = reduce_by_key_sum(rec["key"], rec["value"],
                                                valid)
        return {"key": keys, "value": sums}, keys >= 0, dropped

    return (Dataflow.stream_source()
            .map(emit)
            .shuffle(by=lambda r: default_hash(r["key"], NUM_BUCKETS),
                     num_buckets=NUM_BUCKETS)
            .reduce(count))


def main() -> None:
    df = build_pipeline()
    print(f"pipeline: {df.describe()}")

    queue = TenantQueue(quantum=32.0)
    for tenant, weight in (("free", 1.0), ("pro", 3.0), ("enterprise", 4.0)):
        queue.register(tenant, weight=weight)
    mesh = jax.make_mesh((8,), ("data",))
    ex = StreamExecutor(SPMDExecutor(mesh), df, micro_batch=MICRO_BATCH,
                        carry_capacity=VOCAB, queue=queue)

    rng = np.random.default_rng(0)
    submitted = []
    for _ in range(24):                 # a burst of requests from each tenant
        for tenant in ("free", "pro", "enterprise"):
            words = rng.integers(0, VOCAB, size=32).astype(np.uint8)
            submitted.append(words)
            ex.submit({"word": words}, tenant=tenant)

    while queue.pending():
        batch = ex.step()
        if batch is None:
            break
        snap = ex.carry_state()
        print(f"batch {batch.step}: {len(batch.delivered)} requests, "
              f"{int(np.asarray(snap['value']).sum())} words counted so far")

    snap = ex.carry_state()
    got = {int(k): int(v) for k, v in zip(snap["key"], snap["value"])}
    want = dict(collections.Counter(
        np.concatenate(submitted).astype(int).tolist()))
    assert got == want, "streamed counts diverged from ground truth"

    stats = ex.stats()
    print(f"cache: {stats['cache']['misses']} compile, "
          f"{stats['cache']['hits']} reuses")
    for tenant, t in stats["tenants"].items():
        print(f"  {tenant:<11} weight={t['weight']:.0f} "
              f"served={t['records_served']} records "
              f"p50_wait={t['latency_p50']:.3f}s")
    assert stats["cache"]["misses"] == 1, "stream recompiled mid-flight"
    print("final snapshot == one-shot ground truth (verified)")


if __name__ == "__main__":
    main()
