"""Quickstart: the paper's §3.1 pseudo-code, runnable.

    SphereStream sdss;  sdss.init(<slices>);
    SphereProcess myproc;  myproc.run(sdss, "findBrownDwarf");
    myproc.read(result);

Brings up an in-process Sector deployment, uploads a sliced 'SDSS' dataset,
and runs a UDF over every segment through the Sphere engine — with locality
scheduling and fault tolerance underneath.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import _bootstrap

_bootstrap.setup()

import os
import tempfile

import numpy as np

from repro.sector import (Master, NodeAddress, ReplicationDaemon,
                          SectorClient, SecurityServer, SlaveNode, Topology)
from repro.sphere.engine import SphereProcess
from repro.sphere.spe import SPE


def main() -> None:
    root = tempfile.mkdtemp(prefix="sector_quickstart_")

    # 1. bring up the storage cloud: security server, master, slaves
    sec = SecurityServer()
    sec.add_user("astro", "pw")
    sec.allow_slaves("10.0.0.0/8")
    master = Master(sec, replication_factor=2)
    topo = Topology(pods=1, racks=2, nodes_per_rack=3)
    for i, addr in enumerate(topo.all_addresses()):
        master.register_slave(SlaveNode(i, addr,
                                        os.path.join(root, f"slave{i}"),
                                        ip=f"10.0.0.{i + 1}"))
    client = SectorClient(master, "astro", "pw",
                          client_addr=NodeAddress(0, 0, 0))

    # 2. upload the dataset as Sector slices (paper: SDSS1.dat ... SDSS64.dat)
    rng = np.random.default_rng(0)
    record_bytes = 1024            # one "image" per record
    slices = [rng.integers(0, 256, size=(200, record_bytes),
                           dtype=np.uint8) for _ in range(8)]
    client.upload_dataset("/sdss/SDSS", [s.tobytes() for s in slices])
    ReplicationDaemon(master).run_until_stable()
    print(f"uploaded {len(slices)} slices; "
          f"{len(master.index)} files in the master index")

    # 3. the UDF
    def find_brown_dwarf(records: np.ndarray) -> np.ndarray:
        brightness = records.astype(np.int32).sum(axis=1)
        return np.nonzero(brightness > brightness.mean())[0].astype(np.int32)

    # 4. run it over every segment (one SPE per slave)
    spes = [SPE(i, master.slaves[i].address, master, client.session_id)
            for i in range(6)]
    proc = SphereProcess(master, client.session_id, spes)
    result = proc.run([f"/sdss/SDSS.{i:05d}" for i in range(8)],
                      find_brown_dwarf, record_bytes)
    found = sum(len(v) for v in result.outputs.values())
    print(f"segments processed: {len(result.outputs)}, "
          f"brown dwarfs found: {found}, retries: {result.retries}, "
          f"errors: {len(result.errors)}")


if __name__ == "__main__":
    main()
