"""Terasort demo (paper Fig 3): the two-stage distributed sort as ONE
dataflow pipeline on 8 virtual devices, with the Pallas bitonic kernel as
stage 2.

The whole sort is `Dataflow.source().sort(key=..., splitters=...)`; the SPMD
executor fuses range-partition shuffle + local sort into one jit'd program
and caches the compilation, so the timed second call is pure execution.

Run:  PYTHONPATH=src python examples/terasort_demo.py
"""

import _bootstrap

_bootstrap.setup(devices=8)

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sort import hadoop_style_sort, is_globally_sorted, \
    sampled_splitters
from repro.sphere.dataflow import Dataflow, SPMDExecutor


def main() -> None:
    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    n = 8 * 16_384
    keys = rng.integers(0, 2**31 - 2, size=n).astype(np.int32)
    payload = np.arange(n, dtype=np.int32)   # index into the 90-byte values
    kd, pd = jnp.asarray(keys), jnp.asarray(payload)

    with mesh:
        # non-uniform keys? sample splitters like the paper's 'more advanced
        # hashing technique' (§3.6)
        spl = sampled_splitters(kd, 8, sample_per_shard=128, mesh=mesh)
        df = Dataflow.source().sort(key=lambda r: r["key"], splitters=spl,
                                    num_buckets=8)
        print(f"pipeline: {df.describe()}")

        def run_df(executor):
            return executor.run(df, {"key": kd, "payload": pd})

        for name, fn in (
            ("sphere (pallas stage-2)",
             lambda ex=SPMDExecutor(mesh, use_pallas=True): run_df(ex)),
            ("sphere (xla sort)",
             lambda ex=SPMDExecutor(mesh, use_pallas=False): run_df(ex)),
            ("hadoop-style (allgather)",
             lambda: hadoop_style_sort(kd, pd, mesh)),
        ):
            res = fn()                        # compile (cached in executor)
            jax.block_until_ready(jax.tree.leaves(res.records
                                  if hasattr(res, "records") else res.keys)[0])
            t0 = time.time()
            res = fn()                        # cache hit: execution only
            out_keys = (res.records["key"] if hasattr(res, "records")
                        else res.keys)
            jax.block_until_ready(out_keys)
            dt = time.time() - t0
            ok = is_globally_sorted_result(res, out_keys)
            print(f"{name:28s} {n / dt / 1e6:7.2f} Mrec/s "
                  f"sorted={ok} dropped={int(res.dropped)}")


def is_globally_sorted_result(res, out_keys) -> bool:
    if hasattr(res, "records"):               # DataflowResult
        vk = np.asarray(out_keys)[np.asarray(res.valid)]
        return bool((np.diff(vk) >= 0).all())
    return is_globally_sorted(res, 8)         # SortResult baseline


if __name__ == "__main__":
    main()
