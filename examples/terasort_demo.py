"""Terasort demo (paper Fig 3): the compiled two-stage distributed sort on
8 virtual devices, with the Pallas bitonic kernel as stage 2.

Run:  PYTHONPATH=src python examples/terasort_demo.py
(Sets its own XLA_FLAGS; must be a fresh process.)
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.sort import (hadoop_style_sort, is_globally_sorted,
                             sampled_splitters, terasort)


def main() -> None:
    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    n = 8 * 16_384
    keys = rng.integers(0, 2**31 - 2, size=n).astype(np.int32)
    payload = np.arange(n, dtype=np.int32)   # index into the 90-byte values
    kd = jax.device_put(jnp.asarray(keys), NamedSharding(mesh, P("data")))
    pd = jax.device_put(jnp.asarray(payload), NamedSharding(mesh, P("data")))

    with mesh:
        # non-uniform keys? sample splitters like the paper's 'more advanced
        # hashing technique' (§3.6)
        spl = sampled_splitters(kd, 8, sample_per_shard=128, mesh=mesh)
        for name, fn in (
            ("sphere (pallas stage-2)",
             lambda: terasort(kd, pd, mesh, splitters=spl, use_pallas=True)),
            ("sphere (xla sort)",
             lambda: terasort(kd, pd, mesh, splitters=spl, use_pallas=False)),
            ("hadoop-style (allgather)",
             lambda: hadoop_style_sort(kd, pd, mesh)),
        ):
            res = fn()
            jax.block_until_ready(res.keys)
            t0 = time.time()
            res = fn()
            jax.block_until_ready(res.keys)
            dt = time.time() - t0
            ok = is_globally_sorted(res, 8)
            print(f"{name:28s} {n / dt / 1e6:7.2f} Mrec/s "
                  f"sorted={ok} dropped={int(res.dropped)}")


if __name__ == "__main__":
    main()
