"""Shared example bootstrap: virtual devices + import path.

Every SPMD example needs ``XLA_FLAGS=--xla_force_host_platform_device_count``
set **before jax initializes**, plus ``src/`` on ``sys.path``. Call
``setup()`` as the very first statement, before importing jax:

    import _bootstrap
    _bootstrap.setup(devices=8)

(The examples directory itself is on ``sys.path`` when a script is run as
``python examples/foo.py``, so this module is importable without packaging.)
"""

import os
import sys


def setup(devices: int = 0) -> None:
    """Add ``src/`` to the import path; with ``devices`` > 0, force that many
    virtual XLA host devices (must run before jax is imported)."""
    if devices:
        if "jax" in sys.modules:
            raise RuntimeError("_bootstrap.setup() must run before jax is "
                               "imported (XLA_FLAGS is read at jax init)")
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices}")
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
    if src not in sys.path:
        sys.path.insert(0, src)
