"""Inverted index via Map UDF + bucket shuffle + Reduce UDF (paper §3.6).

The paper's own example: compute word -> [pages] for a collection of web
pages, once through the host-level Sphere engine (Sector-stored pages, SPEs,
bucket files) and once through the compiled SPMD map_reduce (all_to_all).

Run:  PYTHONPATH=src python examples/inverted_index.py
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.mapreduce import map_reduce, reduce_by_key_sum
from repro.launch.train import make_sector
from repro.sphere.engine import SphereProcess
from repro.sphere.spe import SPE


def host_level(pages):
    """Stage 1: extract (word, page) pairs, hash words into buckets.
    Stage 2: aggregate each bucket (paper's bee/cow/camel example)."""
    root = tempfile.mkdtemp(prefix="sector_ii_")
    master, client, daemon = make_sector(root, num_slaves=4)
    client.upload_dataset("/web/page", [p.tobytes() for p in pages])
    daemon.run_until_stable()
    spes = [SPE(i, master.slaves[i].address, master, client.session_id)
            for i in range(4)]
    proc = SphereProcess(master, client.session_id, spes)
    n_buckets = 4
    result = proc.run(
        [f"/web/page.{i:05d}" for i in range(len(pages))],
        lambda recs: recs.reshape(-1, 2), record_bytes=2,
        bucket_fn=lambda out: {b: out[out[:, 0] % n_buckets == b]
                               for b in range(n_buckets)},
        num_buckets=n_buckets)
    index = {}
    for b, recs in result.outputs.items():
        recs = recs.reshape(-1, 2)
        for w in np.unique(recs[:, 0]) if len(recs) else []:
            index[int(w)] = sorted(set(recs[recs[:, 0] == w][:, 1].tolist()))
    return index


def spmd_level(words):
    """The same shuffle as a compiled all_to_all wordcount."""
    mesh = jax.make_mesh((8,), ("data",))
    wd = jax.device_put(jnp.asarray(words),
                        NamedSharding(mesh, P("data")))
    with mesh:
        k, v, valid, dropped = map_reduce(
            lambda seg: (seg, jnp.ones_like(seg)), reduce_by_key_sum,
            wd, mesh)
    k, v, valid = map(np.asarray, (k, v, valid))
    return {int(a): int(b) for a, b, ok in zip(k, v, valid) if ok and a >= 0}


def main() -> None:
    rng = np.random.default_rng(0)
    pages = []
    for i in range(4):
        p = rng.integers(0, 26, size=(30, 2), dtype=np.uint8)
        p[:, 1] = i
        pages.append(p)
    index = host_level(pages)
    print(f"host-level inverted index: {len(index)} words; "
          f"word0 -> pages {index.get(0, [])}")

    words = rng.integers(0, 26, size=8 * 128).astype(np.int32)
    counts = spmd_level(words)
    import collections
    assert counts == dict(collections.Counter(words.tolist()))
    print(f"SPMD wordcount over 8 devices: {len(counts)} words, "
          f"total {sum(counts.values())} (verified)")


if __name__ == "__main__":
    main()
