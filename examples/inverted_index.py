"""Inverted index / wordcount via the unified dataflow API (paper §3.6).

The paper's own example — word -> pages buckets — written ONCE as a
``Dataflow`` pipeline (map -> hash bucket shuffle -> per-bucket reduce) and
executed twice:

- on the **host executor**: pages stored in Sector, SPEs with locality
  scheduling and retry, bucket files materialized back into Sector;
- on the **SPMD executor**: the identical pipeline object fused into one
  jit'd shard_map with a capacity-bounded all_to_all.

Both runs produce the same word -> count multiset, asserted at the end.

Run:  PYTHONPATH=src python examples/inverted_index.py
"""

import _bootstrap

_bootstrap.setup(devices=8)

import collections
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mapreduce import default_hash, reduce_by_key_sum
from repro.core.records import RecordCodec
from repro.launch.train import make_sector
from repro.sphere.dataflow import Dataflow, HostExecutor, SPMDExecutor
from repro.sphere.spe import SPE

NUM_BUCKETS = 8

#: one record = one (word, page) occurrence, 2 bytes in Sector
PAGE_CODEC = RecordCodec.from_fields({"word": np.uint8, "page": np.uint8})


def build_pipeline() -> Dataflow:
    def emit(rec):
        return {"key": rec["word"].astype(jnp.int32),
                "value": jnp.ones_like(rec["word"], jnp.int32)}

    def count(rec, valid):
        keys, sums, dropped = reduce_by_key_sum(rec["key"], rec["value"], valid)
        return {"key": keys, "value": sums}, keys >= 0, dropped

    return (Dataflow.source(PAGE_CODEC)
            .map(emit)
            .shuffle(by=lambda r: default_hash(r["key"], NUM_BUCKETS),
                     num_buckets=NUM_BUCKETS)
            .reduce(count))


def counts_of(result) -> dict:
    rec = result.valid_records()
    return {int(k): int(v) for k, v in zip(rec["key"], rec["value"])}


def main() -> None:
    rng = np.random.default_rng(0)
    pages = []
    for i in range(4):
        p = rng.integers(0, 26, size=(30, 2), dtype=np.uint8)
        p[:, 1] = i
        pages.append(p)
    allpages = np.concatenate(pages)
    want = dict(collections.Counter(allpages[:, 0].tolist()))

    df = build_pipeline()
    print(f"pipeline: {df.describe()}")

    # -- host executor: Sector storage, SPEs, bucket files -------------------
    root = tempfile.mkdtemp(prefix="sector_ii_")
    master, client, daemon = make_sector(root, num_slaves=4)
    client.upload_dataset("/web/page", [p.tobytes() for p in pages])
    daemon.run_until_stable()
    spes = [SPE(i, master.slaves[i].address, master, client.session_id)
            for i in range(4)]
    host = HostExecutor(master, client, spes)
    host_res = host.run(df, [f"/web/page.{i:05d}" for i in range(len(pages))])
    host_counts = counts_of(host_res)
    print(f"host (Sector/SPE):  {len(host_counts)} words, "
          f"total {sum(host_counts.values())}, retries {host_res.retries}")

    # -- SPMD executor: same pipeline, one compiled program -------------------
    mesh = jax.make_mesh((8,), ("data",))
    spmd = SPMDExecutor(mesh)
    with mesh:
        spmd_res = spmd.run(df, {"word": jnp.asarray(allpages[:, 0]),
                                 "page": jnp.asarray(allpages[:, 1])})
    spmd_counts = counts_of(spmd_res)
    print(f"SPMD (8 devices):   {len(spmd_counts)} words, "
          f"total {sum(spmd_counts.values())}, "
          f"dropped {int(spmd_res.dropped)}")

    assert host_counts == want, "host executor diverged from ground truth"
    assert spmd_counts == want, "SPMD executor diverged from ground truth"
    print("host == SPMD == ground truth (verified)")


if __name__ == "__main__":
    main()
